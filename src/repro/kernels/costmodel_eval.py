"""Pallas TPU kernel: batched (design-point x layer) cost evaluation.

This is the compute hot-spot of the whole search: every REINFORCE epoch,
GA generation, grid sweep or baseline iteration evaluates a *batch* of
per-layer (PE, Buf, dataflow) assignments against the workload's layer
descriptors.  On TPU the batch can be millions of design points (distributed
GA populations / vmapped episode batches), so the evaluation is tiled through
VMEM explicitly:

  grid = (B / TB, N / TN)              B = design-point batch, N = layers
  layers   : (NUM_FIELDS, N)  f32  -> block (NUM_FIELDS, TN)   [broadcast row]
  pe,kt,df : (B, N)           f32  -> block (TB, TN)
  outputs  : 4 x (B, N)       f32  -> block (TB, TN)

TN = 128 puts the layer axis in the lane dimension (VPU 8x128 registers);
TB = 8 fills the sublane dimension.  The whole model is elementwise
transcendental-light arithmetic (ceil/div/min/max/sqrt), so one fused pass
through VMEM is optimal -- the kernel's job is to avoid materializing the
~20 intermediate (B, N) tensors the unfused jnp oracle round-trips through
HBM.  VMEM footprint per step: (8 + 3*TB + 4*TB) * TN * 4 B ~= 32 KiB << 16 MiB.

The kernel body calls :func:`repro.costmodel.maestro.core_cost` -- the exact
ops the ``ref.py`` oracle lowers, both running on the shared *hard* plateau-op
primitives (costmodel/primitives.py) -- so allclose agreement is structural.
(The soft/differentiable primitives never enter the kernel: Pallas only ever
lowers the hard path.)
Validated in interpret mode on CPU (tests/test_kernels.py sweeps shapes and
dtypes against the oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.costmodel import maestro
from repro.costmodel.layers import NUM_FIELDS

# Tile sizes: lane dim 128, sublane 8 (float32 VREG tile on TPU).
TB = 8
TN = 128


def _cost_kernel(layers_ref, pe_ref, kt_ref, df_ref,
                 lat_ref, en_ref, area_ref, pw_ref):
    """One (TB, TN) tile: unpack layer fields, run the shared model core."""
    fields = [layers_ref[i, :][None, :] for i in range(NUM_FIELDS)]
    K, C, Y, X, R, S, ltype, repeat = fields
    out = maestro.core_cost(K, C, Y, X, R, S, ltype, repeat,
                            pe_ref[...], kt_ref[...], df_ref[...])
    lat_ref[...] = out.latency
    en_ref[...] = out.energy
    area_ref[...] = out.area
    pw_ref[...] = out.power


@functools.partial(jax.jit, static_argnames=("interpret",))
def cost_eval_padded(layers_t, pe, kt, df, *, interpret: bool = True):
    """Run the kernel on pre-padded inputs.

    layers_t: (NUM_FIELDS, N) f32, N % TN == 0.
    pe/kt/df: (B, N) f32, B % TB == 0.
    Returns (latency, energy, area, power), each (B, N) f32.
    """
    B, N = pe.shape
    grid = (B // TB, N // TN)
    layer_spec = pl.BlockSpec((NUM_FIELDS, TN), lambda i, j: (0, j))
    bn_spec = pl.BlockSpec((TB, TN), lambda i, j: (i, j))
    out_shape = [jax.ShapeDtypeStruct((B, N), jnp.float32)] * 4
    return pl.pallas_call(
        _cost_kernel,
        grid=grid,
        in_specs=[layer_spec, bn_spec, bn_spec, bn_spec],
        out_specs=[bn_spec] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )(layers_t, pe, kt, df)


def _cost_kernel_multi(layers_ref, pe_ref, kt_ref, df_ref,
                       lat_ref, en_ref, area_ref, pw_ref):
    """One (TB, TN) tile with a PER-ROW layer descriptor.

    Unlike :func:`_cost_kernel`, every batch row carries its own layer
    fields -- the multi-tenant shape the search service's cross-request
    batcher produces, where one dispatch fuses design points drawn from
    DIFFERENT workloads (mobilenet rows next to resnet rows).
    """
    fields = [layers_ref[:, i, :] for i in range(NUM_FIELDS)]
    K, C, Y, X, R, S, ltype, repeat = fields
    out = maestro.core_cost(K, C, Y, X, R, S, ltype, repeat,
                            pe_ref[...], kt_ref[...], df_ref[...])
    lat_ref[...] = out.latency
    en_ref[...] = out.energy
    area_ref[...] = out.area
    pw_ref[...] = out.power


@functools.partial(jax.jit, static_argnames=("interpret",))
def cost_eval_multi_padded(layers_bt, pe, kt, df, *, interpret: bool = True):
    """Per-row-layers kernel on pre-padded inputs.

    layers_bt: (B, NUM_FIELDS, N) f32 -- row b's own layer descriptors.
    pe/kt/df:  (B, N) f32, B % TB == 0, N % TN == 0.
    Returns (latency, energy, area, power), each (B, N) f32.

    VMEM per step grows by the (TB, NUM_FIELDS, TN) layer block versus the
    broadcast kernel: (8*TB + 7*TB) * TN * 4 B ~= 60 KiB, still far under
    the 16 MiB budget.
    """
    B, N = pe.shape
    grid = (B // TB, N // TN)
    layer_spec = pl.BlockSpec((TB, NUM_FIELDS, TN), lambda i, j: (i, 0, j))
    bn_spec = pl.BlockSpec((TB, TN), lambda i, j: (i, j))
    out_shape = [jax.ShapeDtypeStruct((B, N), jnp.float32)] * 4
    return pl.pallas_call(
        _cost_kernel_multi,
        grid=grid,
        in_specs=[layer_spec, bn_spec, bn_spec, bn_spec],
        out_specs=[bn_spec] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )(layers_bt, pe, kt, df)
