"""Pallas TPU kernels for the framework's compute hot-spots.

  costmodel_eval -- batched (design-point x layer) cost evaluation (the
                    search inner loop; DESIGN.md S3)
  lstm_cell      -- fused REINFORCE policy step
  flash_decode   -- online-softmax single-token GQA attention for long-KV
                    serving shapes

``ops`` exposes shape-flexible wrappers; ``ref`` holds the pure-jnp oracles.
Off-TPU everything runs through ``interpret=True``.
"""
from repro.kernels.ops import batched_cost, decode_attention, lstm_step

__all__ = ["batched_cost", "decode_attention", "lstm_step"]
