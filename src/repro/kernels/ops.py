"""Public, shape-flexible entry points for the Pallas kernels.

Each op pads its inputs to the kernel's tile multiples, dispatches to the
``pl.pallas_call`` implementation (interpret mode off-TPU), and slices the
result back.  ``use_kernel=False`` routes to the pure-jnp oracle in ref.py --
the ops are drop-in interchangeable, which is how the tests validate them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.costmodel.layers import NUM_FIELDS
from repro.kernels import costmodel_eval, flash_decode, lstm_cell, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis: int, mult: int, value=0.0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def batched_cost(layers, pe, kt, df, *, use_kernel: bool = True):
    """Evaluate a (B, N) batch of per-layer assignments.

    layers: (N, NUM_FIELDS); pe/kt/df: (B, N) (df may be scalar).
    Returns (latency, energy, area, power), each (B, N) f32.
    """
    layers = jnp.asarray(layers, jnp.float32)
    N = layers.shape[0]
    pe = jnp.asarray(pe, jnp.float32)
    B = pe.shape[0]
    kt = jnp.broadcast_to(jnp.asarray(kt, jnp.float32), (B, N))
    df = jnp.broadcast_to(jnp.asarray(df, jnp.float32), (B, N))

    layers_t = layers.T  # (NUM_FIELDS, N)
    if not use_kernel:
        return ref.cost_eval_ref(layers_t, pe, kt, df)

    # Pad layers with benign dummies (all-ones layer) and slice out after.
    layers_p = _pad_to(layers_t, 1, costmodel_eval.TN, value=1.0)
    pe_p = _pad_to(_pad_to(pe, 0, costmodel_eval.TB, 1.0), 1,
                   costmodel_eval.TN, 1.0)
    kt_p = _pad_to(_pad_to(kt, 0, costmodel_eval.TB, 1.0), 1,
                   costmodel_eval.TN, 1.0)
    df_p = _pad_to(_pad_to(df, 0, costmodel_eval.TB, 1.0), 1,
                   costmodel_eval.TN, 1.0)
    outs = costmodel_eval.cost_eval_padded(layers_p, pe_p, kt_p, df_p,
                                           interpret=_interpret())
    return tuple(o[:B, :N] for o in outs)


def batched_cost_multi(layers, pe, kt, df, *, use_kernel: bool = True):
    """Evaluate a (B, N) batch where EVERY ROW has its own layer descriptors.

    layers: (B, N, NUM_FIELDS); pe/kt/df: (B, N) (kt/df may broadcast).
    Returns (latency, energy, area, power), each (B, N) f32.

    This is the multi-tenant shape of the serving batcher: one dispatch can
    fuse design points belonging to different users' workloads.  Tile
    padding uses benign all-ones values whose outputs are sliced away
    before returning -- callers aggregating over the full (B, N) result
    must mask their OWN padding (the batcher pads its rows with
    ``repeat=0`` layers, which zero all four outputs).
    """
    layers = jnp.asarray(layers, jnp.float32)
    B, N = layers.shape[0], layers.shape[1]
    pe = jnp.broadcast_to(jnp.asarray(pe, jnp.float32), (B, N))
    kt = jnp.broadcast_to(jnp.asarray(kt, jnp.float32), (B, N))
    df = jnp.broadcast_to(jnp.asarray(df, jnp.float32), (B, N))

    layers_bt = layers.transpose(0, 2, 1)  # (B, NUM_FIELDS, N)
    if not use_kernel:
        return ref.cost_eval_multi_ref(layers_bt, pe, kt, df)

    layers_p = _pad_to(_pad_to(layers_bt, 0, costmodel_eval.TB, 1.0), 2,
                       costmodel_eval.TN, 1.0)
    pe_p = _pad_to(_pad_to(pe, 0, costmodel_eval.TB, 1.0), 1,
                   costmodel_eval.TN, 1.0)
    kt_p = _pad_to(_pad_to(kt, 0, costmodel_eval.TB, 1.0), 1,
                   costmodel_eval.TN, 1.0)
    df_p = _pad_to(_pad_to(df, 0, costmodel_eval.TB, 1.0), 1,
                   costmodel_eval.TN, 1.0)
    outs = costmodel_eval.cost_eval_multi_padded(layers_p, pe_p, kt_p, df_p,
                                                 interpret=_interpret())
    return tuple(o[:B, :N] for o in outs)


def lstm_step(x, h, c, wx, wh, b, *, use_kernel: bool = True):
    """One LSTM cell step.  x: (B, I); h/c: (B, H); returns (h', c')."""
    if not use_kernel:
        return ref.lstm_cell_ref(x, h, c, wx, wh, jnp.reshape(b, (-1,)))
    B, I = x.shape
    H = h.shape[-1]
    # Pad the observation dim to the lane width and B to the batch tile.
    I_pad = int(np.maximum(128, -(-I // 128) * 128))
    x_p = _pad_to(_pad_to(x, 1, I_pad), 0, lstm_cell.TBL)
    wx_p = _pad_to(jnp.asarray(wx, jnp.float32), 0, I_pad)
    h_p = _pad_to(h, 0, lstm_cell.TBL)
    c_p = _pad_to(c, 0, lstm_cell.TBL)
    b2 = jnp.reshape(b, (1, 4 * H))
    h_new, c_new = lstm_cell.lstm_cell_padded(
        x_p, h_p, c_p, wx_p, jnp.asarray(wh, jnp.float32), b2,
        interpret=_interpret())
    return h_new[:B], c_new[:B]


def decode_attention(q, k, v, *, use_kernel: bool = True):
    """Single-token GQA attention over a KV cache.

    q: (B, Hq, D); k/v: (B, T, Hkv, D).  Returns (B, Hq, D).
    """
    if not use_kernel:
        return ref.flash_decode_ref(q, k, v)
    T = k.shape[1]
    # Pad the cache length with -inf-masked dummy keys: we pad K with a huge
    # negative value in the first lane?  Simpler and exact: pad with zeros
    # and mask by appending matching zero-value V and correcting the softmax
    # -- instead we require T % TT == 0 here and fall back otherwise.
    if T % flash_decode.TT != 0:
        return ref.flash_decode_ref(q, k, v)
    return flash_decode.flash_decode_padded(
        jnp.asarray(q, jnp.float32), jnp.asarray(k, jnp.float32),
        jnp.asarray(v, jnp.float32), interpret=_interpret())
