"""Pallas TPU kernel: fused LSTM cell (the REINFORCE policy step).

The paper's policy network is an LSTM(128) stepped once per DNN layer
(SIII-A2).  With batched episodes (E parallel rollouts) the step is

    gates = x @ Wx + h @ Wh + b          (B, 4H)
    i,f,g,o = split(gates); c' = sig(f)*c + sig(i)*tanh(g); h' = sig(o)*tanh(c')

Unfused, XLA materializes ``gates`` plus 4 gate tensors in HBM between the
two matmuls and the elementwise tail.  The kernel fuses both matmuls (MXU)
and the gate nonlinearities (VPU) in one VMEM-resident pass:

  grid = (B / TBL,)
  x  : (B, I)  -> block (TBL, I)
  h,c: (B, H)  -> block (TBL, H)
  Wx : (I, 4H) -> whole  (I, 4H)    (H=128 -> 4H=512 lanes, MXU-aligned)
  Wh : (H, 4H) -> whole  (H, 4H)
  b  : (1, 4H) -> whole

H = 128 makes every matmul dim a multiple of 128 (MXU native); the input
dim I (the 10-dim observation) is zero-padded to 128 by the wrapper.
VMEM: (I + H)*4H*4B ~= 0.5 MiB of weights + small activations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TBL = 8  # episode-batch tile


def _sig(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref,
                 h_out_ref, c_out_ref):
    gates = (jnp.dot(x_ref[...], wx_ref[...],
                     preferred_element_type=jnp.float32)
             + jnp.dot(h_ref[...], wh_ref[...],
                       preferred_element_type=jnp.float32)
             + b_ref[...])
    H = h_ref.shape[-1]
    i = _sig(gates[:, 0 * H:1 * H])
    f = _sig(gates[:, 1 * H:2 * H])
    g = jnp.tanh(gates[:, 2 * H:3 * H])
    o = _sig(gates[:, 3 * H:4 * H])
    c_new = f * c_ref[...] + i * g
    h_out_ref[...] = o * jnp.tanh(c_new)
    c_out_ref[...] = c_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def lstm_cell_padded(x, h, c, wx, wh, b, *, interpret: bool = True):
    """Fused LSTM step on pre-padded inputs (B % TBL == 0).

    x: (B, I), h/c: (B, H), wx: (I, 4H), wh: (H, 4H), b: (1, 4H).
    Returns (h', c'), each (B, H).
    """
    B, I = x.shape
    H = h.shape[-1]
    grid = (B // TBL,)
    row = lambda shape: pl.BlockSpec(shape, lambda i: (i, 0))
    whole = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    return pl.pallas_call(
        _lstm_kernel,
        grid=grid,
        in_specs=[row((TBL, I)), row((TBL, H)), row((TBL, H)),
                  whole((I, 4 * H)), whole((H, 4 * H)), whole((1, 4 * H))],
        out_specs=[row((TBL, H)), row((TBL, H))],
        out_shape=[jax.ShapeDtypeStruct((B, H), jnp.float32)] * 2,
        interpret=interpret,
    )(x, h, c, wx, wh, b)
