"""Pallas TPU kernel: flash-decode GQA attention (one token vs a long KV).

Serving the assigned architectures at decode_32k / long_500k means one query
token attending over a KV cache of T = 32k..512k entries.  The naive lowering
materializes (Hq, T) logits and softmax weights in HBM -- at T = 512k that is
the whole memory story.  This kernel streams the cache through VMEM in TT
chunks with an online-softmax accumulator (the flash-attention recurrence),
so HBM traffic is exactly one read of K and V:

  grid = (B, Hkv, T / TT)          innermost = cache chunks
  q   : (B, Hq, D)     -> block (1, G, D)      G = Hq / Hkv (GQA group)
  k,v : (B, T, Hkv, D) -> block (1, TT, 1, D)
  out : (B, Hq, D)     -> block (1, G, D)
  scratch (VMEM): m (G,1), l (G,1), acc (G,D)  -- the online-softmax state

TT = 512 and D = 128 keep the (G, TT) logit tile and (TT, D) value tile
MXU-shaped; VMEM per step ~ (TT*D*2 + G*D + G*TT)*4B ~= 0.6 MiB.

The same kernel is the TPU-native analogue of the paper's "HW performance
estimator inner loop" insight: keep the hot operand (here the KV stream,
there the layer tile) resident and never round-trip intermediates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TT = 512  # KV-chunk tile


def _flash_decode_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    t = pl.program_id(2)
    nT = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                   # (G, D)
    k = k_ref[0, :, 0, :]             # (TT, D)
    v = v_ref[0, :, 0, :]             # (TT, D)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, TT)

    m_prev = m_ref[...]               # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)            # (G, TT)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * corr
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(t == nT - 1)
    def _finish():
        o_ref[0, 0] = acc_ref[...] / l_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_padded(q, k, v, *, interpret: bool = True):
    """q: (B, Hq, D); k, v: (B, T, Hkv, D), T % TT == 0, Hq % Hkv == 0."""
    B, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, T // TT)
    out = pl.pallas_call(
        _flash_decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, TT, 1, D), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, TT, 1, D), lambda b, h, t: (b, t, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(B, Hq, D)
