"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the ground truth a kernel is validated against (allclose
over shape/dtype sweeps in tests/test_kernels.py).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.costmodel import maestro
from repro.costmodel.layers import NUM_FIELDS


def cost_eval_ref(layers_t, pe, kt, df):
    """Oracle for kernels.costmodel_eval: (NUM_FIELDS, N) x (B, N) -> 4x(B, N).

    Identical math to the kernel: both call maestro.core_cost, which runs on
    the shared *hard* plateau-op primitives (costmodel/primitives.py) -- the
    single source of truth for the dataflow-term math.  This version simply
    broadcasts without any tiling.
    """
    fields = [layers_t[i][None, :] for i in range(NUM_FIELDS)]
    out = maestro.core_cost(*fields, pe, kt, df)
    return out.latency, out.energy, out.area, out.power


def cost_eval_multi_ref(layers_bt, pe, kt, df):
    """Oracle for the per-row-layers kernel: (B, NUM_FIELDS, N) x (B, N).

    Every batch row carries its own layer descriptor (the cross-request
    batcher's multi-tenant shape); plain broadcasting, no tiling.
    """
    fields = [layers_bt[:, i, :] for i in range(NUM_FIELDS)]
    out = maestro.core_cost(*fields, pe, kt, df)
    return out.latency, out.energy, out.area, out.power


def lstm_cell_ref(x, h, c, wx, wh, b):
    """Oracle for kernels.lstm_cell: one fused LSTM step.

    x: (B, I), h/c: (B, H), wx: (I, 4H), wh: (H, 4H), b: (4H,).
    Gate order: i, f, g, o.  Returns (h', c').
    """
    gates = x @ wx + h @ wh + b
    H = h.shape[-1]
    i = _sig(gates[..., 0 * H:1 * H])
    f = _sig(gates[..., 1 * H:2 * H])
    g = jnp.tanh(gates[..., 2 * H:3 * H])
    o = _sig(gates[..., 3 * H:4 * H])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def _sig(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def flash_decode_ref(q, k, v):
    """Oracle for kernels.flash_decode: single-token GQA attention.

    q: (B, Hq, D), k/v: (B, T, Hkv, D) with Hq % Hkv == 0.
    Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, D)
    logits = jnp.einsum("bhgd,bthd->bhgt", qg, k) / jnp.sqrt(D).astype(q.dtype)
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgt,bthd->bhgd", w, v)
    return out.reshape(B, Hq, D)
