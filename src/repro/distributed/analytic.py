"""Closed-form FLOPs / HBM-bytes accounting per (arch x shape) cell.

Why this exists: XLA's ``cost_analysis()`` counts a ``while`` body ONCE --
with scan-over-layers (and flash/CE chunk scans) the reported FLOPs are off
by the trip counts (verified: L=4 vs L=8 compiles differ by 0.4%).  We
therefore derive the roofline numerators analytically from the architecture
-- we wrote every matmul, so the counts are exact for *our* lowering,
including the costs a naive 6ND estimate misses: full-T^2 blockwise
attention (no causal-block skipping), MoE dispatch/combine einsums and
capacity overprovisioning, SSD intra-chunk quadratic work, remat recompute,
and the chunked-CE unembed.

Validation: tests/test_analytic.py compiles a reduced-depth FULLY-UNROLLED
variant and checks XLA's flops against these formulas (agreement within a
few %).  Collective traffic is NOT estimated here -- it is parsed from the
compiled HLO with while-trip scaling (hlo_analysis.py); this module only
provides the compute and memory terms.

Conventions: counts are GLOBAL per step; divide by mesh size for per-device.
Train factor: fwd(1) + bwd(2) + remat-recompute(1) = 4x forward matmul
FLOPs for everything under a checkpoint (all blocks, CE); optimizer adds
~12 flops/param.  bf16 = 2 bytes; optimizer state f32.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, InputShape
from repro.models import moe as moe_lib

TRAIN_FACTOR = 4.0     # fwd + 2x bwd + 1x remat recompute
BF16 = 2
F32 = 4


def _attn_layer_flops(cfg: ArchConfig, B: int, T: int, ctx: int) -> float:
    """One attention block forward: projections + full-block scores/ctx."""
    d, hd, H, Kv = cfg.d_model, cfg.hd(), cfg.num_heads, cfg.num_kv_heads
    N = B * T
    proj = 2.0 * N * d * hd * (H + 2 * Kv) + 2.0 * N * H * hd * d
    scores = 2.0 * B * T * ctx * H * hd * 2  # QK^T and PV, full blocks
    return proj + scores


def _mlp_flops(cfg: ArchConfig, B: int, T: int) -> float:
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    return 2.0 * B * T * cfg.d_model * cfg.d_ff * n_mats


def _moe_flops(cfg: ArchConfig, B: int, T: int, group: int) -> float:
    N = B * T
    d, f = cfg.d_model, cfg.d_ff
    E, k, cf = cfg.num_experts, cfg.experts_per_token, cfg.moe_capacity_factor
    g = min(group, N)
    C = moe_lib.capacity(g, cfg)
    router = 2.0 * N * d * E
    dispatch = 2.0 * N * (E * C / g) * d * 2          # dispatch + combine
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    experts = 2.0 * (N / g) * E * C * d * f * n_mats
    return router + dispatch + experts


def _mamba_layer_flops(cfg: ArchConfig, B: int, T: int) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    S = cfg.ssm_state
    Q = min(cfg.ssm_chunk, T)
    nc = max(T // Q, 1)
    N = B * T
    proj = 2.0 * N * d * (2 * di + 2 * S + H) + 2.0 * N * di * d
    conv = 2.0 * N * (di + 2 * S) * 4
    intra = 2.0 * B * nc * Q * Q * (S + H * P)        # CB scores + W.x
    states = 2.0 * B * nc * Q * H * P * S * 2         # chunk states + inter
    return proj + conv + intra + states


def _unembed_flops(cfg: ArchConfig, B: int, T: int) -> float:
    return 2.0 * B * T * cfg.d_model * cfg.vocab_size


def flops_cell(cfg: ArchConfig, shape: InputShape,
               moe_group: int = 256,
               train_factor: float = TRAIN_FACTOR) -> Dict[str, float]:
    """Global FLOPs for one step of this cell, by component.

    ``train_factor``: fwd(1) + bwd(2) + remat-recompute(r).  4.0 for full
    per-layer remat; for the 'dots' policy (matmul outputs saved) the
    recompute term drops to the non-dot ops -- the dry-run measures the
    actual ratio on an unrolled reduced config and passes it here.
    """
    B, T = shape.global_batch, shape.seq_len
    fam = cfg.family
    out: Dict[str, float] = {}

    if shape.kind in ("train", "prefill"):
        ctx = T
        if fam in ("dense", "moe"):
            attn = cfg.num_layers * _attn_layer_flops(cfg, B, T, ctx)
            ffn = cfg.num_layers * (
                _moe_flops(cfg, B, T, moe_group) if fam == "moe"
                else _mlp_flops(cfg, B, T))
            out = {"attention": attn, "ffn": ffn}
        elif fam == "ssm":
            out = {"ssm": cfg.num_layers * _mamba_layer_flops(cfg, B, T)}
        elif fam == "hybrid":
            n_sites = cfg.num_layers // cfg.shared_attn_period
            out = {"ssm": cfg.num_layers * _mamba_layer_flops(cfg, B, T),
                   "attention": n_sites * (_attn_layer_flops(cfg, B, T, ctx)
                                           + _mlp_flops(cfg, B, T))}
        elif fam == "audio":
            Se = cfg.encoder_seq
            enc = cfg.encoder_layers * (_attn_layer_flops(cfg, B, Se, Se)
                                        + _mlp_flops(cfg, B, Se))
            dec = cfg.num_layers * (
                _attn_layer_flops(cfg, B, T, T)            # self
                + _attn_layer_flops(cfg, B, T, Se)         # cross
                + 2 * _mlp_flops(cfg, B, T))
            out = {"encoder": enc, "decoder": dec}
        elif fam == "vlm":
            Sv = cfg.vision_seq
            n_cross = cfg.num_layers // cfg.cross_attn_period
            n_self = cfg.num_layers - n_cross
            out = {"attention": n_self * (_attn_layer_flops(cfg, B, T, T)
                                          + _mlp_flops(cfg, B, T)),
                   "cross": n_cross * (_attn_layer_flops(cfg, B, T, Sv)
                                       + _mlp_flops(cfg, B, T))}
        if shape.kind == "train":
            out["unembed_ce"] = _unembed_flops(cfg, B, T)
            out = {k: v * train_factor for k, v in out.items()}
            n_params = cfg.param_count()
            out["optimizer"] = 12.0 * n_params
        else:
            out["unembed_ce"] = _unembed_flops(cfg, B, 1)
        out["total"] = sum(out.values())
        return out

    # ---- decode: one token per sequence -------------------------------
    Tc = T  # cache / context length
    if fam in ("dense", "moe"):
        attn = cfg.num_layers * _attn_layer_flops(cfg, B, 1, Tc)
        ffn = cfg.num_layers * (
            _moe_flops(cfg, B, 1, moe_group) if fam == "moe"
            else _mlp_flops(cfg, B, 1))
        out = {"attention": attn, "ffn": ffn}
    elif fam == "ssm":
        d = cfg.d_model
        di = cfg.ssm_expand * d
        H, P, S = di // cfg.ssm_head_dim, cfg.ssm_head_dim, cfg.ssm_state
        per = (2.0 * B * d * (2 * di + 2 * S + H) + 2.0 * B * di * d
               + 2.0 * B * H * P * S * 2)
        out = {"ssm": cfg.num_layers * per}
    elif fam == "hybrid":
        d = cfg.d_model
        di = cfg.ssm_expand * d
        H, P, S = di // cfg.ssm_head_dim, cfg.ssm_head_dim, cfg.ssm_state
        per = (2.0 * B * d * (2 * di + 2 * S + H) + 2.0 * B * di * d
               + 2.0 * B * H * P * S * 2)
        n_sites = cfg.num_layers // cfg.shared_attn_period
        out = {"ssm": cfg.num_layers * per,
               "attention": n_sites * (_attn_layer_flops(cfg, B, 1, Tc)
                                       + _mlp_flops(cfg, B, 1))}
    elif fam == "audio":
        out = {"decoder": cfg.num_layers * (
            _attn_layer_flops(cfg, B, 1, Tc)
            + _attn_layer_flops(cfg, B, 1, cfg.encoder_seq)
            + 2 * _mlp_flops(cfg, B, 1))}
    elif fam == "vlm":
        n_cross = cfg.num_layers // cfg.cross_attn_period
        n_self = cfg.num_layers - n_cross
        out = {"attention": n_self * (_attn_layer_flops(cfg, B, 1, Tc)
                                      + _mlp_flops(cfg, B, 1)),
               "cross": n_cross * (_attn_layer_flops(cfg, B, 1,
                                                     cfg.vision_seq)
                                   + _mlp_flops(cfg, B, 1))}
    out["unembed"] = _unembed_flops(cfg, B, 1)
    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------
# HBM bytes.
# ---------------------------------------------------------------------------
def param_bytes(cfg: ArchConfig) -> float:
    return cfg.param_count() * BF16


def bytes_cell(cfg: ArchConfig, shape: InputShape) -> Dict[str, float]:
    """Global HBM traffic for one step (streaming lower bound)."""
    B, T = shape.global_batch, shape.seq_len
    N = B * T
    d = cfg.d_model
    pbytes = param_bytes(cfg)
    out: Dict[str, float] = {}

    if shape.kind == "train":
        # weights: fwd + remat recompute + bwd reads, grad write, adam rmw.
        out["weights"] = pbytes * 3
        out["grads+optimizer"] = (cfg.param_count()
                                  * (BF16 * 2 + F32 * 4 + F32 * 2))
        # layer-boundary activations saved + re-read (remat policy).
        out["activations"] = 2.0 * cfg.num_layers * N * d * BF16
        out["tokens"] = 2.0 * N * 4
    elif shape.kind == "prefill":
        out["weights"] = pbytes
        out["activations"] = 2.0 * cfg.num_layers * N * d * BF16
    else:  # decode
        active = pbytes
        if cfg.num_experts:
            # Dense-dispatch reads every expert's weights each step.
            active = pbytes
        out["weights"] = active
        if cfg.family in ("dense", "moe", "audio", "vlm"):
            sites = cfg.num_layers
            if cfg.family == "vlm":
                sites = cfg.num_layers - cfg.num_layers // cfg.cross_attn_period
            kv = 2.0 * sites * B * T * cfg.num_kv_heads * cfg.hd() * BF16
            out["kv_cache_read"] = kv
            out["kv_cache_write"] = kv / T
        if cfg.family in ("ssm", "hybrid"):
            di = cfg.ssm_expand * d
            H, P, S = di // cfg.ssm_head_dim, cfg.ssm_head_dim, cfg.ssm_state
            out["ssm_state_rmw"] = 2.0 * cfg.num_layers * B * H * P * S * F32
            if cfg.family == "hybrid":
                sites = cfg.num_layers // cfg.shared_attn_period
                kv = 2.0 * sites * B * T * cfg.num_kv_heads * cfg.hd() * BF16
                out["kv_cache_read"] = kv
    out["total"] = sum(out.values())
    return out


def summarize(cfg: ArchConfig, shape: InputShape, n_devices: int,
              moe_group: int = 256,
              train_factor: float = TRAIN_FACTOR) -> Dict[str, float]:
    f = flops_cell(cfg, shape, moe_group, train_factor)
    b = bytes_cell(cfg, shape)
    return {
        "flops_total": f["total"],
        "flops_per_device": f["total"] / n_devices,
        "bytes_total": b["total"],
        "bytes_per_device": b["total"] / n_devices,
        "flops_breakdown": f,
        "bytes_breakdown": b,
    }
