"""Distribution layer: sharding rules, HLO analysis, distributed search."""
