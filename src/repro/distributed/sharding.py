"""Per-architecture sharding rules (DP / FSDP / TP / EP / SP).

Parameter placement is rule-based on the parameter *path*: the big matmul
weights are TP-sharded on ``model`` along their parallel dimension and
FSDP-sharded on ``data`` along the other; experts put their E dim on
``model`` (EP); norms/scalars replicate.  Every assignment is guarded by
divisibility against the actual mesh -- a dim that doesn't divide falls back
to the next candidate axis or replication, so the same rules serve the
production 16x16 mesh, the 2x16x16 multi-pod mesh and tiny test meshes.

Activation sharding enters the model through a ShardingPolicy
(models/common.py): batch on ('pod','data'), sequence-parallel residual on
``model`` for training shapes, KV-cache sequence on ``model`` for decode
(the flash-decode layout), MoE group/expert dims on data/model.
"""
from __future__ import annotations

import math
import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import ShardingPolicy


def norm_path(kp) -> str:
    """tree key-path -> 'blocks/attn/wq' style string the rules match on."""
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def assign_spec(mesh, shape, prefs) -> P:
    """Greedy divisibility-guarded axis assignment.

    prefs: per-dim tuple of candidate axes (each an axis name or tuple of
    names), highest priority first.  An axis is used at most once.
    """
    used = set()
    spec = []
    for dim, cands in zip(shape, prefs):
        chosen = None
        for ax in cands:
            names = ax if isinstance(ax, tuple) else (ax,)
            if any(n not in mesh.axis_names or n in used for n in names):
                continue
            if dim % _axis_size(mesh, ax) == 0 and dim > 0:
                chosen = ax
                used.update(names)
                break
        # Normalize 1-tuples to bare names so specs compare canonically.
        if isinstance(chosen, tuple) and len(chosen) == 1:
            chosen = chosen[0]
        spec.append(chosen)
    return P(*spec)


# Parameter path -> per-dim axis preferences for the *trailing* dims; any
# leading (stack) dims are replicated.  fsdp = ('data',) [+ optionally
# ('pod',) when zero-3 across pods is enabled]; tp = 'model'.
_RULES = [
    # MoE expert banks: (E, D, F) / (E, F, D) -- EP on model.
    (r"moe.*w_(gate|up)$", (("model",), ("data",), ())),
    (r"moe.*w_down$", (("model",), (), ("data",))),
    (r"moe.*router$", (("data",), ())),
    # Embeddings.
    (r"embed.*tok$", (("model",), ("data",))),
    (r"embed.*unembed$", (("data",), ("model",))),
    # Attention.
    (r"attn.*w[qkv]$", (("data",), ("model",))),
    (r"attn.*wo$", (("model",), ("data",))),
    (r"attn.*b[qkv]$", (("model",),)),
    # Dense MLP.
    (r"mlp.*w_(gate|up)$", (("data",), ("model",))),
    (r"mlp.*w_down$", (("model",), ("data",))),
    # Mamba: in_proj is row-parallel TP (irregular output dim), out_proj
    # column-parallel.
    (r"mamba.*in_proj$", (("model",), ("data",))),
    (r"mamba.*out_proj$", (("model",), ("data",))),
    (r"mamba.*conv_[wb]$", ((), ("model",))),
]


def _fsdp_spec(mesh, shape) -> P:
    """ZeRO-3 placement: shard the largest divisible dim over ALL mesh axes
    (merged); no tensor parallelism.  Small/indivisible leaves replicate."""
    axes = tuple(mesh.axis_names)
    n = _axis_size(mesh, axes)
    if not shape or int(np.prod(shape)) < 2 * n:
        return P()
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if shape[i] % n == 0:
            spec = [None] * len(shape)
            spec[i] = axes
            return P(*spec)
    return P()


def param_spec(mesh, path: str, shape, mode: str = "tp") -> P:
    """mode 'tp' (baseline): TP on model + FSDP on data, per _RULES.
    mode 'tp_serve': TP on model only -- params replicated across the data
                     axis (serving replicas re-gather nothing per step).
    mode 'fsdp': pure ZeRO-3 over the merged mesh (no TP).
    mode 'dp':   fully replicated parameters (pure data parallel)."""
    if mode == "dp":
        return P()
    if mode == "fsdp":
        return _fsdp_spec(mesh, shape)
    for pat, prefs in _RULES:
        if re.search(pat, path):
            n_lead = len(shape) - len(prefs)
            if n_lead < 0:
                return P()
            full = tuple(() for _ in range(n_lead)) + tuple(prefs)
            if mode == "tp_serve":
                full = tuple(
                    tuple(ax for ax in cands
                          if ax not in ("data", "pod")
                          and not (isinstance(ax, tuple)
                                   and set(ax) & {"data", "pod"}))
                    for cands in full)
            return assign_spec(mesh, shape, full)
    return P()  # norms, scalars, biases without rules: replicate


def tree_shardings(mesh, tree, mode: str = "tp"):
    """NamedSharding pytree for params / optimizer state."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        spec = param_spec(mesh, norm_path(kp), np.shape(leaf), mode)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def sds_with_sharding(mesh, tree, mode: str = "tp"):
    """ShapeDtypeStructs carrying their target shardings (for AOT lower)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        spec = param_spec(mesh, norm_path(kp), leaf.shape, mode)
        out.append(jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                        sharding=NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Activation policies.
# ---------------------------------------------------------------------------
def _batch_axis(mesh, batch: int, *, include_model: bool = False):
    """Largest data-parallel axis combo that divides the global batch."""
    cands = (("pod", "data", "model"), ("data", "model"),
             ("pod", "data"), ("data",), ("pod",)) if include_model else \
            (("pod", "data"), ("data",), ("pod",))
    for cand in cands:
        if all(a in mesh.axis_names for a in cand):
            if batch % _axis_size(mesh, cand) == 0:
                return cand
    return None


def make_policy(mesh, *, batch: int, kind: str = "train",
                sp: bool = True, mode: str = "tp") -> ShardingPolicy:
    """Activation-sharding hooks for a given input shape.

    mode "tp"/"tp_serve" (baseline): residual stream is sequence-parallel
    on ``model`` (when divisible) for train/prefill, heads/ffn TP on
    ``model``; decode uses the KV-cache layout.
    mode "fsdp"/"dp": every mesh axis carries batch -- activations shard
    dim 0 only; layer math is fully local (ZeRO-3 weight gathers / pure-DP
    gradient reduction are the only collectives).
    """
    if mode in ("fsdp", "dp"):
        return _batch_only_policy(mesh, batch)
    dp = _batch_axis(mesh, batch)
    msize = mesh.shape["model"]

    def cons(x, spec):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def resid(x):
        if x.ndim != 3:
            return x
        seq_ok = sp and kind != "decode" and x.shape[1] % msize == 0
        return cons(x, P(dp, "model" if seq_ok else None, None))

    def heads(x):  # (B, T, H, hd): q stays sequence-sharded in SP mode
        if x.ndim != 4:
            return x
        if sp and kind != "decode" and x.shape[1] % msize == 0:
            return cons(x, P(dp, "model", None, None))
        if x.shape[2] % msize == 0:
            return cons(x, P(dp, None, "model", None))
        return x

    def kv_full(x):  # (B, S, Kv, hd): sequence-complete per device
        if x.ndim != 4 or kind == "decode":
            return x
        return cons(x, P(dp, None, None, None))

    def ssm_x(x):  # (B, T, H, P): full sequence; heads on model if divisible
        if x.ndim != 4:
            return x
        hax = "model" if x.shape[2] % msize == 0 else None
        return cons(x, P(dp, None, hax, None))

    def ffn(x):    # (B, T, F)
        if x.ndim != 3 or x.shape[2] % msize:
            return x
        return cons(x, P(dp, None, "model"))

    def experts(x):  # (n_groups, E, C, D)
        if x.ndim != 4 or x.shape[1] % msize:
            return x
        ng = dp if (dp and x.shape[0] % _axis_size(mesh, dp) == 0) else None
        return cons(x, P(ng, "model", None, None))

    # Routing/dispatch stays fully local: the group dim carries the merged
    # (batch x seq) sharding over EVERY mesh axis, so the only MoE traffic
    # is the all-to-all at the expert boundary (the pol.experts constraint).
    dpm = (tuple(dp) if dp else ()) + ("model",)

    def dispatch(x):  # (n_groups, g, E*C)
        if x.ndim != 3 or x.shape[0] % _axis_size(mesh, dpm):
            return x
        return cons(x, P(dpm, None, None))

    def experts_flat(x):  # (n_groups, E*C, D/F): same local layout
        if x.ndim != 3 or x.shape[0] % _axis_size(mesh, dpm):
            return x
        return cons(x, P(dpm, None, None))

    def logits(x):  # (B, T, V)
        if x.ndim != 3 or x.shape[2] % msize:
            return x
        return cons(x, P(dp, None, "model"))

    def cache(x):  # (B, Tmax, Kv, hd): sequence on model (flash-decode)
        if x.ndim != 4 or x.shape[1] % msize:
            return x
        bax = dp if (dp and x.shape[0] % _axis_size(mesh, dp) == 0) else None
        return cons(x, P(bax, "model", None, None))

    return ShardingPolicy(resid=resid, heads=heads, kv_full=kv_full,
                          ffn=ffn, experts=experts, dispatch=dispatch,
                          experts_flat=experts_flat, ssm_x=ssm_x,
                          logits=logits, cache=cache)


def _batch_only_policy(mesh, batch: int) -> ShardingPolicy:
    """fsdp/dp activation policy: dim 0 (batch or group) over ALL axes."""
    dp = _batch_axis(mesh, batch, include_model=True)

    def lead(x):
        if (dp is None or x.ndim < 1
                or x.shape[0] % _axis_size(mesh, dp)):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1)))))

    return ShardingPolicy(resid=lead, heads=lead, kv_full=lead, ffn=lead,
                          experts=lead, dispatch=lead, experts_flat=lead,
                          ssm_x=lead, logits=lead, cache=lead)


def batch_sharding(mesh, batch: int, *, mode: str = "tp"):
    dp = _batch_axis(mesh, batch, include_model=mode in ("fsdp", "dp"))
    return NamedSharding(mesh, P(dp, None))


def cache_shardings(mesh, cache, *, batch: int):
    """Shardings for the decode-cache pytree (flash-decode layout)."""
    dp = _batch_axis(mesh, batch)
    msize = mesh.shape["model"]

    def spec_for(path: str, leaf) -> P:
        shp = leaf.shape
        if re.search(r"attn_[kv]|cross_[kv]", path) and len(shp) == 5:
            # (sites, B, T, Kv, hd)
            bax = dp if (dp and shp[1] % _axis_size(mesh, dp) == 0) else None
            sax = "model" if shp[2] % msize == 0 else None
            return P(None, bax, sax, None, None)
        if re.search(r"mamba.*ssm", path):
            # (..., B, H, P, S): heads on model.
            prefs = tuple(() for _ in shp[:-4]) + (
                (("pod", "data"), ("data",)), ("model",), (), ())
            return assign_spec(mesh, shp, prefs)
        if re.search(r"mamba.*conv", path):
            prefs = tuple(() for _ in shp[:-3]) + (
                (("pod", "data"), ("data",)), (), ("model",))
            return assign_spec(mesh, shp, prefs)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for kp, leaf in flat:
        path = norm_path(kp)
        if hasattr(leaf, "shape") and leaf.ndim > 0:
            out.append(NamedSharding(mesh, spec_for(path, leaf)))
        else:
            out.append(NamedSharding(mesh, P()))
    return jax.tree_util.tree_unflatten(treedef, out)
