"""Pipeline parallelism (GPipe-style) via shard_map + collective_permute.

The roofline hillclimb (EXPERIMENTS.md §Perf) showed the big dense models
are bound by weight movement: TP+SP moves activations every layer, ZeRO-3
moves 2x the parameters every step.  Pipelining removes both: each stage
*owns* its layers' weights permanently and only the (microbatch, T, D)
boundary activations cross the wire.

Mapping onto the production mesh: the ``model`` axis becomes the stage
axis (S stages), ``data`` (x ``pod``) stays data-parallel.  The layer
stack's stacked parameters (L, ...) are sharded on dim 0 over ``model``
-- L % S == 0 -- so each device holds L/S contiguous layers.  One train
step inside ``shard_map``:

  1. embed the local batch shard, split into M microbatches;
  2. for t in range(M + S - 1):  (the GPipe schedule)
       every stage runs its layers on its current microbatch (SPMD: all
       stages compute every tick; inactive ticks are masked -- the bubble),
       then the boundary activation rotates one stage forward through a
       ``collective_permute`` ring;
  3. the last stage's outputs go through the chunked-CE loss; gradients
     flow back through the same schedule (autodiff of ppermute is the
     reverse permute -- the backward pipeline needs no extra code);
  4. block-weight grads stay stage-local (psum over ``data`` only);
     embed/unembed grads psum over the whole mesh.

Scope: dense-family (GQA attention + MLP) training -- the family where
PP matters at scale (qwen3-32b, llama-class).  MoE/ssm stages would
compose the same way around their block fns.

Cost notes for the dry-run record: with M microbatches the SPMD-masked
schedule *executes* (M+S-1)/M x the useful per-stage FLOPs (the bubble);
``pipeline_overhead`` in the record carries that factor, and the roofline
compute term is scaled by it (we charge ourselves for the bubble).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import common, lm


def _stage_forward(blocks_local, cfg, x, positions):
    """Run this stage's L/S layers sequentially (rematerialized)."""
    body = lambda lp, h: lm._attn_block(lp, cfg, h, positions)
    return lm._scan_stack(blocks_local, body, x, remat=True)


def _ce_loss(embed_params, cfg, h, labels):
    """Chunked CE over (mb, T, D) hidden states (same math as lm.lm_loss)."""
    B, T, D = h.shape
    ck = min(lm.CE_CHUNK, T)
    while T % ck:
        ck -= 1
    xc = h.reshape(B, T // ck, ck, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, T // ck, ck).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_nll(carry, xs):
        xchunk, lchunk = xs
        logits = common.unembed(embed_params, cfg, xchunk
                                ).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lchunk[..., None], axis=-1)[..., 0]
        return carry + nll.sum(), None

    total, _ = jax.lax.scan(chunk_nll, jnp.float32(0.0), (xc, lc))
    return total


def make_pp_train_step(cfg, optimizer, mesh, *, n_micro: int):
    """Build the pjit-able pipelined train step for a dense-family config.

    params layout: {"embed": ..., "blocks": stacked (L, ...)} with the
    blocks' leading dim sharded over ``model`` (the stage axis) and embed
    replicated.  batch: {"tokens": (B, T), "labels": (B, T)} sharded on
    the data axes.
    """
    assert cfg.family == "dense", "PP stages implemented for dense family"
    S = mesh.shape["model"]
    assert cfg.num_layers % S == 0, (cfg.num_layers, S)
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    M = n_micro

    def loss_fn(blocks_local, embed_params, tokens, labels):
        """Runs per device inside shard_map; returns the global mean NLL."""
        sid = jax.lax.axis_index("model")
        B, T = tokens.shape
        assert B % M == 0, (B, M)
        mb = B // M
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32),
                                     (mb, T))
        x = common.embed(embed_params, cfg, tokens)       # (B, T, D)
        xs = x.reshape(M, mb, T, x.shape[-1])
        lbs = labels.reshape(M, mb, T)

        n_ticks = M + S - 1

        def tick(recv, t):
            mb_idx = jnp.clip(t - sid, 0, M - 1)
            active = (t >= sid) & (t - sid < M)
            inp = jnp.where(sid == 0, xs[mb_idx], recv)
            out = _stage_forward(blocks_local, cfg, inp, positions)
            out = jnp.where(active, out, 0.0)
            nxt = jax.lax.ppermute(
                out, "model", [(i, (i + 1) % S) for i in range(S)])
            return nxt, out

        init = jnp.zeros((mb, T, x.shape[-1]), x.dtype)
        _, outs = jax.lax.scan(tick, init, jnp.arange(n_ticks))
        # On the LAST stage, outs[S-1+m] is microbatch m's final hidden.
        # CE runs once, after the pipeline drains (per-tick CE would both
        # waste unembed FLOPs and stack its residuals tick-wise).
        h_final = jax.lax.dynamic_slice_in_dim(outs, S - 1, M, axis=0)

        def mb_loss(acc, hm_lm):
            hm, lm_ = hm_lm
            return acc + _ce_loss(embed_params, cfg, hm, lm_), None

        loss_sum, _ = jax.lax.scan(mb_loss, jnp.float32(0.0),
                                   (h_final, lbs))
        is_last = (sid == S - 1).astype(jnp.float32)
        # Only the last stage saw real hiddens; share it, then average
        # over the data-parallel replicas and token count.
        loss_sum = jax.lax.psum(loss_sum * is_last, "model")
        loss = loss_sum / (B * T)
        return jax.lax.pmean(loss, data_axes)

    def spmd_step(blocks_local, embed_params, opt_blocks, opt_embed,
                  tokens, labels):
        loss, (g_blocks, g_embed) = jax.value_and_grad(
            loss_fn, argnums=(0, 1))(blocks_local, embed_params, tokens,
                                     labels)
        # Stage-local block grads reduce over the data replicas only;
        # embed/unembed grads were computed redundantly on every stage --
        # psum over data, mean over stages (each stage saw the full batch
        # shard's embedding path cotangent or zero).
        g_blocks = jax.lax.psum(g_blocks, data_axes)
        g_embed = jax.lax.psum(g_embed, data_axes + ("model",))
        new_blocks, opt_blocks = optimizer.update(g_blocks, opt_blocks,
                                                  blocks_local)
        new_embed, opt_embed = optimizer.update(g_embed, opt_embed,
                                                embed_params)
        return new_blocks, new_embed, opt_blocks, opt_embed, loss

    stage = P("model")
    rep = P()
    dspec = P(data_axes if len(data_axes) > 1 else data_axes[0], None)

    def train_step(params: Dict[str, Any], opt_state, batch):
        blocks, embed = params["blocks"], params["embed"]
        ob, oe = opt_state
        fn = shard_map(
            spmd_step, mesh=mesh,
            in_specs=(_specs(blocks, stage), _specs(embed, rep),
                      _specs(ob, stage), _specs(oe, rep),
                      dspec, dspec),
            out_specs=(_specs(blocks, stage), _specs(embed, rep),
                       _specs(ob, stage), _specs(oe, rep), rep),
            check_rep=False)
        nb, ne, ob, oe, loss = fn(blocks, embed, ob, oe,
                                  batch["tokens"], batch["labels"])
        return {"blocks": nb, "embed": ne}, (ob, oe), loss

    train_step.pipeline_overhead = (M + S - 1) / M
    return train_step


def _specs(tree, spec):
    """Per-leaf PartitionSpecs: scalars (e.g. OptState.step) replicate."""
    return jax.tree.map(
        lambda l: spec if getattr(l, "ndim", jnp.ndim(l)) > 0 else P(), tree)


def pp_shardings(mesh, params, opt_state=None):
    """NamedShardings for the PP layout: blocks stage-sharded on ``model``,
    embed replicated, scalar opt-state leaves replicated."""
    stage = NamedSharding(mesh, P("model"))
    rep = NamedSharding(mesh, P())

    def named(tree, sh):
        return jax.tree.map(
            lambda l: sh if getattr(l, "ndim", jnp.ndim(l)) > 0 else rep,
            tree)

    psh = {"blocks": named(params["blocks"], stage),
           "embed": named(params["embed"], rep)}
    if opt_state is None:
        return psh
    osh = (named(opt_state[0], stage), named(opt_state[1], rep))
    return psh, osh


def init_pp(key, cfg, optimizer):
    """Initialize dense params split into the PP layout + its opt state."""
    p = lm.init_params(key, cfg)
    p = jax.tree.map(lambda x: x.astype(cfg.param_dtype)
                     if x.dtype == jnp.float32 else x, p)
    params = {"blocks": p["blocks"], "embed": p["embed"]}
    opt_state = (optimizer.init(params["blocks"]),
                 optimizer.init(params["embed"]))
    return params, opt_state
