"""HLO-text analysis: collective-traffic accounting for the roofline.

``compiled.cost_analysis()`` reports FLOPs and bytes but *not* collective
traffic, so we parse the compiled HLO: every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute op's result shapes are
summed (result bytes are the standard per-device traffic proxy; all-reduce
gets a 2x wire factor for its reduce+broadcast ring phases; reduce-scatter
results are scaled by the replica-group size back to operand bytes, since
the wire moves the full reduced tensor, not the output shard -- see
EXPERIMENTS.md SRoofline for the exact accounting).
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  "bf16[16,512,4096]{2,1,0}"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = (.*?) (" + "|".join(
        c.replace("-", r"\-") + r"(?:-start|-done)?" for c in COLLECTIVES)
    + r")\(",)

WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

# replica_groups=[4,8]<=[32]...  (iota form: [n_groups, group_size]) or the
# explicit {{0,1,...},{...}} list form.
_RG_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_RG_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str) -> int:
    m = _RG_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _RG_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _shape_bytes(type_str: str, f32_elem_bytes: int = 4) -> int:
    """Sum tensor bytes in an HLO type string.

    ``f32_elem_bytes=2`` counts f32 tensors at bf16 width: the CPU host
    backend's float-normalization pass upcasts bf16 compute to f32 *before*
    SPMD collective insertion, so a CPU-compiled HLO reports 4 B/elem wire
    traffic for tensors that are bf16 in the program and would be bf16 on
    the TPU target.  The dry-run records both raw and corrected numbers.
    """
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        nb = f32_elem_bytes if dtype == "f32" else _DTYPE_BYTES[dtype]
        total += n * nb
    return total


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(
    r"=.*while\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(
    r"(?:to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _parse_computations(hlo_text: str):
    """Split HLO text into named computations; return {name: [lines]}."""
    comps: Dict[str, list] = {}
    current = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            current = m.group(1)
            comps[current] = []
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)
    return comps


def _trip_count(cond_lines) -> int:
    """Trip count from a while condition: the s32 limit constant."""
    consts = [int(m.group(1)) for ln in cond_lines
              for m in _CONST_RE.finditer(ln)]
    return max(consts) if consts else 1


def computation_multipliers(hlo_text: str) -> Dict[str, float]:
    """Execution count per computation, following while trip counts.

    XLA prints each while body ONCE; anything inside it actually runs
    trip-count times (nested scans multiply).  We walk the call graph from
    ENTRY: while bodies inherit caller_mult * trip, plain calls/fusions
    inherit caller_mult.  Conservative: unknown structures default to 1x.
    """
    comps = _parse_computations(hlo_text)
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))
    mult: Dict[str, float] = {}

    def visit(name: str, m: float, depth=0):
        if depth > 50 or name not in comps:
            return
        mult[name] = max(mult.get(name, 0.0), m)
        for line in comps[name]:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.groups()
                trips = _trip_count(comps.get(cond, []))
                visit(body, m * trips, depth + 1)
                visit(cond, m * (trips + 1), depth + 1)
                continue
            for cm in _CALL_RE.finditer(line):
                visit(cm.group(1), m, depth + 1)

    if entry:
        visit(entry, 1.0)
    return mult


def collective_stats(hlo_text: str, *, scale_loops: bool = True,
                     f32_elem_bytes: int = 4) -> Dict[str, float]:
    """Per-device collective bytes by op type (+ 'total_wire_bytes').

    With scale_loops=True (default), collectives inside while bodies are
    multiplied by the loop trip count (XLA prints scan bodies once).
    ``f32_elem_bytes=2`` applies the CPU-host bf16->f32 normalization
    correction (see _shape_bytes).
    """
    mult = computation_multipliers(hlo_text) if scale_loops else {}
    comps = _parse_computations(hlo_text)
    out: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    for comp_name, lines in comps.items():
        m = mult.get(comp_name, 1.0) if scale_loops else 1.0
        if m == 0.0:
            m = 1.0
        for line in lines:
            om = _OP_RE.match(line)
            if not om:
                continue
            type_str, op = om.groups()
            base = op.replace("-start", "").replace("-done", "")
            if op.endswith("-done"):
                continue  # avoid double counting async pairs
            b = _shape_bytes(type_str, f32_elem_bytes)
            if base == "reduce-scatter":
                # Result is the post-scatter SHARD; wire traffic is the
                # (n-1)/n of the full reduced operand ~= result * n.  A
                # result-bytes proxy would under-count by the group size.
                b *= _group_size(line)
            out[base] += b * m
            counts[base] += 1
    out["total_bytes"] = sum(out[c] for c in COLLECTIVES)
    out["total_wire_bytes"] = sum(out[c] * WIRE_FACTOR[c]
                                  for c in COLLECTIVES)
    for c in COLLECTIVES:
        out[f"n_{c}"] = counts[c]
    return out


# TPU v5e hardware constants (the roofline denominators).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # B/s per chip
ICI_BW = 50e9                     # B/s per link (~per chip, 1 axis)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   wire_bytes_per_device: float) -> Dict[str, float]:
    """The three roofline times (seconds) for one step on one chip."""
    t_compute = flops_per_device / PEAK_FLOPS_BF16
    t_memory = bytes_per_device / HBM_BW
    t_collective = wire_bytes_per_device / ICI_BW
    terms = {"t_compute": t_compute, "t_memory": t_memory,
             "t_collective": t_collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom
    total = max(terms["t_compute"], terms["t_memory"], terms["t_collective"])
    terms["bound_seconds"] = total
    terms["compute_fraction"] = t_compute / total if total else 0.0
    return terms
