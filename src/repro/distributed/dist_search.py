"""Distributed ConfuciuX search: the paper's algorithm at pod scale.

Three shard_map building blocks (DESIGN.md S3/S6):

  * episode-parallel REINFORCE -- every device runs E_local episodes with a
    device-folded RNG and computes a local policy gradient; gradients are
    psum'd (synchronous data-parallel RL).  Params stay replicated, so
    scaling from 1 device to 512 chips changes only the reduction tree.
  * int8-compressed gradient reduction -- across the ``pod`` axis (the slow
    inter-pod links) gradients are quantized to int8 with a per-leaf scale,
    psum'd in int32, and dequantized.  In-pod reduction stays f32.
  * straggler masking -- each shard carries a validity flag; dead/slow
    shards contribute zero gradient and the reduction renormalizes by the
    live count (drop-slowest semantics).  tests/test_distributed.py checks
    the search still converges with a masked shard.

Island-model GA: each device evolves its own subpopulation and the best
genomes are exchanged (all_gather) every ``exchange_every`` generations.

Unified-API wrappers (registered in the ``repro.api`` optimizer registry):

  * ``fanout``         -- seed-parallel fan-out of ANY registered optimizer:
    n shards run the inner method with distinct seeds and the results are
    merged (best value wins; the trace is the elementwise min, i.e. the
    wall-clock view of the parallel ensemble).
  * ``dist_reinforce`` -- the episode-parallel shard_map REINFORCE above,
    exposed through the same SearchRequest/SearchOutcome schema.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.api import registry as api_registry
from repro.api import types as api_types
from repro.core import env as env_lib
from repro.core import policy as policy_lib
from repro.core import reinforce
from repro.training import optim


# ---------------------------------------------------------------------------
# Compressed / masked reductions.
# ---------------------------------------------------------------------------
def psum_int8(tree, axis_name: str):
    """Quantized all-reduce: int8 per-leaf symmetric quantization.

    Wire cost is ~4x lower than f32 psum; the quantization error is bounded
    by scale/2 per element (tested).  Scales are reduced with a max so every
    participant dequantizes identically.
    """
    def reduce_leaf(x):
        scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, axis_name)
        return total.astype(jnp.float32) * scale

    return jax.tree.map(reduce_leaf, tree)


def masked_psum(tree, alive, axis_name: str):
    """Straggler-tolerant mean-reduction: dead shards contribute nothing."""
    n_alive = jnp.maximum(jax.lax.psum(alive.astype(jnp.float32),
                                       axis_name), 1.0)
    return jax.tree.map(
        lambda x: jax.lax.psum(x * alive.astype(x.dtype), axis_name)
        / n_alive, tree)


# ---------------------------------------------------------------------------
# Episode-parallel REINFORCE.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DistConfig:
    episodes_per_device: int = 4
    compress_pod_axis: bool = False   # int8 reduction across 'pod'
    seed: int = 0


def make_distributed_epoch(ecfg: env_lib.EnvConfig,
                           pcfg: policy_lib.PolicyConfig,
                           rcfg: reinforce.ReinforceConfig,
                           env: env_lib.EnvArrays,
                           opt: optim.Adam, mesh,
                           dcfg: DistConfig = DistConfig()):
    """Build the shard_map'd epoch: all mesh axes run episodes in parallel."""
    rollout = reinforce.make_rollout(ecfg, pcfg, env, rcfg.discount)
    axes = tuple(mesh.axis_names)
    E = dcfg.episodes_per_device

    def local_loss(params, pmin, keys):
        rolls = jax.vmap(lambda k: rollout(params, pmin, k))(keys)
        G = jax.vmap(lambda r: reinforce._discounted_returns(
            r, rcfg.discount))(rolls.rewards * rolls.mask)
        n_valid = jnp.maximum(rolls.mask.sum(axis=1), 1.0)
        mean = (G * rolls.mask).sum(axis=1) / n_valid
        var = (jnp.square(G - mean[:, None]) * rolls.mask).sum(1) / n_valid
        G_std = (G - mean[:, None]) / (jnp.sqrt(var)[:, None] + 1e-8)
        pg = -(rolls.logps * jax.lax.stop_gradient(G_std)
               * rolls.mask).sum(axis=1)
        return jnp.mean(pg), rolls

    def epoch_shard(state: reinforce.SearchState, alive):
        alive = alive[0]  # (1,) local shard of the per-device flag vector
        # Per-device RNG: fold in every mesh axis index.
        key = state.key
        for ax in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(ax))
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, E)
        (_, rolls), grads = jax.value_and_grad(
            local_loss, has_aux=True)(state.params, state.pmin, keys)

        # Hierarchical reduction: f32 within the pod, optionally int8 across.
        inpod = tuple(a for a in axes if a != "pod")
        if "pod" in axes and dcfg.compress_pod_axis:
            grads = masked_psum(grads, alive, inpod)
            grads = jax.tree.map(lambda g: g / len(inpod or (1,)), grads)
            grads = psum_int8(grads, "pod")
            npods = 2
            grads = jax.tree.map(lambda g: g / npods, grads)
        else:
            grads = masked_psum(grads, alive, axes)

        params, opt_state = opt.update(grads, state.opt_state, state.params)
        pmin = jax.lax.pmin(jnp.min(rolls.pmin), axes)

        values = jnp.where(rolls.feasible, rolls.model_value, jnp.inf)
        i = jnp.argmin(values)
        local_best = values[i]
        # Global argmin across devices.
        all_best = jax.lax.all_gather(local_best, axes, tiled=False)
        all_pe = jax.lax.all_gather(rolls.actions[i, :, 0], axes)
        all_kt = jax.lax.all_gather(rolls.actions[i, :, 1], axes)
        all_df = jax.lax.all_gather(rolls.actions[i, :, 2], axes)
        flat_best = all_best.reshape(-1)
        j = jnp.argmin(flat_best)
        better = flat_best[j] < state.best_value
        pick = lambda new, old: jnp.where(better, new, old)
        new_state = reinforce.SearchState(
            params=params, opt_state=opt_state, pmin=pmin,
            best_value=jnp.where(better, flat_best[j], state.best_value),
            best_pe_lvl=pick(all_pe.reshape(-1, all_pe.shape[-1])[j],
                             state.best_pe_lvl),
            best_kt_lvl=pick(all_kt.reshape(-1, all_kt.shape[-1])[j],
                             state.best_kt_lvl),
            best_df=pick(all_df.reshape(-1, all_df.shape[-1])[j],
                         state.best_df),
            key=state.key, epoch=state.epoch + 1)
        # Advance the replicated key identically on all shards.
        new_state = new_state._replace(
            key=jax.random.fold_in(state.key, state.epoch + 1))
        metrics = {
            "best_value": new_state.best_value,
            "feasible_frac": jax.lax.pmean(
                jnp.mean(rolls.feasible.astype(jnp.float32)), axes),
        }
        return new_state, metrics

    rep = P()
    fn = shard_map(
        epoch_shard, mesh=mesh,
        in_specs=(rep, P(axes)),   # alive: one flag per device
        out_specs=(rep, rep),
        check_rep=False)
    return fn


def run_distributed_search(workload, ecfg: env_lib.EnvConfig, mesh,
                           rcfg: reinforce.ReinforceConfig,
                           dcfg: DistConfig = DistConfig(),
                           pcfg: Optional[policy_lib.PolicyConfig] = None,
                           straggler_mask=None):
    """Full distributed stage-1 search on a mesh.

    straggler_mask: optional bool array of shape (n_devices,) -- False marks
    a simulated dead/slow shard whose contribution is dropped.
    """
    env = env_lib.make_env(workload, ecfg)
    if pcfg is None:
        pcfg = policy_lib.PolicyConfig(obs_dim=ecfg.obs_dim, mix=ecfg.mix,
                                       levels=ecfg.levels)
    opt = optim.Adam(lr=rcfg.lr)
    state = reinforce.init_search(env, ecfg, pcfg, rcfg, opt)
    epoch_fn = make_distributed_epoch(ecfg, pcfg, rcfg, env, opt, mesh, dcfg)

    n_dev = int(np.prod(list(mesh.shape.values())))
    if straggler_mask is None:
        straggler_mask = np.ones((n_dev,), bool)
    alive = jax.device_put(
        jnp.asarray(straggler_mask),
        jax.sharding.NamedSharding(mesh, P(tuple(mesh.axis_names))))

    @jax.jit
    def one_epoch(state):
        return epoch_fn(state, alive)

    history = {"best_value": [], "feasible_frac": []}
    for _ in range(rcfg.epochs):
        state, metrics = one_epoch(state)
        for k in history:
            history[k].append(float(metrics[k]))
    history = {k: np.asarray(v) for k, v in history.items()}
    return state, history


# ---------------------------------------------------------------------------
# Unified-API wrappers.
# ---------------------------------------------------------------------------
@api_registry.register("fanout")
class FanoutOptimizer:
    """Seed-parallel fan-out of any registered optimizer.

    options: ``inner`` (registry name, default "reinforce"), ``n_shards``
    (default 4), ``inner_options`` (passed to each shard).  Each shard keeps
    the full ``eps`` budget -- this models n workers searching in parallel,
    so the merged trace is the wall-clock best-so-far of the ensemble and
    total samples are ``n_shards * eps`` (reported in extras).  On a real
    deployment each shard maps to one host/device; here they run in turn.
    """

    name = "fanout"

    def run(self, request: api_types.SearchRequest) -> api_types.SearchOutcome:
        t0 = time.time()
        opts = request.options
        inner = opts.get("inner", "reinforce")
        n_shards = int(opts.get("n_shards", 4))
        inner_opts = dict(opts.get("inner_options", {}))
        if isinstance(api_registry.get_optimizer(inner), FanoutOptimizer):
            raise ValueError("fanout cannot nest itself as the inner method")
        shards = []
        for s in range(n_shards):
            sub = dataclasses.replace(
                request, method=inner, options=inner_opts,
                seed=request.seed + s, on_progress=None)
            shards.append(api_registry.get_optimizer(inner).run(sub))
        best = min(shards, key=lambda o: o.best_value)
        trace = np.min(np.stack([o.history for o in shards]), axis=0)
        return api_types.build_outcome(
            request, self.name, best.best_value, best.pe, best.kt, best.df,
            trace, t0,
            extras={"inner": inner, "n_shards": n_shards,
                    "total_samples": n_shards * request.eps,
                    "shard_best_values": [o.best_value for o in shards],
                    "best_seed": best.seed})


@api_registry.register("dist_reinforce")
class DistributedReinforceOptimizer:
    """Episode-parallel REINFORCE across every device of a mesh.

    options: ``mesh`` (a jax Mesh; default: one axis over all local devices),
    ``episodes_per_device``, ``compress_pod_axis``, ``straggler_mask``,
    ``lr``.  One epoch consumes ``episodes_per_device * n_devices`` samples.
    """

    name = "dist_reinforce"

    def run(self, request: api_types.SearchRequest) -> api_types.SearchOutcome:
        t0 = time.time()
        opts = request.options
        mesh = opts.get("mesh")
        if mesh is None:
            mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        n_dev = int(np.prod(list(mesh.shape.values())))
        E = int(opts.get("episodes_per_device", 1))
        per_epoch = max(E * n_dev, 1)
        rcfg = reinforce.ReinforceConfig(
            epochs=max(request.eps // per_epoch, 1),
            lr=opts.get("lr", 3e-3), seed=request.seed)
        dcfg = DistConfig(
            episodes_per_device=E,
            compress_pod_axis=bool(opts.get("compress_pod_axis", False)),
            seed=request.seed)
        wl = request.resolve_workload()
        state, hist = run_distributed_search(
            wl, request.env, mesh, rcfg, dcfg,
            straggler_mask=opts.get("straggler_mask"))
        env = env_lib.make_env(wl, request.env)
        pe, kt, df = reinforce.solution_arrays(state, env)
        trace = api_types.expand_trace(hist["best_value"], per_epoch)
        return api_types.build_outcome(
            request, self.name, state.best_value, np.asarray(pe),
            np.asarray(kt), np.asarray(df), trace, t0,
            extras={"epochs": rcfg.epochs, "devices": n_dev,
                    "history": hist})
