"""Distributed ConfuciuX search: the paper's algorithm at pod scale.

Three shard_map building blocks (DESIGN.md S3/S6):

  * episode-parallel REINFORCE -- every device runs E_local episodes with a
    device-folded RNG and computes a local policy gradient; gradients are
    psum'd (synchronous data-parallel RL).  Params stay replicated, so
    scaling from 1 device to 512 chips changes only the reduction tree.
  * int8-compressed gradient reduction -- across the ``pod`` axis (the slow
    inter-pod links) gradients are quantized to int8 with a per-leaf scale,
    psum'd in int32, and dequantized.  In-pod reduction stays f32.
  * straggler masking -- each shard carries a validity flag; dead/slow
    shards contribute zero gradient and the reduction renormalizes by the
    live count (drop-slowest semantics).  tests/test_distributed.py checks
    the search still converges with a masked shard.

Island-model GA: each device evolves its own subpopulation and the best
genomes are exchanged (all_gather) every ``exchange_every`` generations.

Unified-API wrappers (registered in the ``repro.api`` optimizer registry):

  * ``fanout``         -- seed-parallel fan-out of ANY registered optimizer:
    n shards run the inner method with distinct seeds and the results are
    merged (best value wins; the trace is the elementwise min, i.e. the
    wall-clock view of the parallel ensemble).  Three execution backends:
    ``device`` (one shard per local device, the whole fleet in one
    shard_map'd XLA program), ``threads`` (one host worker per shard), and
    ``serial`` (the debugging loop); all three produce identical outcomes,
    and live progress streams merged + shard-tagged through the unified API.
  * ``dist_reinforce`` -- the episode-parallel shard_map REINFORCE above,
    exposed through the same SearchRequest/SearchOutcome schema.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.api import registry as api_registry
from repro.api import types as api_types
from repro.core import chunk as chunk_lib
from repro.core import env as env_lib
from repro.core import ga as ga_lib
from repro.core import policy as policy_lib
from repro.core import reinforce
from repro.training import optim


# ---------------------------------------------------------------------------
# Compressed / masked reductions.
# ---------------------------------------------------------------------------
def psum_int8(tree, axis_name: str):
    """Quantized all-reduce: int8 per-leaf symmetric quantization.

    Wire cost is ~4x lower than f32 psum; the quantization error is bounded
    by scale/2 per element (tested).  Scales are reduced with a max so every
    participant dequantizes identically.
    """
    def reduce_leaf(x):
        scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, axis_name)
        return total.astype(jnp.float32) * scale

    return jax.tree.map(reduce_leaf, tree)


def masked_psum(tree, alive, axis_name: str):
    """Straggler-tolerant mean-reduction: dead shards contribute nothing."""
    n_alive = jnp.maximum(jax.lax.psum(alive.astype(jnp.float32),
                                       axis_name), 1.0)
    return jax.tree.map(
        lambda x: jax.lax.psum(x * alive.astype(x.dtype), axis_name)
        / n_alive, tree)


def masked_hierarchical_psum(tree, alive, axes, pod_axis: str = "pod",
                             compress: bool = False):
    """Masked global mean with an optionally compressed cross-pod hop.

    Semantics match :func:`masked_psum` over all ``axes``: the sum of the
    alive shards' leaves divided by the global alive-device count.  With
    ``compress`` the reduction is hierarchical -- exact f32 sums within each
    pod (fast links), then one int8-quantized psum across ``pod_axis`` (slow
    inter-pod links) for both the leaf sums and the alive counts' exact f32
    psum.  Normalizing by the true global alive count (instead of averaging
    per-pod means) keeps the result equal to the flat masked_psum, up to
    int8 quantization error, even when pods have different live counts.
    """
    if pod_axis not in axes or not compress:
        return masked_psum(tree, alive, axes)
    inpod = tuple(a for a in axes if a != pod_axis)
    af = alive.astype(jnp.float32)
    gsum = jax.tree.map(lambda x: x * af.astype(x.dtype), tree)
    n_local = af
    if inpod:
        gsum = jax.tree.map(lambda x: jax.lax.psum(x, inpod), gsum)
        n_local = jax.lax.psum(af, inpod)
    gsum = psum_int8(gsum, pod_axis)
    n_alive = jnp.maximum(jax.lax.psum(n_local, pod_axis), 1.0)
    return jax.tree.map(lambda g: g / n_alive, gsum)


# ---------------------------------------------------------------------------
# Episode-parallel REINFORCE.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DistConfig:
    episodes_per_device: int = 4
    compress_pod_axis: bool = False   # int8 reduction across 'pod'
    seed: int = 0


def make_distributed_epoch(ecfg: env_lib.EnvConfig,
                           pcfg: policy_lib.PolicyConfig,
                           rcfg: reinforce.ReinforceConfig,
                           env: env_lib.EnvArrays,
                           opt: optim.Adam, mesh,
                           dcfg: DistConfig = DistConfig()):
    """Build the shard_map'd epoch: all mesh axes run episodes in parallel."""
    rollout = reinforce.make_rollout(ecfg, pcfg, env, rcfg.discount)
    axes = tuple(mesh.axis_names)
    E = dcfg.episodes_per_device

    def local_loss(params, pmin, keys):
        rolls = jax.vmap(lambda k: rollout(params, pmin, k))(keys)
        G = jax.vmap(lambda r: reinforce._discounted_returns(
            r, rcfg.discount))(rolls.rewards * rolls.mask)
        n_valid = jnp.maximum(rolls.mask.sum(axis=1), 1.0)
        mean = (G * rolls.mask).sum(axis=1) / n_valid
        var = (jnp.square(G - mean[:, None]) * rolls.mask).sum(1) / n_valid
        G_std = (G - mean[:, None]) / (jnp.sqrt(var)[:, None] + 1e-8)
        pg = -(rolls.logps * jax.lax.stop_gradient(G_std)
               * rolls.mask).sum(axis=1)
        return jnp.mean(pg), rolls

    def epoch_shard(state: reinforce.SearchState, alive):
        alive = alive[0]  # (1,) local shard of the per-device flag vector
        # Per-device RNG: fold in every mesh axis index.
        key = state.key
        for ax in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(ax))
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, E)
        (_, rolls), grads = jax.value_and_grad(
            local_loss, has_aux=True)(state.params, state.pmin, keys)

        # Hierarchical reduction: f32 within the pod, optionally int8 across.
        grads = masked_hierarchical_psum(grads, alive, axes,
                                         compress=dcfg.compress_pod_axis)

        params, opt_state = opt.update(grads, state.opt_state, state.params)
        pmin = jax.lax.pmin(jnp.min(rolls.pmin), axes)

        values = jnp.where(rolls.feasible, rolls.model_value, jnp.inf)
        i = jnp.argmin(values)
        local_best = values[i]
        # Global argmin across devices.
        all_best = jax.lax.all_gather(local_best, axes, tiled=False)
        all_pe = jax.lax.all_gather(rolls.actions[i, :, 0], axes)
        all_kt = jax.lax.all_gather(rolls.actions[i, :, 1], axes)
        all_df = jax.lax.all_gather(rolls.actions[i, :, 2], axes)
        flat_best = all_best.reshape(-1)
        j = jnp.argmin(flat_best)
        better = flat_best[j] < state.best_value
        pick = lambda new, old: jnp.where(better, new, old)
        new_state = reinforce.SearchState(
            params=params, opt_state=opt_state, pmin=pmin,
            best_value=jnp.where(better, flat_best[j], state.best_value),
            best_pe_lvl=pick(all_pe.reshape(-1, all_pe.shape[-1])[j],
                             state.best_pe_lvl),
            best_kt_lvl=pick(all_kt.reshape(-1, all_kt.shape[-1])[j],
                             state.best_kt_lvl),
            best_df=pick(all_df.reshape(-1, all_df.shape[-1])[j],
                         state.best_df),
            key=state.key, epoch=state.epoch + 1)
        # Advance the replicated key identically on all shards.
        new_state = new_state._replace(
            key=jax.random.fold_in(state.key, state.epoch + 1))
        metrics = {
            "best_value": new_state.best_value,
            "feasible_frac": jax.lax.pmean(
                jnp.mean(rolls.feasible.astype(jnp.float32)), axes),
        }
        return new_state, metrics

    rep = P()
    fn = shard_map(
        epoch_shard, mesh=mesh,
        in_specs=(rep, P(axes)),   # alive: one flag per device
        out_specs=(rep, rep),
        check_rep=False)
    return fn


def run_distributed_search(workload, ecfg: env_lib.EnvConfig, mesh,
                           rcfg: reinforce.ReinforceConfig,
                           dcfg: DistConfig = DistConfig(),
                           pcfg: Optional[policy_lib.PolicyConfig] = None,
                           straggler_mask=None):
    """Full distributed stage-1 search on a mesh.

    straggler_mask: optional bool array of shape (n_devices,) -- False marks
    a simulated dead/slow shard whose contribution is dropped.
    """
    env = env_lib.make_env(workload, ecfg)
    if pcfg is None:
        pcfg = policy_lib.PolicyConfig(obs_dim=ecfg.obs_dim, mix=ecfg.mix,
                                       levels=ecfg.levels)
    opt = optim.Adam(lr=rcfg.lr)
    state = reinforce.init_search(env, ecfg, pcfg, rcfg, opt)
    epoch_fn = make_distributed_epoch(ecfg, pcfg, rcfg, env, opt, mesh, dcfg)

    n_dev = int(np.prod(list(mesh.shape.values())))
    if straggler_mask is None:
        straggler_mask = np.ones((n_dev,), bool)
    alive = jax.device_put(
        jnp.asarray(straggler_mask),
        jax.sharding.NamedSharding(mesh, P(tuple(mesh.axis_names))))

    @jax.jit
    def one_epoch(state):
        return epoch_fn(state, alive)

    def run_epochs(state, n):
        vals = {"best_value": [], "feasible_frac": []}
        for _ in range(n):
            state, metrics = one_epoch(state)
            for k in vals:
                vals[k].append(float(metrics[k]))
        return state, vals

    # One chunk (chunk=0 -> full budget): nothing happens between epochs
    # here, drive() only adds the span/metrics accounting.
    state, chunks = chunk_lib.drive(
        state, rcfg.epochs, 0, run_epochs, lambda *a: None,
        engine="dist_reinforce",
        evals_per_step=dcfg.episodes_per_device * n_dev)
    history = {k: np.asarray([v for h in chunks for v in h[k]])
               for k in chunks[0]}
    return state, history


# ---------------------------------------------------------------------------
# Fanout execution backends.
# ---------------------------------------------------------------------------
# Inner methods whose whole search is one JAX program, so n seeds can run as
# one shard_map'd XLA computation over n local devices (bit-identical to the
# serial loop: each device executes exactly the single-shard program).
DEVICE_INNERS = ("reinforce", "ga")
FANOUT_BACKENDS = ("auto", "device", "threads", "serial")


class _MergedProgress:
    """Thread-safe merge of per-shard progress into one tagged stream.

    Each shard's Trials are re-emitted with ``shard=s`` and the *ensemble*
    best-so-far (min over everything any shard has reported).  ``step`` is
    the shard-local sample index, so every shard's sub-stream stays monotone;
    how the sub-streams interleave depends on the backend's scheduling.
    """

    def __init__(self, cb: Optional[api_types.ProgressFn], n_shards: int):
        self._cb = cb
        self._lock = threading.Lock()
        self._best = [float("inf")] * n_shards

    def shard_cb(self, s: int) -> Optional[api_types.ProgressFn]:
        if self._cb is None:
            return None

        def cb(trial: api_types.Trial) -> None:
            with self._lock:
                self._best[s] = min(self._best[s], trial.best_value)
                ensemble = min(self._best)
                self._cb(api_types.Trial(trial.step, trial.value,
                                         ensemble, shard=s))

        return cb


def _shard_mesh(n_shards: int):
    return jax.make_mesh((n_shards,), ("shard",))


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _fanout_reinforce_device(subs) -> list:
    """All shards' REINFORCE searches as one shard_map'd program.

    Every device runs the exact single-shard epoch scan (the per-shard block
    is squeezed to the serial shapes), so shard s's outcome is bit-identical
    to ``get_optimizer("reinforce").run(subs[s])`` -- only the wall-clock
    changes: one XLA compile for the whole fleet and all devices stepping
    concurrently.
    """
    from repro.api import optimizers as api_optimizers

    req0 = subs[0]
    n_shards = len(subs)
    wl = req0.resolve_workload()
    ecfg = req0.env
    env = env_lib.make_env(wl, ecfg)
    pcfg = api_optimizers._policy_config(ecfg, req0.options)
    rcfgs = [api_optimizers._reinforce_cfg(sub)[0] for sub in subs]
    E = rcfgs[0].episodes_per_epoch
    epochs = rcfgs[0].epochs
    opt = optim.Adam(lr=rcfgs[0].lr)
    epoch_fn = reinforce.make_epoch_fn(ecfg, pcfg, rcfgs[0], env, opt)
    stacked = _stack_trees(
        [reinforce.init_search(env, ecfg, pcfg, rcfg, opt)
         for rcfg in rcfgs])
    mesh = _shard_mesh(n_shards)
    P_s = P("shard")

    @functools.partial(jax.jit, static_argnames=("n",))
    def run_chunk(stacked, n):
        def body(state):
            state = jax.tree.map(lambda x: jnp.squeeze(x, 0), state)
            state2, metrics = jax.lax.scan(epoch_fn, state, None, length=n)
            return (jax.tree.map(lambda x: x[None], state2),
                    jax.tree.map(lambda x: x[None], metrics))

        return shard_map(body, mesh=mesh, in_specs=(P_s,),
                         out_specs=(P_s, P_s), check_rep=False)(stacked)

    streaming = req0.on_progress is not None
    # Not streaming -> nothing happens between chunks, so run the whole
    # epoch budget as ONE static scan length (a tail chunk of a different
    # length would trigger a second fleet-wide compile).
    chunk = max(req0.progress_every // E, 1) if streaming else epochs
    t0 = time.time()

    def drive_chunk(stacked, n):
        stacked, metrics = run_chunk(stacked, n)
        # (n_shards, n) leaves
        return stacked, jax.tree.map(jax.device_get, metrics)

    def on_chunk(stacked, h, done):
        if not streaming:
            return
        best_now = np.asarray(stacked.best_value)
        for s, sub in enumerate(subs):
            sub.on_progress(api_types.Trial(
                min(done * E, sub.eps),
                float(np.min(h["best_value"][s])),
                float(best_now[s])))

    stacked, chunks = chunk_lib.drive(
        stacked, epochs, chunk, drive_chunk, on_chunk,
        engine="dist_reinforce", evals_per_step=E * n_shards)
    hist = {k: np.concatenate([h[k] for h in chunks], axis=1)
            for k in chunks[0]}

    outcomes = []
    for s, sub in enumerate(subs):
        state_s = jax.tree.map(lambda x: x[s], stacked)
        pe, kt, df = reinforce.solution_arrays(state_s, env)
        trace = api_types.expand_trace(hist["best_value"][s], E)
        outcomes.append(api_types.build_outcome(
            sub, "reinforce", float(state_s.best_value), np.asarray(pe),
            np.asarray(kt), np.asarray(df), trace, t0,
            extras={"epochs": epochs,
                    "history": {k: v[s] for k, v in hist.items()}},
            streamed=streaming))
    return outcomes


def _fanout_ga_device(subs) -> list:
    """All shards' GA runs as one shard_map'd generation scan.

    Per-shard carries differ only in their seed; the generation step is
    shared, so one compile drives every island.  The fitness hot-spot goes
    through :func:`repro.core.ga._fitness`, which dispatches the Pallas
    batched cost kernel on TPU (``GAConfig.use_kernel``).
    """
    from repro.api import optimizers as api_optimizers

    req0 = subs[0]
    n_shards = len(subs)
    wl = req0.resolve_workload()
    ecfg = req0.env
    env = env_lib.make_env(wl, ecfg)
    cfg = api_optimizers._ga_cfg(req0)
    pop, gens = cfg.population, cfg.generations
    engine = ga_lib.make_ga_engine(env, ecfg, cfg)
    stacked = _stack_trees([engine.init_carry(sub.seed) for sub in subs])
    mesh = _shard_mesh(n_shards)
    P_s = P("shard")

    @jax.jit
    def run_all(stacked):
        def body(carry):
            carry = jax.tree.map(lambda x: jnp.squeeze(x, 0), carry)
            carry2, hist = jax.lax.scan(engine.gen_step, carry, None,
                                        length=gens)
            return jax.tree.map(lambda x: x[None], carry2), hist[None]

        return shard_map(body, mesh=mesh, in_specs=(P_s,),
                         out_specs=(P_s, P_s), check_rep=False)(stacked)

    t0 = time.time()
    final, hist = run_all(stacked)
    best_vals = np.asarray(final.best_val)
    best_genomes = final.best_genome
    hist = np.asarray(hist)

    outcomes = []
    for s, sub in enumerate(subs):
        pe, kt, df = engine.decode(best_genomes[s])
        df = jnp.broadcast_to(df, (env.num_layers,))
        trace = api_types.expand_trace(hist[s], pop)
        outcomes.append(api_types.build_outcome(
            sub, "ga", float(best_vals[s]), np.asarray(pe), np.asarray(kt),
            np.asarray(df), trace, t0,
            extras={"generations": gens, "population": pop}))
    return outcomes


_DEVICE_ENGINES = {"reinforce": _fanout_reinforce_device,
                   "ga": _fanout_ga_device}


# ---------------------------------------------------------------------------
# Unified-API wrappers.
# ---------------------------------------------------------------------------
@api_registry.register("fanout")
class FanoutOptimizer:
    """Seed-parallel fan-out of any registered optimizer.

    options:
      ``inner``          registry name of the inner method (default
                         "reinforce")
      ``n_shards``       number of parallel searches (default 4)
      ``inner_options``  options dict passed to every shard
      ``backend``        "auto" | "device" | "threads" | "serial":

        * ``device``  -- one shard per local JAX device; every shard's whole
          search fuses into one shard_map'd XLA program (JAX-native inners
          only: reinforce, ga).  One compile for the fleet, all devices
          stepping concurrently, bit-identical results to ``serial``.
        * ``threads`` -- one host thread per shard running the inner
          optimizer unchanged (works for any inner; XLA releases the GIL
          during compilation and execution, so non-JAX engines like sa/bo/
          grid/random overlap too).
        * ``serial``  -- the in-process for-loop (debugging, 1-core hosts).
        * ``auto``    -- device when the inner supports it and enough local
          devices exist, else threads.

    Each shard keeps the full ``eps`` budget -- this models n workers
    searching in parallel, so the merged trace is the wall-clock best-so-far
    of the ensemble and total samples are ``n_shards * eps`` (reported in
    extras).  Shards are merged in shard-index order, so every backend
    returns identical outcomes for the same seeds.

    Progress streams through ``request.on_progress`` as shard-tagged Trials
    (``Trial.shard``) whose ``best_value`` is the ensemble best-so-far; each
    shard's sub-stream is monotone in ``step``, while the interleaving
    across shards follows the backend's scheduling.
    """

    name = "fanout"

    def run(self, request: api_types.SearchRequest) -> api_types.SearchOutcome:
        t0 = time.time()
        opts = request.options
        inner = opts.get("inner", "reinforce")
        n_shards = int(opts.get("n_shards", 4))
        inner_opts = dict(opts.get("inner_options", {}))
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        inner_impl = api_registry.get_optimizer(inner)
        if isinstance(inner_impl, FanoutOptimizer):
            raise ValueError("fanout cannot nest itself as the inner method")
        backend = _resolve_backend(opts.get("backend", "auto"),
                                   inner_impl.name, n_shards)
        merger = _MergedProgress(request.on_progress, n_shards)
        subs = [dataclasses.replace(
                    request, method=inner_impl.name, options=inner_opts,
                    seed=request.seed + s, on_progress=merger.shard_cb(s))
                for s in range(n_shards)]

        # Each shard gets a fresh optimizer instance so stateful custom
        # optimizers never share one object across concurrent threads.
        if backend == "device":
            shards = _DEVICE_ENGINES[inner_impl.name](subs)
        elif backend == "threads":
            with ThreadPoolExecutor(max_workers=n_shards) as pool:
                futures = [pool.submit(api_registry.get_optimizer(inner).run,
                                       sub) for sub in subs]
                shards = [f.result() for f in futures]
        else:
            shards = [api_registry.get_optimizer(inner).run(sub)
                      for sub in subs]

        best = min(shards, key=lambda o: o.best_value)
        trace = np.min(np.stack([o.history for o in shards]), axis=0)
        return api_types.build_outcome(
            request, self.name, best.best_value, best.pe, best.kt, best.df,
            trace, t0,
            extras={"inner": inner_impl.name, "n_shards": n_shards,
                    "backend": backend,
                    "total_samples": n_shards * request.eps,
                    "shard_best_values": [o.best_value for o in shards],
                    "best_seed": best.seed},
            streamed=request.on_progress is not None)


def _resolve_backend(backend: str, inner_name: str, n_shards: int) -> str:
    n_dev = len(jax.devices())
    if backend == "auto":
        return ("device" if inner_name in DEVICE_INNERS and n_shards <= n_dev
                else "threads")
    if backend == "device":
        if inner_name not in DEVICE_INNERS:
            raise ValueError(
                f"backend='device' supports the JAX-native inner methods "
                f"{DEVICE_INNERS}, not {inner_name!r}; use backend='threads'")
        if n_shards > n_dev:
            raise ValueError(
                f"backend='device' needs >= {n_shards} local devices, have "
                f"{n_dev} (lower n_shards or set the env var "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{n_shards})")
        return backend
    if backend not in FANOUT_BACKENDS:
        raise ValueError(f"unknown fanout backend {backend!r}; expected one "
                         f"of {FANOUT_BACKENDS}")
    return backend


@api_registry.register("dist_reinforce")
class DistributedReinforceOptimizer:
    """Episode-parallel REINFORCE across every device of a mesh.

    options: ``mesh`` (a jax Mesh; default: one axis over all local devices),
    ``episodes_per_device``, ``compress_pod_axis``, ``straggler_mask``,
    ``lr``.  One epoch consumes ``episodes_per_device * n_devices`` samples.
    """

    name = "dist_reinforce"

    def run(self, request: api_types.SearchRequest) -> api_types.SearchOutcome:
        t0 = time.time()
        opts = request.options
        mesh = opts.get("mesh")
        if mesh is None:
            mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        n_dev = int(np.prod(list(mesh.shape.values())))
        E = int(opts.get("episodes_per_device", 1))
        per_epoch = max(E * n_dev, 1)
        rcfg = reinforce.ReinforceConfig(
            epochs=max(request.eps // per_epoch, 1),
            lr=opts.get("lr", 3e-3), seed=request.seed)
        dcfg = DistConfig(
            episodes_per_device=E,
            compress_pod_axis=bool(opts.get("compress_pod_axis", False)),
            seed=request.seed)
        wl = request.resolve_workload()
        state, hist = run_distributed_search(
            wl, request.env, mesh, rcfg, dcfg,
            straggler_mask=opts.get("straggler_mask"))
        env = env_lib.make_env(wl, request.env)
        pe, kt, df = reinforce.solution_arrays(state, env)
        trace = api_types.expand_trace(hist["best_value"], per_epoch)
        return api_types.build_outcome(
            request, self.name, state.best_value, np.asarray(pe),
            np.asarray(kt), np.asarray(df), trace, t0,
            extras={"epochs": rcfg.epochs, "devices": n_dev,
                    "history": hist})
