"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state -- the dry-run sets
``--xla_force_host_platform_device_count=512`` *before* first jax init and
everything else sees the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods.

    Axes: ``data`` carries DP + FSDP, ``model`` carries TP / EP / SP, and
    ``pod`` (multi-pod only) carries pure data parallelism whose gradient
    reduction crosses the inter-pod links -- scaling pods never changes
    layer math (DESIGN.md S6).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many (host) devices exist -- for tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
