import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (architecture x input shape)
cell on the production meshes and extract the roofline raw numbers.

MUST be run as its own process (the two lines above must execute before any
other jax-touching import -- jax locks the device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out results/dryrun.jsonl

Per cell it records: per-device HLO FLOPs + bytes (cost_analysis), peak /
argument / output bytes per device (memory_analysis), per-device collective
bytes by op type (parsed from the compiled HLO), MODEL_FLOPS (6*N_active*D
for train, 2*N_active per decoded token), and the derived three roofline
terms (distributed/hlo_analysis.py).
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from functools import partial  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs.base import InputShape  # noqa: E402
from repro.distributed import analytic, hlo_analysis, sharding  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.training import optim  # noqa: E402


def skip_reason(cfg, shape: InputShape) -> str | None:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.name} is pure full-attention (DESIGN.md "
                "SArch-applicability)")
    return None


def input_specs(arch: str, shape_name: str, mesh, mode: str = "tp"):
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    Weak-type-correct, carries target shardings, allocates nothing.
    """
    cfg = configs.get(arch)
    shape = configs.get_shape(shape_name)
    B, T = shape.global_batch, shape.seq_len
    bs = sharding.batch_sharding(mesh, B, mode=mode)
    tok = jax.ShapeDtypeStruct((B, T), jnp.int32, sharding=bs)
    specs = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = tok
        if shape.kind == "train":
            specs["labels"] = tok
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.compute_dtype),
                sharding=sharding.batch_sharding(mesh, B))
        elif cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_seq, cfg.d_model),
                jnp.dtype(cfg.compute_dtype),
                sharding=sharding.batch_sharding(mesh, B))
    else:  # decode
        specs["token"] = jax.ShapeDtypeStruct(
            (B,), jnp.int32,
            sharding=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()))
        cache = jax.eval_shape(
            lambda: lm.init_cache(cfg, B, T, dtype=cfg.compute_dtype))
        cache_sh = sharding.cache_shardings(mesh, cache, batch=B)
        specs["cache"] = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s)
            if hasattr(l, "shape") else l, cache, cache_sh)
        if cfg.family in ("audio", "vlm"):
            S = cfg.encoder_seq if cfg.family == "audio" else cfg.vision_seq
            sites = (cfg.num_layers if cfg.family == "audio"
                     else cfg.num_layers // cfg.cross_attn_period)
            xkv = jax.ShapeDtypeStruct(
                (sites, B, S, cfg.num_kv_heads, cfg.hd()),
                jnp.dtype(cfg.compute_dtype),
                sharding=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec(None, None, None, None,
                                                     None)))
            specs["cache"] = specs["cache"]._replace(cross_k=xkv,
                                                     cross_v=xkv)
    return specs


def model_flops(cfg, shape: InputShape) -> float:
    """MODEL_FLOPS: 6*N_active*D tokens (train) / 2*N_active*B (decode)."""
    n = cfg.param_count()
    if cfg.num_experts:
        inactive = (cfg.num_layers * (cfg.num_experts - cfg.experts_per_token)
                    * 3 * cfg.d_model * cfg.d_ff)
        n = n - inactive
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token / sequence


def measure_remat_factor(arch: str, remat: str) -> float:
    """Measured train factor (fwd+bwd+recompute) for a remat policy.

    Compiles a reduced-depth UNROLLED single-device variant (XLA counts
    unrolled bodies exactly) with remat='full' (factor 4 by construction)
    and with the requested policy, and scales: factor = 4 * flops(policy)
    / flops(full).  Memoized per (arch, remat).
    """
    if remat in ("full", True):
        return 4.0
    key = (arch, remat)
    if key in _REMAT_FACTOR_CACHE:
        return _REMAT_FACTOR_CACHE[key]
    cfg = configs.get_smoke(arch)
    opt = optim.Adam(lr=1e-4)
    B, T = 2, 128
    toks = jax.ShapeDtypeStruct((B, T), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    elif cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_seq, cfg.d_model), jnp.dtype(cfg.compute_dtype))

    def flops_for(policy):
        lm.UNROLL_STACKS = True
        try:
            def init():
                p = lm.init_params(jax.random.PRNGKey(0), cfg)
                return p, opt.init(p)

            pshapes = jax.eval_shape(init)
            step = partial(lm.train_step, cfg=cfg, optimizer=opt,
                           remat=policy)
            c = jax.jit(step).lower(pshapes[0], pshapes[1], batch).compile()
            return float(c.cost_analysis().get("flops", 0.0))
        finally:
            lm.UNROLL_STACKS = False

    f_full, f_pol = flops_for("full"), flops_for(remat)
    factor = 4.0 * (f_pol / f_full) if f_full else 4.0
    _REMAT_FACTOR_CACHE[key] = factor
    return factor


_REMAT_FACTOR_CACHE: dict = {}


def resolve_mode(mode: str, cfg, shape: InputShape) -> str:
    """'auto' = the SPerf-winning strategy per cell class:

    * train, replica fits comfortably on a chip (< 4 GB bf16) -> ``dp``
      (19x on mamba2; zero gather traffic, one gradient all-reduce);
    * train, dense + large -> ``fsdp`` (ZeRO-3; 1.75-1.84x on llama/qwen3);
    * train, MoE + large -> ``tp`` (expert parallelism IS the
      communication-minimal layout for expert banks: only routed tokens
      move; ZeRO-3 re-gathers the full expert weights and measured 3x
      WORSE on phi3.5/qwen3-moe -- a confirmed-negative result);
    * prefill/decode -> ``tp_serve`` (params never re-gathered; 14.5x on
      qwen3 decode).
    """
    if mode != "auto":
        return mode
    if shape.kind == "train":
        if cfg.param_count() * 2 < 4e9:
            return "dp"
        return "tp" if cfg.num_experts else "fsdp"
    return "tp_serve"


def build_lowered(arch: str, shape_name: str, mesh, *, sp: bool = True,
                  moe_group: int = 256, mode: str = "tp",
                  explicit_out: bool = False, remat: str = "full"):
    """Lower one cell.  ``mode`` picks the sharding strategy (tp | tp_serve
    | fsdp | dp | pp | auto -- see distributed/sharding.py and
    resolve_mode); ``explicit_out`` pins the train step's output shardings
    to the parameter shardings (SPerf iteration, refuted -- kept as an
    ablation flag)."""
    cfg = configs.get(arch)
    shape = configs.get_shape(shape_name)
    mode = resolve_mode(mode, cfg, shape)
    pol = sharding.make_policy(mesh, batch=shape.global_batch,
                               kind=shape.kind, sp=sp, mode=mode)
    specs = input_specs(arch, shape_name, mesh, mode=mode)

    if shape.kind == "train" and mode == "pp":
        from repro.distributed import pipeline
        opt = optim.Adam(lr=1e-4)

        def init():
            return pipeline.init_pp(jax.random.PRNGKey(0), cfg, opt)

        pshapes, oshapes = jax.eval_shape(init)
        psh, osh = pipeline.pp_shardings(mesh, pshapes, oshapes)
        p_sds = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            pshapes, psh)
        o_sds = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            oshapes, osh)
        n_micro = mesh.shape["model"]  # M = S: bubble factor (2S-1)/S
        step = pipeline.make_pp_train_step(cfg, opt, mesh, n_micro=n_micro)
        fn = jax.jit(step, donate_argnums=(0, 1))
        with mesh:
            lowered = fn.lower(p_sds, o_sds, specs)
        return lowered, cfg, shape

    if shape.kind == "train":
        opt = optim.Adam(lr=1e-4)

        def init():
            p = lm.init_params(jax.random.PRNGKey(0), cfg)
            p = jax.tree.map(
                lambda x: x.astype(cfg.param_dtype)
                if x.dtype == jnp.float32 else x, p)
            return p, opt.init(p)

        pshapes = jax.eval_shape(init)
        p_sds = sharding.sds_with_sharding(mesh, pshapes[0], mode)
        o_sds = sharding.sds_with_sharding(mesh, pshapes[1], mode)
        ngroups = max(1, shape.global_batch * shape.seq_len // moe_group)
        step = partial(lm.train_step, cfg=cfg, optimizer=opt, pol=pol,
                       moe_groups=ngroups, remat=remat)
        kw = {}
        if explicit_out:
            kw["out_shardings"] = (
                sharding.tree_shardings(mesh, pshapes[0], mode),
                sharding.tree_shardings(mesh, pshapes[1], mode),
                jax.sharding.NamedSharding(mesh,
                                           jax.sharding.PartitionSpec()))
        fn = jax.jit(step, donate_argnums=(0, 1), **kw)
        with mesh:
            lowered = fn.lower(p_sds, o_sds, specs)
        return lowered, cfg, shape

    if shape.kind == "prefill":
        def init():
            p = lm.init_params(jax.random.PRNGKey(0), cfg)
            return jax.tree.map(
                lambda x: x.astype(cfg.param_dtype)
                if x.dtype == jnp.float32 else x, p)

        p_sds = sharding.sds_with_sharding(mesh, jax.eval_shape(init), mode)
        aux_keys = [k for k in specs if k not in ("tokens",)]
        ngroups = max(1, shape.global_batch * shape.seq_len // moe_group)

        def step(params, tokens, aux):
            return lm.prefill(params, cfg, tokens, aux or None, pol=pol,
                              moe_groups=ngroups)

        aux = {k: specs[k] for k in aux_keys}
        with mesh:
            lowered = jax.jit(step).lower(p_sds, specs["tokens"], aux)
        return lowered, cfg, shape

    # decode
    def init():
        p = lm.init_params(jax.random.PRNGKey(0), cfg)
        return jax.tree.map(
            lambda x: x.astype(cfg.param_dtype)
            if x.dtype == jnp.float32 else x, p)

    p_sds = sharding.sds_with_sharding(mesh, jax.eval_shape(init), mode)

    def step(params, cache, token):
        return lm.serve_step(params, cache, token, cfg, pol=pol)

    fn = jax.jit(step, donate_argnums=(1,))
    with mesh:
        lowered = fn.lower(p_sds, specs["cache"], specs["token"])
    return lowered, cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             *, sp: bool = True, moe_group: int = 256,
             mode: str = "tp", explicit_out: bool = False,
             wire_bf16: bool = True, remat: str = "full",
             verbose: bool = True) -> dict:
    cfg = configs.get(arch)
    shape = configs.get_shape(shape_name)
    mode = resolve_mode(mode, cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "mode": mode, "remat": remat, "status": "ok"}
    reason = skip_reason(cfg, shape)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec
    t0 = time.time()
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    try:
        lowered, cfg, shape = build_lowered(arch, shape_name, mesh, sp=sp,
                                            moe_group=moe_group, mode=mode,
                                            explicit_out=explicit_out,
                                            remat=remat)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        hlo = compiled.as_text()
        # Wire accounting: f32 collectives counted at bf16 width by default
        # (the CPU host pipeline upcasts bf16 before SPMD -- see
        # hlo_analysis._shape_bytes); raw-HLO numbers recorded alongside.
        f32b = 2 if wire_bf16 else 4
        coll = hlo_analysis.collective_stats(hlo, f32_elem_bytes=f32b)
        coll_raw = hlo_analysis.collective_stats(hlo, scale_loops=False)
        # XLA cost_analysis counts while (scan) bodies once (verified in
        # tests/test_analytic.py), so the roofline numerators come from the
        # exact analytic accounting; raw HLO numbers are recorded alongside.
        tf = (measure_remat_factor(arch, remat)
              if shape.kind == "train" else 4.0)
        rec["train_factor"] = tf
        an = analytic.summarize(cfg, shape, n_dev, train_factor=tf)
        flops_dev = an["flops_per_device"]
        bytes_dev = an["bytes_per_device"]
        if mode == "pp":
            # GPipe bubble: the SPMD schedule executes (M+S-1)/M x the
            # useful per-stage work -- charge the compute term for it.
            S = mesh.shape["model"]
            M = S
            rec["pipeline_overhead"] = (M + S - 1) / M
            flops_dev *= rec["pipeline_overhead"]
        mf = model_flops(cfg, shape)
        terms = hlo_analysis.roofline_terms(
            flops_dev, bytes_dev, coll["total_wire_bytes"])
        rec.update(
            devices=n_dev,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops_per_device=flops_dev, bytes_per_device=bytes_dev,
            hlo_flops_per_device=float(ca.get("flops", 0.0)),
            hlo_bytes_per_device=float(ca.get("bytes accessed", 0.0)),
            peak_bytes_per_device=int(ma.peak_memory_in_bytes),
            argument_bytes_per_device=int(ma.argument_size_in_bytes),
            output_bytes_per_device=int(ma.output_size_in_bytes),
            collectives={k: v for k, v in coll.items()},
            collectives_unscaled={k: v for k, v in coll_raw.items()},
            model_flops_total=mf,
            model_flops_per_device=mf / n_dev,
            useful_flops_ratio=(mf / n_dev) / flops_dev if flops_dev else 0,
            **{k: v for k, v in terms.items()},
        )
    except Exception as e:  # noqa: BLE001 -- record the failure, keep going
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    if verbose:
        msg = {k: rec.get(k) for k in
               ("arch", "shape", "mesh", "status", "compile_s", "bottleneck",
                "compute_fraction", "peak_bytes_per_device")}
        print(json.dumps(msg), flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence parallelism (perf ablation)")
    ap.add_argument("--moe-group", type=int, default=256)
    ap.add_argument("--mode", default="tp",
                    choices=["tp", "tp_serve", "fsdp", "dp", "pp", "auto"],
                    help="sharding strategy (SPerf hillclimb variants; "
                         "pp = GPipe stages on the model axis, dense train; "
                         "auto = the SPerf-winning strategy per cell class)")
    ap.add_argument("--explicit-out", action="store_true",
                    help="pin train output shardings (grad reduce-scatter)")
    ap.add_argument("--raw-wire", action="store_true",
                    help="disable the f32->bf16 wire-byte correction")
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"],
                    help="activation-checkpoint policy for train cells")
    args = ap.parse_args(argv)

    archs = (configs.ARCH_IDS if args.arch == "all"
             else [configs.canonical(a) for a in args.arch.split(",")])
    shapes = ([s.name for s in configs.SHAPES] if args.shape == "all"
              else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_fail = 0
    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    rec = run_cell(arch, shape, mp, sp=not args.no_sp,
                                   moe_group=args.moe_group, mode=args.mode,
                                   explicit_out=args.explicit_out,
                                   wire_bf16=not args.raw_wire,
                                   remat=args.remat)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    n_fail += rec["status"] == "error"
    print(f"done; {n_fail} errors", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
