"""ConfuciuX search launcher: any registered optimizer as a CLI.

    PYTHONPATH=src python -m repro.launch.search --workload mobilenet_v2 \
        --objective latency --constraint area --platform iot \
        --dataflow dla --epochs 5000 --out results/search.json

    # Any other search method through the same flags:
    PYTHONPATH=src python -m repro.launch.search --workload mnasnet \
        --method sa --epochs 2000

    # One-shot gradient descent through the differentiable cost model:
    PYTHONPATH=src python -m repro.launch.search --workload ncf \
        --method relaxed --epochs 200

    # Assigned architecture as the search target (LLM serving workload):
    PYTHONPATH=src python -m repro.launch.search --arch qwen3-32b --tokens 512

Inputs mirror Fig. 3: target model, deployment scenario (LS/LP), objective
(latency/energy), platform constraint (Table II).  ``--method`` picks any
optimizer from the unified registry (repro.api); the default is the paper's
two-stage pipeline.  Output: the optimized per-layer (PE, Buffer[,
dataflow]) assignment in one schema for every method.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro import api
from repro.core import env as env_lib
from repro.costmodel import dataflows as dfl
from repro.costmodel import workloads as workloads_lib
from repro.costmodel.layers import total_macs


def build_request(args) -> api.SearchRequest:
    """Translate CLI flags into the canonical SearchRequest."""
    if args.workload:
        wl = workloads_lib.get_workload(args.workload)
    else:
        from repro.costmodel import arch_workloads
        wl = arch_workloads.lower_arch(args.arch, tokens=args.tokens)

    mix = args.dataflow == "mix"
    ecfg = env_lib.EnvConfig(
        objective=args.objective, constraint=args.constraint,
        platform=args.platform, scenario=args.scenario,
        dataflow=(dfl.DLA if mix
                  else dfl.DATAFLOW_NAMES.index(args.dataflow)),
        mix=mix, levels=args.levels,
        blend_weight=args.blend_weight)
    # GA flags feed both the two_stage fine-tuner (nested "ga" dict) and
    # --method ga / nsga2 (top-level keys); unset flags keep each method's
    # defaults.
    ga_opts = {k: v for k, v in (("population", args.ga_population),
                                 ("generations", args.ga_generations))
               if v is not None}
    options = {
        "episodes_per_epoch": args.episodes,
        "fine_tune": not args.no_finetune,
        "ga": ga_opts,
        **ga_opts,
    }
    if args.archive is not None:
        options["archive"] = args.archive
    if args.lr is not None:      # unset keeps each method's own default
        options["lr"] = args.lr
    # Relaxed-engine knobs (ignored by every other method).
    for k, v in (("steps_per_eval", args.relaxed_steps),
                 ("restarts", args.relaxed_restarts),
                 ("tau_start", args.tau_start),
                 ("tau_min", args.tau_min)):
        if v is not None:
            options[k] = v
    if args.method == "fanout":
        # The per-method knobs collected above configure the *inner* method;
        # the fanout layer itself takes the shard/backend flags.
        options = {"inner": args.fanout_inner,
                   "n_shards": args.fanout_shards,
                   "backend": args.fanout_backend,
                   "inner_options": options}
    # eps counts whole-model evaluations; --epochs keeps the paper's
    # epoch semantics (one epoch = --episodes samples for the RL family).
    return api.SearchRequest(
        workload=wl, env=ecfg, eps=args.epochs * args.episodes,
        seed=args.seed, method=args.method, options=options)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--workload", help="paper workload name "
                     f"(one of {workloads_lib.workload_names()})")
    src.add_argument("--arch", help="assigned architecture id (the model is "
                     "lowered to its per-layer GEMM/CONV descriptors)")
    ap.add_argument("--tokens", type=int, default=256,
                    help="tokens per forward for --arch lowering")
    ap.add_argument("--method", default="two_stage",
                    help="search method from the unified registry "
                    f"(one of {', '.join(api.list_optimizers())})")
    ap.add_argument("--objective", default="latency",
                    choices=["latency", "energy", "blend"],
                    help="whole-model objective; 'blend' scalarizes "
                    "lat^w * en^(1-w) with --blend-weight (sampling "
                    "methods only)")
    ap.add_argument("--blend-weight", type=float, default=0.5,
                    help="--objective blend: latency weight w in [0, 1]")
    ap.add_argument("--archive", type=int, default=None,
                    help="--method nsga2: Pareto-archive capacity "
                    "(default 128)")
    ap.add_argument("--constraint", default="area",
                    choices=["area", "power"])
    ap.add_argument("--platform", default="iot",
                    choices=["unlimited", "cloud", "iot", "iotx"])
    ap.add_argument("--scenario", default="LP", choices=["LP", "LS"])
    ap.add_argument("--dataflow", default="dla",
                    choices=["dla", "eye", "shi", "mix"])
    ap.add_argument("--levels", type=int, default=12, choices=[10, 12, 14])
    ap.add_argument("--epochs", type=int, default=5000,
                    help="sample budget Eps (in epochs of --episodes)")
    ap.add_argument("--episodes", type=int, default=1,
                    help="episodes per epoch (1 = the paper's setting)")
    ap.add_argument("--lr", type=float, default=None,
                    help="default: 3e-3 for reinforce/two_stage, "
                    "1e-3 for a2c/ppo2")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-finetune", action="store_true",
                    help="skip the stage-2 local GA (two_stage only)")
    ap.add_argument("--ga-generations", type=int, default=None,
                    help="default: 2000 for the two_stage fine-tuner, "
                    "eps/population for --method ga")
    ap.add_argument("--ga-population", type=int, default=None,
                    help="default: 20 for the two_stage fine-tuner, "
                    "100 for --method ga")
    ap.add_argument("--relaxed-steps", type=int, default=None,
                    help="--method relaxed: gradient steps per hard "
                    "evaluation (default 25)")
    ap.add_argument("--relaxed-restarts", type=int, default=None,
                    help="--method relaxed: parallel descent replicas "
                    "(default 4)")
    ap.add_argument("--tau-start", type=float, default=None,
                    help="--method relaxed: initial surrogate temperature "
                    "(default 1.0)")
    ap.add_argument("--tau-min", type=float, default=None,
                    help="--method relaxed: annealing floor (default 0.05)")
    ap.add_argument("--fanout-backend", default="auto",
                    choices=["auto", "device", "threads", "serial"],
                    help="--method fanout execution backend: one shard per "
                    "local device in one XLA program (device), one host "
                    "thread per shard (threads), or an in-process loop "
                    "(serial); auto picks device for JAX-native inners "
                    "when enough devices exist, else threads")
    ap.add_argument("--fanout-inner", default="reinforce",
                    help="--method fanout: inner method each shard runs")
    ap.add_argument("--fanout-shards", type=int, default=4,
                    help="--method fanout: number of parallel searches")
    ap.add_argument("--progress-every", type=int, default=0,
                    help="stream best-so-far every N samples (0 = off)")
    ap.add_argument("--out", default="")
    ap.add_argument("--trace-out", default="",
                    help="write a span trace here (.jsonl = one span per "
                    "line, else Chrome-trace JSON for chrome://tracing / "
                    "ui.perfetto.dev); enables telemetry")
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics registry here (.prom text "
                    "exposition, or .json snapshot); enables telemetry")
    ap.add_argument("--profile", action="store_true",
                    help="enable telemetry and print the flight-recorder "
                    "summary even without --trace-out/--metrics-out")
    args = ap.parse_args(argv)

    try:
        api.get_optimizer(args.method)
    except KeyError as e:
        ap.error(e.args[0])

    request = build_request(args)
    wl = request.workload
    target = args.workload or args.arch
    print(f"target={target} method={args.method} layers={len(wl)} "
          f"macs={total_macs(wl)/1e6:.0f}M obj={args.objective} "
          f"cstr={args.constraint}:{args.platform} df={args.dataflow} "
          f"scenario={args.scenario} eps={request.eps}", flush=True)

    if args.progress_every > 0:
        request.progress_every = args.progress_every
        request.on_progress = lambda t: print(
            f"  [{t.step}/{request.eps}]"
            + (f" shard={t.shard}" if t.shard is not None else "")
            + f" best={t.best_value:.4e}",
            flush=True)

    profile = bool(args.profile or args.trace_out or args.metrics_out)
    if profile:
        from repro import obs
        obs.enable(trace=True)

    out = api.run_search(request)

    if profile:
        from repro import obs
        print(out.summary(), flush=True)
        if args.trace_out:
            obs.save_trace(args.trace_out)
            print(f"wrote {args.trace_out}", flush=True)
        if args.metrics_out:
            obs.write_prometheus(args.metrics_out)
            print(f"wrote {args.metrics_out}", flush=True)
        obs.disable()

    stage1 = out.extras.get("stage1_value")
    initial = out.extras.get("initial_valid_value")
    rec = {
        "target": target, "method": out.method,
        "objective": args.objective,
        "constraint": args.constraint, "platform": args.platform,
        "scenario": args.scenario, "dataflow": args.dataflow,
        "eps": out.eps, "epochs": args.epochs, "seed": out.seed,
        "best_value": out.best_value,
        "feasible": out.feasible,
        "stage1_value": stage1,
        "initial_valid_value": initial,
        "stage1_improvement_pct": (
            100.0 * (1 - stage1 / initial)
            if initial is not None and np.isfinite(initial) else None),
        "stage2_improvement_pct": (
            100.0 * (1 - out.best_value / stage1)
            if stage1 is not None and np.isfinite(stage1) else None),
        "samples_to_convergence": out.samples_to_convergence,
        "wall_seconds": round(out.wall_seconds, 2),
    }
    if out.telemetry is not None:
        rec["telemetry"] = out.telemetry
    if out.frontier is not None:
        # Multi-objective methods: the latency-energy trade-off curve.
        rec["frontier"] = {
            k: np.asarray(v).tolist()
            for k, v in out.frontier.items() if k not in ("pe", "kt", "df")}
        rec["frontier_size"] = len(out.frontier["lat"])
    if out.feasible:
        rec["assignment"] = {
            "pe": np.asarray(out.pe).astype(int).tolist(),
            "kt": np.asarray(out.kt).astype(int).tolist(),
            "dataflow": [dfl.DATAFLOW_NAMES[int(d)] for d in out.df],
            "layers": [l.name or f"layer{i}" for i, l in enumerate(wl)],
        }
    print(json.dumps({k: rec[k] for k in
                      ("method", "best_value", "stage1_value",
                       "initial_valid_value", "samples_to_convergence",
                       "wall_seconds")}), flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {args.out}", flush=True)
    return 0 if out.feasible else 1


if __name__ == "__main__":
    sys.exit(main())
