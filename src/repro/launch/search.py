"""ConfuciuX search launcher: the paper's workflow as a CLI.

    PYTHONPATH=src python -m repro.launch.search --workload mobilenet_v2 \
        --objective latency --constraint area --platform iot \
        --dataflow dla --epochs 5000 --out results/search.json

    # Assigned architecture as the search target (LLM serving workload):
    PYTHONPATH=src python -m repro.launch.search --arch qwen3-32b --tokens 512

Inputs mirror Fig. 3: target model, deployment scenario (LS/LP), objective
(latency/energy), platform constraint (Table II).  Output: the optimized
per-layer (PE, Buffer[, dataflow]) assignment + both stage values.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.core import env as env_lib
from repro.core import ga as ga_lib
from repro.core import reinforce, search
from repro.costmodel import dataflows as dfl
from repro.costmodel import workloads as workloads_lib
from repro.costmodel.layers import total_macs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--workload", help="paper workload name "
                     f"(one of {workloads_lib.workload_names()})")
    src.add_argument("--arch", help="assigned architecture id (the model is "
                     "lowered to its per-layer GEMM/CONV descriptors)")
    ap.add_argument("--tokens", type=int, default=256,
                    help="tokens per forward for --arch lowering")
    ap.add_argument("--objective", default="latency",
                    choices=["latency", "energy"])
    ap.add_argument("--constraint", default="area",
                    choices=["area", "power"])
    ap.add_argument("--platform", default="iot",
                    choices=["unlimited", "cloud", "iot", "iotx"])
    ap.add_argument("--scenario", default="LP", choices=["LP", "LS"])
    ap.add_argument("--dataflow", default="dla",
                    choices=["dla", "eye", "shi", "mix"])
    ap.add_argument("--levels", type=int, default=12, choices=[10, 12, 14])
    ap.add_argument("--epochs", type=int, default=5000)
    ap.add_argument("--episodes", type=int, default=1,
                    help="episodes per epoch (1 = the paper's setting)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-finetune", action="store_true",
                    help="skip the stage-2 local GA")
    ap.add_argument("--ga-generations", type=int, default=2000)
    ap.add_argument("--ga-population", type=int, default=20)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    if args.workload:
        wl = workloads_lib.get_workload(args.workload)
        target = args.workload
    else:
        from repro.costmodel import arch_workloads
        wl = arch_workloads.lower_arch(args.arch, tokens=args.tokens)
        target = args.arch

    mix = args.dataflow == "mix"
    ecfg = env_lib.EnvConfig(
        objective=args.objective, constraint=args.constraint,
        platform=args.platform, scenario=args.scenario,
        dataflow=(dfl.DLA if mix
                  else dfl.DATAFLOW_NAMES.index(args.dataflow)),
        mix=mix, levels=args.levels)
    rcfg = reinforce.ReinforceConfig(
        epochs=args.epochs, episodes_per_epoch=args.episodes,
        lr=args.lr, seed=args.seed)
    gcfg = ga_lib.LocalGAConfig(population=args.ga_population,
                                generations=args.ga_generations,
                                seed=args.seed)

    print(f"target={target} layers={len(wl)} macs={total_macs(wl)/1e6:.0f}M "
          f"obj={args.objective} cstr={args.constraint}:{args.platform} "
          f"df={args.dataflow} scenario={args.scenario}", flush=True)

    res = search.confuciux_search(wl, ecfg, rcfg, gcfg,
                                  fine_tune=not args.no_finetune)

    rec = {
        "target": target, "objective": args.objective,
        "constraint": args.constraint, "platform": args.platform,
        "scenario": args.scenario, "dataflow": args.dataflow,
        "epochs": args.epochs,
        "initial_valid_value": res.initial_valid_value,
        "stage1_value": res.stage1_value,
        "best_value": res.best_value,
        "stage1_improvement_pct": (
            100.0 * (1 - res.stage1_value / res.initial_valid_value)
            if np.isfinite(res.initial_valid_value) else None),
        "stage2_improvement_pct": (
            100.0 * (1 - res.best_value / res.stage1_value)
            if np.isfinite(res.stage1_value) else None),
        "wall_seconds": round(res.wall_seconds, 2),
        "assignment": {
            "pe": np.asarray(res.pe).astype(int).tolist(),
            "kt": np.asarray(res.kt).astype(int).tolist(),
            "dataflow": [dfl.DATAFLOW_NAMES[int(d)] for d in res.df],
            "layers": [l.name or f"layer{i}" for i, l in enumerate(wl)],
        },
    }
    print(json.dumps({k: rec[k] for k in
                      ("best_value", "stage1_value", "initial_valid_value",
                       "wall_seconds")}), flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {args.out}", flush=True)
    return 0 if np.isfinite(res.best_value) else 1


if __name__ == "__main__":
    sys.exit(main())
