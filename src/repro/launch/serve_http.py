"""Boot the HTTP search front door.

    # Serve on :8731 with a persistent cost cache and 2:1 tenant weights:
    PYTHONPATH=src python -m repro.launch.serve_http \
        --port 8731 --workers 8 --cache-dir /var/cache/repro \
        --tenant-weights batch=1,interactive=2

    # Then, from anywhere:
    curl -s localhost:8731/v1/search -d \
        '{"workload": "ncf", "method": "random", "eps": 300,
          "tenant": "interactive"}'
    curl -s localhost:8731/v1/search/0            # status / result
    curl -sN localhost:8731/v1/search/0/progress  # chunked JSONL stream
    curl -s localhost:8731/v1/stats
    curl -s localhost:8731/metrics                # Prometheus text

Telemetry is enabled by default so the ``/metrics`` endpoint is live;
``--no-telemetry`` turns it off (requests still work, counters freeze).
``--cache-dir`` makes the per-point cost memo cache persistent: entries
flush to versioned shard files and reload on restart, so a warm restart
serves popular queries almost entirely from disk.
"""
from __future__ import annotations

import argparse
import sys

from repro.serving import (HttpConfig, SearchHTTPService, SearchService,
                           ServiceConfig)


def _parse_weights(text: str):
    """``a=2,b=1`` -> (("a", 2), ("b", 1))."""
    if not text:
        return ()
    pairs = []
    for item in text.split(","):
        name, _, w = item.partition("=")
        pairs.append((name.strip(), int(w or 1)))
    return tuple(pairs)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8731,
                    help="0 picks an ephemeral port")
    ap.add_argument("--workers", type=int, default=8,
                    help="search worker threads in the backing service")
    ap.add_argument("--dispatch-workers", type=int, default=1,
                    help="fused-dispatch pool size in the cost-eval batcher")
    ap.add_argument("--window-ms", type=float, default=2.0)
    ap.add_argument("--cache-dir", default="",
                    help="persist the cost memo cache here (versioned "
                    "shard files); warm restarts reload it")
    ap.add_argument("--cache-flush-every", type=int, default=4096,
                    help="flush the persistent cache every N fresh entries")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="admission queue bound; past it -> HTTP 429")
    ap.add_argument("--max-running", type=int, default=0,
                    help="concurrent searches (0: same as --workers)")
    ap.add_argument("--tenant-weights", default="",
                    help="WRR weights, e.g. batch=1,interactive=4")
    ap.add_argument("--default-weight", type=int, default=1)
    ap.add_argument("--platform", default="cloud",
                    choices=["unlimited", "cloud", "iot", "iotx"])
    ap.add_argument("--eps", type=int, default=600,
                    help="default eval budget for bodies that omit eps")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="skip enabling repro.obs (freezes /metrics)")
    args = ap.parse_args(argv)

    if not args.no_telemetry:
        from repro import obs
        obs.enable(trace=True)

    svc_cfg = ServiceConfig(max_workers=args.workers,
                            window_ms=args.window_ms,
                            dispatch_workers=args.dispatch_workers,
                            cache_dir=args.cache_dir or None,
                            cache_flush_every=args.cache_flush_every)
    http_cfg = HttpConfig(host=args.host, port=args.port,
                          max_queue=args.max_queue,
                          max_running=args.max_running or None,
                          tenant_weights=_parse_weights(args.tenant_weights),
                          default_weight=args.default_weight,
                          default_eps=args.eps,
                          default_platform=args.platform)
    service = SearchService(svc_cfg)
    hub = SearchHTTPService(http_cfg=http_cfg, service=service)
    cache_note = (f", cache-dir {args.cache_dir} "
                  f"({len(service.cache)} entries warm)"
                  if args.cache_dir else "")
    print(f"search front door on {hub.url} "
          f"({args.workers} workers{cache_note})", flush=True)
    try:
        hub.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        hub.close()
        service.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
