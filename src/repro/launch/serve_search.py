"""Search-as-a-service launcher: many users' searches on one machine.

    # 12 synthetic "users" mixing methods over two popular workloads:
    PYTHONPATH=src python -m repro.launch.serve_search \
        --workloads ncf,mobilenet_v2 --methods random,grid,bo,reinforce \
        --n 12 --eps 600 --progress --out results/serve_search.json

    # An explicit request mix from a JSON spec (a list of request dicts;
    # unknown keys go into options):
    PYTHONPATH=src python -m repro.launch.serve_search --spec mix.json

Every request is a unified-API ``SearchRequest`` dispatched through
:class:`repro.serving.SearchService`: host-loop methods (random/grid/bo)
fuse their cost evaluations into one cross-request dispatch stream with a
shared per-point memo cache; ga/sa are chunked engines whose generation /
candidate evaluations route through the SAME batcher; the RL family
(reinforce, two_stage, a2c, ppo2) interleaves at chunk granularity.
``--dispatch-workers N`` sizes the batcher's fused-dispatch pool (N
concurrent fused dispatches, still bit-identical to serial).  The exit
summary reports searches/sec, the cache hit rate and the batcher fusion
stats.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro import api
from repro.core import env as env_lib
from repro.costmodel import dataflows as dfl
from repro.serving import SearchService, ServiceConfig


def _synthetic_requests(args):
    """Round-robin (workload x method) mix; each distinct query is
    submitted by ``--repeat`` users (identical popular queries)."""
    workloads = args.workloads.split(",")
    methods = args.methods.split(",")
    reqs = []
    for u in range(args.n):
        q = u // args.repeat          # users in a repeat group share the
        reqs.append(dict(             # whole query, not just the seed
            workload=workloads[q % len(workloads)],
            method=methods[q % len(methods)],
            eps=args.eps, seed=args.seed + q))
    return reqs


def _to_request(spec: dict, args) -> api.SearchRequest:
    spec = dict(spec)
    ecfg = env_lib.EnvConfig(
        objective=spec.pop("objective", "latency"),
        constraint=spec.pop("constraint", "area"),
        platform=spec.pop("platform", args.platform),
        scenario=spec.pop("scenario", "LP"),
        dataflow=dfl.DATAFLOW_NAMES.index(spec.pop("dataflow", "dla")))
    workload = spec.pop("workload")
    eps = int(spec.pop("eps", args.eps))
    seed = int(spec.pop("seed", 0))
    method = spec.pop("method", "two_stage")
    # Leftover unknown keys merge into options (an explicit "options"
    # dict wins on conflicts).
    explicit = spec.pop("options", {})
    options = {**spec, **explicit}
    return api.SearchRequest(workload=workload, env=ecfg, eps=eps,
                             seed=seed, method=method, options=options)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spec", default="",
                    help="JSON file with a list of request dicts")
    ap.add_argument("--workloads", default="ncf,mobilenet_v2",
                    help="comma list cycled across synthetic users")
    ap.add_argument("--methods", default="random,grid,bo",
                    help="comma list cycled across synthetic users")
    ap.add_argument("--n", type=int, default=8,
                    help="number of synthetic requests")
    ap.add_argument("--repeat", type=int, default=2,
                    help="users per distinct seed -- models identical "
                    "popular queries hitting the memo cache")
    ap.add_argument("--eps", type=int, default=600)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--platform", default="cloud",
                    choices=["unlimited", "cloud", "iot", "iotx"])
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--dispatch-workers", type=int, default=1,
                    help="fused-dispatch pool size in the cost-eval batcher")
    ap.add_argument("--window-ms", type=float, default=2.0)
    ap.add_argument("--cache-dir", default="",
                    help="persist the cost memo cache here (versioned "
                    "shard files); warm restarts reload it")
    ap.add_argument("--progress", action="store_true",
                    help="stream per-request progress lines")
    ap.add_argument("--out", default="")
    ap.add_argument("--trace-out", default="",
                    help="write a span trace here (.jsonl = one span per "
                    "line, else Chrome-trace JSON); enables telemetry")
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics registry here (.prom text "
                    "exposition, or .json snapshot); enables telemetry")
    ap.add_argument("--profile", action="store_true",
                    help="enable telemetry and print per-search "
                    "flight-recorder summaries")
    args = ap.parse_args(argv)

    profile = bool(args.profile or args.trace_out or args.metrics_out)
    if profile:
        from repro import obs
        obs.enable(trace=True)

    if args.spec:
        with open(args.spec) as f:
            specs = json.load(f)
    else:
        specs = _synthetic_requests(args)
    requests = [_to_request(s, args) for s in specs]

    print(f"serving {len(requests)} searches on {args.workers} workers "
          f"({args.dispatch_workers} dispatch, window {args.window_ms}ms)",
          flush=True)
    svc = SearchService(ServiceConfig(max_workers=args.workers,
                                      window_ms=args.window_ms,
                                      dispatch_workers=args.dispatch_workers,
                                      cache_dir=args.cache_dir or None))
    t0 = time.time()
    tickets = []
    for i, r in enumerate(requests):
        if args.progress:
            r.on_progress = (lambda i=i: lambda t: print(
                f"  [req{i}] step={t.step} best={t.best_value:.4e}",
                flush=True))()
            r.progress_every = max(r.eps // 4, 1)
        tickets.append(svc.submit(r))

    rows = []
    for i, (t, spec) in enumerate(zip(tickets, specs)):
        try:
            out = t.result()
            row = {"req": i, "workload": str(spec.get("workload")),
                   "method": out.method, "seed": out.seed,
                   "best_value": out.best_value,
                   "feasible": out.feasible,
                   "wall_seconds": round(t.wall_seconds, 2)}
            if out.telemetry is not None:
                row["telemetry"] = out.telemetry
            rows.append(row)
        except Exception as e:  # noqa: BLE001
            rows.append({"req": i, "status": t.status, "error": repr(e)})
    wall = time.time() - t0
    stats = svc.stats()
    svc.close()

    for r in rows:
        print(json.dumps(r), flush=True)
    summary = {
        "requests": len(requests), "wall_seconds": round(wall, 2),
        "searches_per_sec": round(len(requests) / wall, 3),
        "cache_hit_rate": round(stats["cache_hit_rate"], 4),
        "fused_dispatches": stats["fused_dispatches"],
        "dispatches": stats["dispatches"],
        "points": stats["points"], "fresh_points": stats["fresh_points"],
        # dedup + cache together: fraction of requested points that never
        # reached the cost model (concurrent identical queries fuse into
        # the same dispatch, so they show up here rather than as hits).
        "points_eliminated_frac": round(
            1.0 - stats["fresh_points"] / max(stats["points"], 1), 4),
    }
    print(json.dumps(summary), flush=True)
    if profile:
        from repro import obs
        if args.trace_out:
            obs.save_trace(args.trace_out)
            print(f"wrote {args.trace_out}", flush=True)
        if args.metrics_out:
            obs.write_prometheus(args.metrics_out)
            print(f"wrote {args.metrics_out}", flush=True)
        obs.disable()
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"summary": summary, "results": rows,
                       "stats": stats}, f, indent=1)
        print(f"wrote {args.out}", flush=True)
    # Exit status reflects SERVICE health, not search feasibility: an
    # infeasible outcome under a tight budget is a correct answer (the
    # paper's "NAN"), not a failed request.
    return 1 if any("error" in r for r in rows) else 0


if __name__ == "__main__":
    sys.exit(main())
