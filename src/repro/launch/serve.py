"""Serving launcher: batched greedy decoding with the bucketed engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1p5_0p5b --smoke \
        --requests 32 --max-new 24

Builds the model (smoke or full config), spins up ``repro.serving.Engine``
and runs a synthetic request stream, reporting tokens/s and per-bucket
latency.  On a multi-device host (XLA_FLAGS
--xla_force_host_platform_device_count=N) pass ``--mesh DxM`` to shard the
decode the same way the dry-run's decode cells do.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed import sharding
from repro.launch.train import build_mesh
from repro.models import lm
from repro.serving import Engine, ServeConfig
from repro.serving.engine import synthetic_requests


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1p5_0p5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prompt-lens", default="8,16")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--f32", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    if args.f32:
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  compute_dtype="float32")
    mesh = build_mesh(args.mesh)

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    pol = lm.NO_SHARDING
    if mesh is not None:
        params = jax.device_put(params, sharding.tree_shardings(mesh, params))
        pol = sharding.make_policy(mesh, batch=args.max_batch, kind="decode")

    cross_feats = None
    if cfg.family == "audio":
        cross_feats = jnp.zeros((1, cfg.encoder_seq, cfg.d_model),
                                jnp.dtype(cfg.compute_dtype))
    elif cfg.family == "vlm":
        cross_feats = jnp.zeros((1, cfg.vision_seq, cfg.d_model),
                                jnp.dtype(cfg.compute_dtype))

    engine = Engine(cfg, params,
                    ServeConfig(max_len=args.max_len,
                                max_batch=args.max_batch),
                    pol=pol, cross_feats=cross_feats)
    plens = tuple(int(x) for x in args.prompt_lens.split(","))
    reqs = synthetic_requests(args.requests, cfg.vocab_size,
                              prompt_lens=plens, max_new=args.max_new,
                              seed=args.seed)
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.1f}M "
          f"requests={args.requests} mesh={args.mesh}", flush=True)
    ctx = mesh if mesh is not None else jax.default_device(jax.devices()[0])
    with ctx:
        stats = engine.serve(reqs)
    assert all(r.done and len(r.output) > 0 for r in reqs)
    print(json.dumps(stats), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
