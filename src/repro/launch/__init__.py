"""Launchers: mesh construction, multi-pod dry-run, train/search/serve."""
