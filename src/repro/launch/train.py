"""Training launcher: fault-tolerant, mesh-sharded LM training.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1p5_0p5b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features (DESIGN.md S6):
  * checkpoint/restart -- atomic manifest+npy checkpoints of (params, opt
    state, step); ``--resume`` restores the latest and continues with
    bit-identical batches (the data pipeline is a pure function of step).
  * elastic restore -- checkpoints re-shard onto whatever mesh the restoring
    job builds (host numpy round-trip), so jobs can scale up/down.
  * grad accumulation -- ``--micro`` splits the global batch; the scan body
    lets XLA overlap microbatch i's gradient reduction with i+1's compute.
  * mesh sharding -- on multi-device hosts (XLA_FLAGS
    --xla_force_host_platform_device_count=N) builds a (data, model) mesh
    and applies the production sharding rules; single-device runs skip it.

This is the end-to-end driver example: ``--arch qwen1p5_0p5b`` full config
at --seq 1024 is a ~0.5B model; ``--smoke`` uses the reduced config (~a few
M params) that trains ~100 steps/minute on CPU.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.distributed import sharding
from repro.launch import mesh as mesh_lib
from repro.models import lm
from repro.training import checkpoint, data, optim


def build_mesh(spec: str):
    """'1x1' -> None (unsharded); 'DxM' -> (data, model) mesh."""
    d, m = (int(x) for x in spec.split("x"))
    if d * m == 1:
        return None
    n_avail = len(jax.devices())
    assert d * m <= n_avail, (
        f"mesh {spec} needs {d*m} devices, have {n_avail}; set XLA_FLAGS="
        f"--xla_force_host_platform_device_count={d*m}")
    return mesh_lib.make_debug_mesh(d, m)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1p5_0p5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=1,
                    help="gradient-accumulation microbatches")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--mesh", default="1x1", help="data x model, e.g. 4x2")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "memmap"])
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--f32", action="store_true",
                    help="train in float32 (CPU-friendly)")
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    if args.f32:
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  compute_dtype="float32")
    mesh = build_mesh(args.mesh)

    opt = optim.Adam(
        lr=optim.cosine_schedule(args.lr, args.warmup, args.steps),
        weight_decay=0.01, clip_norm=1.0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.1f}M "
          f"mesh={args.mesh} batch={args.batch}x{args.seq} "
          f"micro={args.micro}", flush=True)

    dcfg = data.DataConfig(seq_len=args.seq, global_batch=args.batch,
                           vocab_size=cfg.vocab_size, source=args.data,
                           path=args.data_path)
    ds = data.make_dataset(dcfg)

    pol = lm.NO_SHARDING
    batch_shd = None
    if mesh is not None:
        params = jax.device_put(params, sharding.tree_shardings(mesh, params))
        opt_state = jax.device_put(
            opt_state, sharding.tree_shardings(mesh, opt_state))
        pol = sharding.make_policy(mesh, batch=args.batch, kind="train")
        batch_shd = sharding.batch_sharding(mesh, args.batch)

    start_step = 0
    if args.resume and args.ckpt_dir:
        try:
            (params, opt_state), start_step, meta = checkpoint.restore(
                args.ckpt_dir, (params, opt_state))
            print(f"resumed from step {start_step}", flush=True)
        except FileNotFoundError:
            print("no checkpoint found; starting fresh", flush=True)

    step_fn = functools.partial(lm.train_step_accum, cfg=cfg, optimizer=opt,
                                n_micro=args.micro, pol=pol)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))

    losses, t0 = [], time.time()
    saver, last_saved = None, -1
    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        for step in range(start_step, args.steps):
            batch = data.device_batch(ds.batch(step), batch_shd)
            params, opt_state, loss = jstep(params, opt_state, batch)
            losses.append(float(loss))
            if (step + 1) % args.log_every == 0:
                dt = time.time() - t0
                tok_s = args.log_every * args.batch * args.seq / dt
                print(f"step {step+1:5d}  loss {np.mean(losses[-args.log_every:]):.4f}"
                      f"  {tok_s:,.0f} tok/s", flush=True)
                t0 = time.time()
            if (args.ckpt_dir and (step + 1) % args.ckpt_every == 0):
                saver = checkpoint.save(
                    args.ckpt_dir, step + 1, (params, opt_state),
                    meta={"loss": float(loss)}, blocking=False)
                last_saved = step + 1
    if saver is not None:
        saver.join()  # never race the async writer with the final save
    if args.ckpt_dir and last_saved != args.steps:
        checkpoint.save(args.ckpt_dir, args.steps, (params, opt_state),
                        meta={"loss": float(losses[-1])})
    summary = {"final_loss": float(np.mean(losses[-10:])),
               "first_loss": float(np.mean(losses[:10])),
               "steps": args.steps, "steps_run": len(losses)}
    print(json.dumps(summary), flush=True)
    # Loss must improve -- but a short resume window (< 20 fresh steps)
    # cannot distinguish first from final; treat completion as success.
    if summary["steps_run"] < 20:
        return 0
    return 0 if summary["final_loss"] < summary["first_loss"] else 1


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    sys.exit(main())
