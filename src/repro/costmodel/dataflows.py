"""Dataflow styles and the coarse (L-level) action tables.

Three dataflow styles from the paper (SII, SIV-A2):

  * NVDLA-style (``dla``)     : weight-stationary; parallelizes K (output
                                channels) and C (input channels); each PE
                                holds ``kt`` filters.
  * Eyeriss-style (``eye``)   : row-stationary; parallelizes Y (output rows)
                                and R (filter rows); each PE runs 1-D row
                                convolutions for ``kt`` filters.
  * ShiDianNao-style (``shi``): output-stationary; parallelizes Y and X
                                (output pixels); each PE accumulates ``kt``
                                output channels of its pixel.

The coarse action space is the paper's Table I: L=12 level values for PEs and
for the per-PE tile count ``kt`` (which determines the L1 buffer size via the
dataflow's buffer formula -- e.g. NVDLA with 3x3 filters gives
9*kt + 9 + kt = 19,29,...,129 bytes, exactly Table I's buffer row).

Table IX ablates L in {10, 12, 14}; ``pe_levels(L)`` / ``kt_levels(L)``
provide those tables.
"""
from __future__ import annotations

import numpy as np

DLA = 0
EYE = 1
SHI = 2
NUM_DATAFLOWS = 3
DATAFLOW_NAMES = ("dla", "eye", "shi")

_PE_TABLES = {
    10: [1, 2, 4, 8, 16, 24, 32, 48, 64, 128],
    # Paper Table I.
    12: [1, 2, 4, 8, 12, 16, 24, 32, 48, 64, 96, 128],
    14: [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128],
}


def pe_levels(L: int = 12) -> np.ndarray:
    """PE count at each of the L coarse action levels."""
    if L not in _PE_TABLES:
        raise ValueError(f"unsupported action-level count L={L}")
    return np.asarray(_PE_TABLES[L], dtype=np.int32)


def kt_levels(L: int = 12) -> np.ndarray:
    """Per-PE tile count (filters resident per PE) at each level: 1..L."""
    if L not in _PE_TABLES:
        raise ValueError(f"unsupported action-level count L={L}")
    return np.arange(1, L + 1, dtype=np.int32)


PE_LEVELS = pe_levels(12)
KT_LEVELS = kt_levels(12)

# Fine-grained (second-stage GA) bounds: raw integers, SIII-G.
PE_MIN, PE_MAX = 1, 160
KT_MIN, KT_MAX = 1, 16


def l1_bytes_by_style(kt, R, S):
    """Per-style L1 buffer bytes per PE: ``(dla, eye, shi)`` formulas.

    dla: kt filters (kt*R*S) + one input patch (R*S) + kt partial outputs
         -> kt*R*S + R*S + kt     (Table I for R=S=3: 19..129)
    eye: kt filter rows (kt*S)   + one input row window (S) + kt psum rows
         -> kt*S + S + kt
    shi: one filter (R*S) + kt psums + kt-neighbourhood of inputs
         -> R*S + 2*kt

    The shared dataflow-term primitive behind both selections: the hard
    model picks one formula by integer id (:func:`l1_bytes_formula`), the
    soft model blends all three with its dataflow simplex weights.  Each
    formula is linear in ``kt``, hence already smooth.
    """
    rs = R * S
    dla_b = kt * rs + rs + kt
    eye_b = kt * S + S + kt
    shi_b = rs + 2 * kt
    return dla_b, eye_b, shi_b


def l1_bytes_formula(dataflow, kt, R, S):
    """L1 buffer bytes per PE for an integer dataflow id (hard selection).

    ``dataflow`` may be a scalar or an array (broadcast, branch-free) so the
    MIX co-automation agent can treat it as a third per-layer action.
    """
    import jax.numpy as jnp  # local import keeps module importable w/o jax

    dla_b, eye_b, shi_b = l1_bytes_by_style(kt, R, S)
    df = jnp.asarray(dataflow)
    return jnp.where(df == DLA, dla_b, jnp.where(df == EYE, eye_b, shi_b))
