"""MAESTRO-style analytical DNN-accelerator cost model (the ConfuciuX Env).

Public API:
  LayerSpec / layers_to_array   -- workload descriptors
  evaluate / evaluate_batch     -- latency/energy/area/power for design points
  soft_evaluate / soft_model_cost -- differentiable relaxation (see maestro)
  content_hash                  -- cache-versioning hash of the model sources
  PE_LEVELS / KT_LEVELS         -- the paper's L=12 coarse action tables
  workloads                     -- paper DNNs + assigned-architecture lowering
"""
from repro.costmodel.layers import (
    LayerSpec,
    layers_to_array,
    CONV,
    DWCONV,
    GEMM,
    NUM_FIELDS,
)
from repro.costmodel.dataflows import (
    DLA,
    EYE,
    SHI,
    DATAFLOW_NAMES,
    pe_levels,
    kt_levels,
    PE_LEVELS,
    KT_LEVELS,
)
from repro.costmodel.maestro import (
    CostOut,
    content_hash,
    evaluate,
    evaluate_point,
    model_cost,
    soft_evaluate,
    soft_model_cost,
)

__all__ = [
    "LayerSpec",
    "layers_to_array",
    "CONV",
    "DWCONV",
    "GEMM",
    "NUM_FIELDS",
    "DLA",
    "EYE",
    "SHI",
    "DATAFLOW_NAMES",
    "pe_levels",
    "kt_levels",
    "PE_LEVELS",
    "KT_LEVELS",
    "CostOut",
    "content_hash",
    "evaluate",
    "evaluate_point",
    "model_cost",
    "soft_evaluate",
    "soft_model_cost",
]
