"""Layer (workload) descriptors for the cost model.

A layer is described exactly as in the paper's observation space (SIII-B):

    (K, C, Y, X, R, S, type)

  * CONV    : K output channels, C input channels, YxX input activation,
              RxS filter kernel.
  * DWCONV  : depth-wise convolution; K == C groups, each group is a single
              2-D convolution (no channel reduction).
  * GEMM    : an (M, N, Kg) matmul -- (M,Kg) x (Kg,N) -> (M,N) -- encoded per
              the paper's footnote 3.  We map it onto the conv descriptor as
                  K  = N   (output features ~ filters)
                  C  = Kg  (reduction dim  ~ input channels)
                  Y  = M   (tokens / rows  ~ activation rows), X = 1
                  R  = S = 1
              so Y' = M, X' = 1 and total MACs = M*N*Kg.

We additionally carry a ``repeat`` field: the number of *identical* hardware
instances of this layer (e.g. the E experts of an MoE block, or consecutive
identical transformer blocks).  One RL action covers the whole group; latency,
energy, area and power scale by ``repeat`` (each instance receives the same
(PE, Buf) assignment -- this keeps episode lengths tractable for 90+ layer
LLMs while remaining faithful to the paper's per-layer formulation, where
every group member *is* the same layer shape).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Layer types.
CONV = 0
DWCONV = 1
GEMM = 2

# Descriptor array column layout.
F_K, F_C, F_Y, F_X, F_R, F_S, F_TYPE, F_REPEAT = range(8)
NUM_FIELDS = 8


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Host-side layer descriptor (converted to an int array for the Env)."""

    K: int
    C: int
    Y: int
    X: int
    R: int
    S: int
    type: int = CONV
    repeat: int = 1
    name: str = ""

    @staticmethod
    def conv(K: int, C: int, Y: int, X: int, R: int, S: int, *, repeat: int = 1,
             name: str = "") -> "LayerSpec":
        return LayerSpec(K, C, Y, X, R, S, CONV, repeat, name)

    @staticmethod
    def dwconv(C: int, Y: int, X: int, R: int, S: int, *, repeat: int = 1,
               name: str = "") -> "LayerSpec":
        # K == C for depth-wise.
        return LayerSpec(C, C, Y, X, R, S, DWCONV, repeat, name)

    @staticmethod
    def gemm(M: int, N: int, Kg: int, *, repeat: int = 1,
             name: str = "") -> "LayerSpec":
        """(M,Kg) x (Kg,N): K=N, C=Kg, Y=M, X=1, R=S=1."""
        return LayerSpec(N, Kg, M, 1, 1, 1, GEMM, repeat, name)

    def macs(self) -> int:
        yp = max(self.Y - self.R + 1, 1)
        xp = max(self.X - self.S + 1, 1)
        if self.type == DWCONV:
            return self.C * yp * xp * self.R * self.S * self.repeat
        return self.K * self.C * yp * xp * self.R * self.S * self.repeat

    def as_row(self) -> np.ndarray:
        return np.array(
            [self.K, self.C, self.Y, self.X, self.R, self.S, self.type,
             self.repeat],
            dtype=np.int32,
        )


def layers_to_array(layers) -> np.ndarray:
    """Stack LayerSpecs into an (N, NUM_FIELDS) int32 array."""
    if len(layers) == 0:
        raise ValueError("empty workload")
    return np.stack([l.as_row() for l in layers], axis=0)


def total_macs(layers) -> int:
    return int(sum(l.macs() for l in layers))
