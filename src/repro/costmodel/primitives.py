"""Plateau-op primitives: one interface, a hard and a soft implementation.

The MAESTRO-style model (:mod:`repro.costmodel.maestro`) owes its landscape
structure to a handful of non-smooth ops -- ``ceil``-division tile counts,
``floor``/``clip`` PE factorizations, hard ``min``/``max`` bottlenecks and
branch gates.  Those same ops are what make the model useless to ``jax.grad``:
their derivatives are zero (plateaus) or undefined (kinks) almost everywhere a
search cares about.

This module factors every such op behind one :class:`Primitives` record with
two implementations sharing the model core:

  * :func:`hard` -- the exact ops, verbatim.  The model core called with these
    primitives is bit-identical to the pre-refactor implementation; it is the
    oracle for ``kernels/ref.py``, the Pallas kernel, and every benchmark.
  * :func:`soft` -- temperature-controlled smooth surrogates.  Every plateau
    op becomes a sigmoid/softplus construction whose gradient is finite and
    non-zero everywhere, and which converges pointwise to the hard op as the
    temperature ``tau -> 0`` (away from the measure-zero jump points).

Soft surrogate cheat-sheet (``tau`` is the shared temperature):

  ceil(x)        -> smoothed unit staircase: ``floor(x) + step(frac(x))`` with
                    a normalized sigmoid step whose center shrinks with tau, so
                    integer inputs (exact tile divisions -- the common case)
                    evaluate to the exact hard value at every temperature.
  max(a, b)      -> ``b + t*softplus((a-b)/t)`` (softplus-clip; >= hard max).
  min(a, b)      -> ``b - t*softplus((b-a)/t)`` (<= hard min; this is the op
                    that frees the buffer-overprovision plateau: the gradient
                    of ``min(kt, K_out)`` w.r.t. ``kt`` stays positive past
                    ``K_out`` instead of snapping to zero).
  clip(x, lo, hi)-> smooth max then smooth min.
  max(a, b, c)   -> p-norm smooth maximum with ``p = 12/tau`` (scale-invariant,
                    overshoot <= 3**(1/p); exact as tau -> 0).
  1{x == v}      -> ``sigmoid((1/2 - |x - v|) / w)`` gate (``is_dw``, dataflow
                    one-hots); sharp by construction, but smooth in x so the
                    soft model is differentiable in *all* of its inputs.
  where(g, a, b) -> convex blend ``g*a + (1-g)*b``.

Everything here is plain jnp: both implementations trace under ``jit``,
``vmap`` and ``grad``, and the hard one also lowers inside Pallas kernels.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

# Floor guard for ceil-division outputs on the soft path: hard ceil-division
# never returns < 1, and letting the relaxation drift toward 0 would collapse
# compute terms to ~0 and fabricate gradient toward meaningless regions.
_GUARD_T = 0.02


class Primitives(NamedTuple):
    """The plateau-op interface shared by the hard and soft model cores."""

    name: str
    ceil_div: Callable    # ceil(a / max(b, 1))       -- tile / step counts
    floor_div: Callable   # floor(a / b)              -- PE factorization
    clip: Callable        # clip(x, lo, hi)           -- parallel-width bounds
    maximum: Callable     # max(a, b)                 -- guards, bottlenecks
    minimum: Callable     # min(a, b)                 -- kt_eff coverage caps
    blend: Callable       # where(g, a, b) with g a {0,1}/[0,1] gate
    clip01: Callable      # clip(x, 0, 1)             -- L2 spill fractions
    max3: Callable        # max(a, b, c)              -- latency bottleneck
    eq_gate: Callable     # 1{x == v} as f32          -- is_dw / dataflow


# ---------------------------------------------------------------------------
# Hard implementation: the exact ops, verbatim from the original model.
# ---------------------------------------------------------------------------
def hard() -> Primitives:
    """Exact plateau ops -- bit-identical to the pre-refactor model."""
    return Primitives(
        name="hard",
        ceil_div=lambda a, b: jnp.ceil(a / jnp.maximum(b, 1.0)),
        floor_div=lambda a, b: jnp.floor(a / b),
        clip=jnp.clip,
        maximum=jnp.maximum,
        minimum=jnp.minimum,
        blend=lambda g, a, b: jnp.where(g > 0, a, b),
        clip01=lambda x: jnp.clip(x, 0.0, 1.0),
        max3=lambda a, b, c: jnp.maximum(jnp.maximum(a, b), c),
        eq_gate=lambda x, v: (x == v).astype(jnp.float32),
    )


HARD = hard()


# ---------------------------------------------------------------------------
# Soft surrogates.
# ---------------------------------------------------------------------------
def soft_ceil(x, tau):
    """Smooth, monotone staircase converging to ``ceil`` as ``tau -> 0``.

    ``floor(x) + step(frac(x))`` where ``step`` is a sigmoid normalized to
    hit exactly 0 at ``frac = 0`` and 1 at ``frac = 1`` (so the staircase is
    continuous across cells and *exact at integer inputs* -- tile counts of
    perfectly divisible dims keep their hard value at any temperature).  The
    step's center tracks ``tau`` toward the left cell edge, matching ceil's
    jump-at-integer semantics in the sharp limit.  The gradient
    ``step'(frac)`` is finite and non-zero everywhere for ``tau > 0``.
    """
    tau = jnp.asarray(tau, jnp.float32)
    c = jnp.clip(0.5 * tau, 0.02, 0.5)          # step center
    w = jnp.clip(0.25 * tau, 0.005, 0.25)       # step width
    f = jnp.floor(x)
    r = x - f
    s = jax.nn.sigmoid((r - c) / w)
    s0 = jax.nn.sigmoid(-c / w)
    s1 = jax.nn.sigmoid((1.0 - c) / w)
    return f + (s - s0) / (s1 - s0)


def soft_floor(x, tau):
    """Smooth floor: the mirrored staircase, ``-soft_ceil(-x, tau)``."""
    return -soft_ceil(-x, tau)


def smooth_max(a, b, t):
    """``>=`` hard max, smooth, with softplus transition of width ``t``."""
    return b + t * jax.nn.softplus((a - b) / t)


def smooth_min(a, b, t):
    """``<=`` hard min, smooth, with softplus transition of width ``t``."""
    return b - t * jax.nn.softplus((b - a) / t)


def smooth_clip(x, lo, hi, t):
    return smooth_min(smooth_max(x, lo, t), hi, t)


def smooth_amax(x, p, axis=-1):
    """Scale-invariant smooth maximum of positives along ``axis``.

    The p-norm ``(sum x^p)^(1/p)`` overshoots the hard max by at most
    ``n**(1/p)``; gradients flow to every element (softmax-like weights).
    The normalization by the stop-gradded hard max is algebraically exact
    (the p-norm is 1-homogeneous), it only keeps ``x**p`` in f32 range.
    """
    m = jax.lax.stop_gradient(
        jnp.maximum(jnp.max(x, axis=axis, keepdims=True), 1e-30))
    s = jnp.sum((x / m) ** p, axis=axis)
    return jnp.squeeze(m, axis) * s ** (1.0 / p)


def soft(tau) -> Primitives:
    """Temperature-``tau`` smooth surrogates of every plateau op.

    ``tau`` may be a traced scalar (the relaxed engine anneals it inside one
    compiled program).  ``tau ~ 1`` gives a heavily smoothed landscape with
    strong gradients everywhere; ``tau -> 0`` recovers the hard ops.
    """
    tau = jnp.asarray(tau, jnp.float32)
    t_guard = jnp.clip(0.1 * tau, 0.01, 0.1)    # lower-bound guards (x >= 1)
    t_clip = jnp.clip(0.25 * tau, 0.01, 0.25)   # spill-fraction clipping
    t_gate = 0.05 * jnp.clip(tau, 0.1, 1.0)     # indicator gates (sharp)
    p = 12.0 / jnp.clip(tau, 1e-3, 1.0)         # latency-bottleneck p-norm

    def ceil_div(a, b):
        raw = soft_ceil(a / smooth_max(b, 1.0, t_guard), tau)
        return smooth_max(raw, 1.0, _GUARD_T)

    def max3(a, b, c):
        return smooth_amax(jnp.stack(
            jnp.broadcast_arrays(a, b, c), axis=-1), p)

    return Primitives(
        name="soft",
        ceil_div=ceil_div,
        floor_div=lambda a, b: soft_floor(a / b, tau),
        clip=lambda x, lo, hi: smooth_clip(x, lo, hi, t_guard),
        maximum=lambda a, b: smooth_max(a, b, t_guard),
        minimum=lambda a, b: smooth_min(a, b, t_guard),
        blend=lambda g, a, b: g * a + (1.0 - g) * b,
        clip01=lambda x: smooth_clip(x, 0.0, 1.0, t_clip),
        max3=max3,
        eq_gate=lambda x, v: jax.nn.sigmoid(
            (0.5 - jnp.abs(jnp.asarray(x, jnp.float32) - v)) / t_gate),
    )
