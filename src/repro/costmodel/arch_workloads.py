"""Lower the assigned architecture configs to cost-model workloads.

This is how the paper's technique becomes a first-class feature for the
model zoo: every ArchConfig lowers to the per-layer (CONV/GEMM) descriptor
list the ConfuciuX Env consumes, so ``launch/search.py --arch qwen3-32b``
searches accelerator resource assignments for serving/training that model.

Lowering conventions (per-layer GEMMs for one forward pass over ``tokens``
token positions):
  * attention: QKV / output projections as GEMMs; score and context batched
    GEMMs folded via ``repeat=heads``.
  * MoE: router GEMM + expert-bank GEMMs with M = tokens * top_k (the routed
    token-slots) and ``repeat=1`` per layer group -- each expert instance is
    one hardware partition in LP.
  * Mamba2/SSD: in/out projections + conv (as CONV descriptor) + the SSD
    intra-chunk matmuls as seq x seq GEMMs per chunk.
  * identical consecutive layers collapse into one entry with ``repeat=L``
    so RL episode lengths stay tractable for 90+ layer models (layers.py).
"""
from __future__ import annotations

from typing import List

from repro import configs
from repro.configs.base import ArchConfig
from repro.costmodel.layers import LayerSpec


def _attn_layers(cfg: ArchConfig, tokens: int, ctx: int, repeat: int,
                 prefix: str) -> List[LayerSpec]:
    d, hd, H, Kv = cfg.d_model, cfg.hd(), cfg.num_heads, cfg.num_kv_heads
    return [
        LayerSpec.gemm(tokens, (H + 2 * Kv) * hd, d, repeat=repeat,
                       name=f"{prefix}.qkv"),
        LayerSpec.gemm(tokens, ctx, hd, repeat=repeat * H,
                       name=f"{prefix}.score"),
        LayerSpec.gemm(tokens, hd, ctx, repeat=repeat * H,
                       name=f"{prefix}.ctx"),
        LayerSpec.gemm(tokens, d, H * hd, repeat=repeat,
                       name=f"{prefix}.out"),
    ]


def _ffn_layers(cfg: ArchConfig, tokens: int, repeat: int,
                prefix: str) -> List[LayerSpec]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.num_experts:
        routed = tokens * cfg.experts_per_token
        out = [LayerSpec.gemm(tokens, cfg.num_experts, d, repeat=repeat,
                              name=f"{prefix}.router")]
        n_mats = 3 if cfg.mlp_act == "swiglu" else 2
        out.append(LayerSpec.gemm(routed, f * (n_mats - 1), d, repeat=repeat,
                                  name=f"{prefix}.experts_up"))
        out.append(LayerSpec.gemm(routed, d, f, repeat=repeat,
                                  name=f"{prefix}.experts_down"))
        return out
    if cfg.mlp_act == "swiglu":
        return [LayerSpec.gemm(tokens, 2 * f, d, repeat=repeat,
                               name=f"{prefix}.up_gate"),
                LayerSpec.gemm(tokens, d, f, repeat=repeat,
                               name=f"{prefix}.down")]
    return [LayerSpec.gemm(tokens, f, d, repeat=repeat,
                           name=f"{prefix}.up"),
            LayerSpec.gemm(tokens, d, f, repeat=repeat,
                           name=f"{prefix}.down")]


def _mamba_layers(cfg: ArchConfig, tokens: int, repeat: int,
                  prefix: str) -> List[LayerSpec]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    S = cfg.ssm_state
    Q = min(cfg.ssm_chunk, tokens)
    nc = max(tokens // Q, 1)
    return [
        LayerSpec.gemm(tokens, 2 * di + 2 * S + H, d, repeat=repeat,
                       name=f"{prefix}.in_proj"),
        LayerSpec.conv(di + 2 * S, 1, tokens + 3, 1, 4, 1, repeat=repeat,
                       name=f"{prefix}.conv1d"),
        # SSD intra-chunk: (Q x Q) score and mix matmuls per chunk.
        LayerSpec.gemm(Q, Q, S, repeat=repeat * nc,
                       name=f"{prefix}.ssd_cb"),
        LayerSpec.gemm(Q, H * P, Q, repeat=repeat * nc,
                       name=f"{prefix}.ssd_mix"),
        LayerSpec.gemm(tokens, d, di, repeat=repeat,
                       name=f"{prefix}.out_proj"),
    ]


def lower_arch(name: str, tokens: int = 1024, ctx: int = None,
               include_unembed: bool = True) -> List[LayerSpec]:
    """Lower an architecture to its serving workload at ``tokens`` positions.

    ctx: attention context length (defaults to tokens -- self-attention over
    the processed window).
    """
    cfg = configs.get(name)
    ctx = ctx or tokens
    out: List[LayerSpec] = []
    fam = cfg.family
    L = cfg.num_layers
    if fam in ("dense", "moe"):
        out += _attn_layers(cfg, tokens, ctx, L, "blk")
        out += _ffn_layers(cfg, tokens, L, "blk")
    elif fam == "ssm":
        out += _mamba_layers(cfg, tokens, L, "blk")
    elif fam == "hybrid":
        sites = L // cfg.shared_attn_period
        out += _mamba_layers(cfg, tokens, L, "ssm")
        out += _attn_layers(cfg, tokens, ctx, sites, "shared")
        out += _ffn_layers(cfg, tokens, sites, "shared")
    elif fam == "audio":
        Se = cfg.encoder_seq
        out += _attn_layers(cfg, Se, Se, cfg.encoder_layers, "enc")
        out += _ffn_layers(cfg, Se, cfg.encoder_layers, "enc")
        out += _attn_layers(cfg, tokens, ctx, L, "dec.self")
        out += _attn_layers(cfg, tokens, Se, L, "dec.cross")
        out += _ffn_layers(cfg, tokens, L, "dec")
    elif fam == "vlm":
        n_cross = L // cfg.cross_attn_period
        n_self = L - n_cross
        out += _attn_layers(cfg, tokens, ctx, n_self, "self")
        out += _ffn_layers(cfg, tokens, n_self, "self")
        out += _attn_layers(cfg, tokens, cfg.vision_seq, n_cross, "cross")
        out += _ffn_layers(cfg, tokens, n_cross, "cross")
    else:
        raise ValueError(fam)
    if include_unembed:
        out.append(LayerSpec.gemm(tokens, cfg.vocab_size, cfg.d_model,
                                  name="unembed"))
    return out


def arch_names() -> List[str]:
    return list(configs.ARCH_IDS)
