"""MAESTRO-style analytical cost model, re-derived as branch-free JAX.

The paper uses MAESTRO [38] as the RL environment: given a layer descriptor,
a dataflow style and a design point (#PEs ``pe``, per-PE tile count ``kt``
which sets the L1 buffer), it returns latency / energy / area / power.  We
re-derive an analytical model with the same interface and the same
*qualitative structure* that the paper's results depend on (Fig. 4/5):

  * ceil-effect plateaus: once PEs exceed the available parallel dims or the
    buffer exceeds the per-PE working set, latency flattens
    (over-provisioning flats in Fig. 5);
  * DWCONV under NVDLA-style gains nothing from more buffer (no channel
    reduction to amortize -- the paper's Layer-23 observation);
  * energy has buffer sweet-spots: bigger L1 raises leakage+access cost but
    cuts execution time; more PEs raise power but can cut energy;
  * latency is *not* monotone in PEs: L2/DRAM bandwidth terms and psum
    collection traffic can grow with the parallel width.

Model structure (per layer, per design point)
---------------------------------------------

Effective dims:  Y' = Y-R+1, X' = X-S+1;  for DWCONV the reduction dim
collapses (C_red = 1) and the independent output dim is the group count
(K_out = C).  GEMM (M,N,Kg) arrives pre-mapped as K=N, C=Kg, Y=M, X=1 (see
``layers.py``).

Each dataflow parallelizes two dims over a (p1, p2) factorization of ``pe``
and tiles output channels by ``kt`` per PE:

                 parallel dims     inner work / PE / step     temporal steps
  dla (NVDLA)    (ceil(K/kt), C)   kt_eff * R*S*Y'*X'         t1 * t2
  eye (Eyeriss)  (Y', R)           kt_eff * S*X'              t1 * t2 * C * Ku
  shi (ShiDianNao)(Y', X')         kt_eff * R*S               t1 * t2 * C * Ku

with Ku = ceil(K_out/kt), t_i = ceil(dim_i/p_i) and
kt_eff = ceil(K_out / (Ku_parallel_coverage)) <= kt.  Once kt >= K_out the
latency is exactly flat (the Fig. 5 over-provisioning plateau: a bigger L1
only costs area/power/leakage).  BELOW that, latency is genuinely
non-monotone in kt -- the tile size is the action and quantization
(ceil-of-coverage) effects are real; the paper's own Fig. 5 shows the same
(two disjoint optimum regions in Layer-34).  1 MAC / PE / cycle.

Traffic (elements; 1 element = 1 byte, int8-style accounting as in Fig. 4's
byte-valued buffers):

  dla: weights fetched once (weight-stationary); activations multicast per
       temporal K-iteration (A * t1); outputs collected with psum width p2.
  eye: weights refetched per temporal row-block (W * t1); activation rows
       refetched per filter-group with halo duplication; psum width p2.
  shi: weights streamed per output tile (W * t1 * t2); activations shared by
       neighbour shifting (halo only); outputs written once.

Latency  = max(compute, L2 traffic / bw_L2(pe), DRAM traffic / bw_DRAM)
           + fill;   bw_L2 grows sublinearly with pe (port contention), which
           is what makes "more PEs" non-free.
Energy   = MAC + L1 + L2 + DRAM access energy + leakage(pe,L1)*latency.
Area/Power = linear models over PEs, L1 bytes, L2 bytes (=2*pe*L1: the
           double-buffered next tile, exactly how the paper sizes L2), NoC.

Absolute numbers are NOT calibrated against the MAESTRO binary (DESIGN.md S5)
-- the paper's claims we reproduce are *relative* search-quality /
sample-efficiency comparisons, which depend on the landscape structure, not
on absolute cycle counts.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.costmodel.dataflows import DLA, EYE, SHI, l1_bytes_formula
from repro.costmodel.layers import (
    F_C,
    F_K,
    F_R,
    F_REPEAT,
    F_S,
    F_TYPE,
    F_X,
    F_Y,
    DWCONV,
)

# ---------------------------------------------------------------------------
# Hardware constants (45nm-era, order-of-magnitude; units documented).
# ---------------------------------------------------------------------------
E_MAC = 1.0          # pJ / MAC
E_L1 = 1.0           # pJ / L1 access (element)
E_L2 = 6.0           # pJ / L2 access (element)
E_DRAM = 200.0       # pJ / DRAM access (element)
L1_ACC_PER_MAC = 3.0  # weight + act read + psum rmw

P_MAC_MW = 1.0       # mW / PE (dynamic, peak)
P_L1_MW_B = 0.005    # mW / L1 byte
P_L2_MW_B = 0.002    # mW / L2 byte
P_NOC_MW_PE = 0.1    # mW / PE of NoC

LEAK_PE_MW = 0.05    # mW leakage / PE
LEAK_L1_MW_B = 0.001  # mW leakage / L1 byte

A_MAC_UM2 = 2000.0   # um^2 / PE (MAC + control)
A_L1_UM2_B = 50.0    # um^2 / L1 byte
A_L2_UM2_B = 25.0    # um^2 / L2 byte
A_NOC_UM2_PE = 300.0  # um^2 / PE of NoC

DRAM_BW = 16.0       # elements / cycle
L2_BW_BASE = 8.0     # elements / cycle
L2_BW_SQRT = 8.0     # + L2_BW_SQRT * sqrt(pe)
FILL_CYCLES = 20.0   # pipeline fill


class CostOut(NamedTuple):
    """Per-layer (or aggregated) cost estimates."""

    latency: jnp.ndarray   # cycles
    energy: jnp.ndarray    # nJ
    area: jnp.ndarray      # um^2
    power: jnp.ndarray     # mW (peak)
    l1_bytes: jnp.ndarray  # per-PE L1 buffer
    l2_bytes: jnp.ndarray  # shared L2
    macs: jnp.ndarray      # true MACs of the layer
    util: jnp.ndarray      # MACs / (latency * pe)


def _ceil_div(a, b):
    return jnp.ceil(a / jnp.maximum(b, 1.0))


def _factorize(pe, d1, d2):
    """Split ``pe`` PEs over two parallel dims (d1 outer): p1*p2 <= pe."""
    p1 = jnp.clip(pe, 1.0, jnp.maximum(d1, 1.0))
    p2 = jnp.clip(jnp.floor(pe / p1), 1.0, jnp.maximum(d2, 1.0))
    return p1, p2


def _dataflow_terms(df_is, is_dw, K_out, C_red, Yp, Xp, R, S, pe, kt,
                    W_u, A_u, O_u):
    """compute cycles + (W, A, O) L2 traffic for one dataflow style.

    ``df_is`` selects the style branch-free via weights in {0,1}.
    Returns (compute_cycles, l2_traffic) for the *selected* style.

    DWCONV activations: output channel k reads ONLY input channel k, so
    temporal K-iterations touch *disjoint* activation slices -- the total
    activation traffic is A_u once, not A_u x #passes.  (Regular conv: every
    output channel reduces over all C input channels, so each temporal K
    block re-reads the full A_u.)  This is what makes DWCONV indifferent to
    the tile size under NVDLA-style -- the paper's Layer-23 observation.
    """
    is_dla, is_eye, is_shi = df_is
    Ku = _ceil_div(K_out, kt)

    # ---- dla: parallel (Ku, C_red) --------------------------------------
    p1d, p2d = _factorize(pe, Ku, C_red)
    t1d = _ceil_div(Ku, p1d)
    t2d = _ceil_div(C_red, p2d)
    kt_eff_d = jnp.minimum(kt, _ceil_div(K_out, p1d * t1d))
    comp_dla = t1d * t2d * kt_eff_d * R * S * Yp * Xp
    a_passes_dla = jnp.where(is_dw > 0, 1.0, t1d)   # disjoint dw channels
    l2_dla = (W_u                      # weight-stationary: once
              + A_u * a_passes_dla     # activation multicast / K-iteration
              + O_u * p2d)             # psum collection width

    # ---- eye: parallel (Y', R); temporal over C and Ku -------------------
    p1e, p2e = _factorize(pe, Yp, R)
    t1e = _ceil_div(Yp, p1e)
    t2e = _ceil_div(R, p2e)
    kt_eff_e = jnp.minimum(kt, K_out)
    comp_eye = t1e * t2e * C_red * Ku * kt_eff_e * S * Xp
    halo_e = (p1e + R - 1.0) / jnp.maximum(p1e, 1.0)
    a_passes_eye = jnp.where(is_dw > 0, 1.0, Ku)    # disjoint dw channels
    l2_eye = (W_u * t1e                # rows re-staged per temporal block
              + A_u * a_passes_eye * halo_e  # per filter-group + row halo
              + O_u * p2e)

    # ---- shi: parallel (Y', X'); temporal over C and Ku ------------------
    p1s, p2s = _factorize(pe, Yp, Xp)
    t1s = _ceil_div(Yp, p1s)
    t2s = _ceil_div(Xp, p2s)
    kt_eff_s = jnp.minimum(kt, K_out)
    comp_shi = t1s * t2s * C_red * Ku * kt_eff_s * R * S
    halo_s = ((p1s + R - 1.0) * (p2s + S - 1.0)) / jnp.maximum(p1s * p2s, 1.0)
    l2_shi = (W_u * t1s * t2s          # weights streamed per output tile
              + A_u * halo_s           # neighbour-shift reuse, halo only
              + O_u)

    comp = is_dla * comp_dla + is_eye * comp_eye + is_shi * comp_shi
    l2 = is_dla * l2_dla + is_eye * l2_eye + is_shi * l2_shi
    # Outer passes over the weight / activation tensors (DRAM refetch when
    # the L2 cannot capture the reuse): dla re-touches activations per
    # temporal K-iteration; eye re-touches weights per row-block and
    # activations per filter-group; shi re-streams weights per output tile.
    passes_w = is_dla * 1.0 + is_eye * t1e + is_shi * (t1s * t2s)
    passes_a = is_dla * a_passes_dla + is_eye * a_passes_eye + is_shi * 1.0
    return comp, l2, passes_w, passes_a


def core_cost(K, C, Y, X, R, S, ltype, repeat, pe, kt, df):
    """The model core on unpacked float32 field arrays (broadcastable).

    Shared verbatim between the pure-jnp oracle (:func:`evaluate`, which is
    ``kernels/ref.py``'s ground truth) and the Pallas TPU kernel
    (``kernels/costmodel_eval.py``) -- both lower exactly these ops.
    """
    pe = jnp.maximum(pe, 1.0)
    kt = jnp.maximum(kt, 1.0)
    is_dla = (df == DLA).astype(jnp.float32)
    is_eye = (df == EYE).astype(jnp.float32)
    is_shi = (df == SHI).astype(jnp.float32)

    Yp = jnp.maximum(Y - R + 1.0, 1.0)
    Xp = jnp.maximum(X - S + 1.0, 1.0)
    is_dw = (ltype == DWCONV).astype(jnp.float32)
    C_red = jnp.where(is_dw > 0, 1.0, C)     # reduction channels
    K_out = jnp.where(is_dw > 0, C, K)       # independent output dims

    macs = K_out * C_red * Yp * Xp * R * S
    W_u = K_out * C_red * R * S              # unique weights
    A_u = C * Y * X                          # unique activations
    O_u = K_out * Yp * Xp                    # unique outputs

    comp, l2_traffic, passes_w, passes_a = _dataflow_terms(
        (is_dla, is_eye, is_shi), is_dw, K_out, C_red, Yp, Xp, R, S, pe, kt,
        W_u, A_u, O_u)

    l1_bytes = l1_bytes_formula(df, kt, R, S)
    l2_bytes = 2.0 * pe * l1_bytes

    # DRAM refetch: an outer pass re-reads its tensor from DRAM only for the
    # fraction that spilled out of L2 (spill -> refetch ~ #passes; tensor
    # resident -> single streaming read).  This is what makes small-buffer
    # designs energy-catastrophic (Fig. 4's 2-orders-of-magnitude spread).
    spill_w = jnp.clip(1.0 - l2_bytes / jnp.maximum(W_u, 1.0), 0.0, 1.0)
    spill_a = jnp.clip(1.0 - l2_bytes / jnp.maximum(A_u, 1.0), 0.0, 1.0)
    dram_traffic = (W_u * (1.0 + (passes_w - 1.0) * spill_w)
                    + A_u * (1.0 + (passes_a - 1.0) * spill_a)
                    + O_u)
    l2_bw = L2_BW_BASE + L2_BW_SQRT * jnp.sqrt(pe)
    lat = (jnp.maximum(jnp.maximum(comp, l2_traffic / l2_bw),
                       dram_traffic / DRAM_BW)
           + jnp.sqrt(pe) + FILL_CYCLES)

    leak_mw = LEAK_PE_MW * pe + LEAK_L1_MW_B * l1_bytes * pe
    energy_pj = (E_MAC * macs
                 + E_L1 * (L1_ACC_PER_MAC * macs + l2_traffic)
                 + E_L2 * l2_traffic
                 + E_DRAM * dram_traffic
                 + leak_mw * lat)            # 1 mW * 1 cycle @1GHz = 1 pJ

    area = (A_MAC_UM2 * pe + A_L1_UM2_B * l1_bytes * pe
            + A_L2_UM2_B * l2_bytes + A_NOC_UM2_PE * pe)
    power = (P_MAC_MW * pe + P_L1_MW_B * l1_bytes * pe
             + P_L2_MW_B * l2_bytes + P_NOC_MW_PE * pe)

    return CostOut(
        latency=lat * repeat,
        energy=(energy_pj * repeat) * 1e-3,  # pJ -> nJ
        area=area * repeat,
        power=power * repeat,
        l1_bytes=l1_bytes,
        l2_bytes=l2_bytes,
        macs=macs * repeat,
        util=macs / jnp.maximum(comp * pe, 1.0),
    )


def evaluate(layers, pe, kt, dataflow):
    """Evaluate design points against layers.  Fully broadcastable.

    Args:
      layers:   (..., NUM_FIELDS) int/float array of layer descriptors.
      pe:       (...,) #PEs   >= 1.
      kt:       (...,) per-PE tile count >= 1.
      dataflow: (...,) in {DLA, EYE, SHI} (scalar or per-layer for MIX).

    Returns CostOut of broadcast shape; all values are per-layer *including*
    the ``repeat`` multiplicity (latency/energy/area/power all scale by it:
    repeated identical layers are separate pipeline partitions with tied
    assignments -- see layers.py).
    """
    layers = jnp.asarray(layers)
    f = lambda i: layers[..., i].astype(jnp.float32)
    return core_cost(
        f(F_K), f(F_C), f(F_Y), f(F_X), f(F_R), f(F_S),
        f(F_TYPE), f(F_REPEAT),
        jnp.asarray(pe, jnp.float32), jnp.asarray(kt, jnp.float32),
        jnp.asarray(dataflow))


def evaluate_point(layer_row, pe, kt, dataflow):
    """Single layer x single design point (still jit-friendly)."""
    return evaluate(layer_row, pe, kt, dataflow)


def model_cost(layers, pe, kt, dataflow, scenario: str = "LP"):
    """Aggregate whole-model cost for a per-layer assignment.

    scenario "LP": every layer is its own partition -> latency/energy/area/
                   power all sum over layers.
    scenario "LS": one shared accelerator -> latency/energy sum (layers run
                   sequentially) but area/power are the max over layers (the
                   single design must provision for the largest demand).
    """
    out = evaluate(layers, pe, kt, dataflow)
    lat = jnp.sum(out.latency, axis=-1)
    en = jnp.sum(out.energy, axis=-1)
    if scenario == "LP":
        area = jnp.sum(out.area, axis=-1)
        power = jnp.sum(out.power, axis=-1)
    elif scenario == "LS":
        area = jnp.max(out.area, axis=-1)
        power = jnp.max(out.power, axis=-1)
    else:
        raise ValueError(f"unknown scenario {scenario!r}")
    return CostOut(lat, en, area, power,
                   jnp.max(out.l1_bytes, axis=-1),
                   jnp.max(out.l2_bytes, axis=-1),
                   jnp.sum(out.macs, axis=-1),
                   jnp.mean(out.util, axis=-1))
