"""MAESTRO-style analytical cost model, re-derived as branch-free JAX.

The paper uses MAESTRO [38] as the RL environment: given a layer descriptor,
a dataflow style and a design point (#PEs ``pe``, per-PE tile count ``kt``
which sets the L1 buffer), it returns latency / energy / area / power.  We
re-derive an analytical model with the same interface and the same
*qualitative structure* that the paper's results depend on (Fig. 4/5):

  * ceil-effect plateaus: once PEs exceed the available parallel dims or the
    buffer exceeds the per-PE working set, latency flattens
    (over-provisioning flats in Fig. 5);
  * DWCONV under NVDLA-style gains nothing from more buffer (no channel
    reduction to amortize -- the paper's Layer-23 observation);
  * energy has buffer sweet-spots: bigger L1 raises leakage+access cost but
    cuts execution time; more PEs raise power but can cut energy;
  * latency is *not* monotone in PEs: L2/DRAM bandwidth terms and psum
    collection traffic can grow with the parallel width.

Model structure (per layer, per design point)
---------------------------------------------

Effective dims:  Y' = Y-R+1, X' = X-S+1;  for DWCONV the reduction dim
collapses (C_red = 1) and the independent output dim is the group count
(K_out = C).  GEMM (M,N,Kg) arrives pre-mapped as K=N, C=Kg, Y=M, X=1 (see
``layers.py``).

Each dataflow parallelizes two dims over a (p1, p2) factorization of ``pe``
and tiles output channels by ``kt`` per PE:

                 parallel dims     inner work / PE / step     temporal steps
  dla (NVDLA)    (ceil(K/kt), C)   kt_eff * R*S*Y'*X'         t1 * t2
  eye (Eyeriss)  (Y', R)           kt_eff * S*X'              t1 * t2 * C * Ku
  shi (ShiDianNao)(Y', X')         kt_eff * R*S               t1 * t2 * C * Ku

with Ku = ceil(K_out/kt), t_i = ceil(dim_i/p_i) and
kt_eff = ceil(K_out / (Ku_parallel_coverage)) <= kt.  Once kt >= K_out the
latency is exactly flat (the Fig. 5 over-provisioning plateau: a bigger L1
only costs area/power/leakage).  BELOW that, latency is genuinely
non-monotone in kt -- the tile size is the action and quantization
(ceil-of-coverage) effects are real; the paper's own Fig. 5 shows the same
(two disjoint optimum regions in Layer-34).  1 MAC / PE / cycle.

Traffic (elements; 1 element = 1 byte, int8-style accounting as in Fig. 4's
byte-valued buffers):

  dla: weights fetched once (weight-stationary); activations multicast per
       temporal K-iteration (A * t1); outputs collected with psum width p2.
  eye: weights refetched per temporal row-block (W * t1); activation rows
       refetched per filter-group with halo duplication; psum width p2.
  shi: weights streamed per output tile (W * t1 * t2); activations shared by
       neighbour shifting (halo only); outputs written once.

Latency  = max(compute, L2 traffic / bw_L2(pe), DRAM traffic / bw_DRAM)
           + fill;   bw_L2 grows sublinearly with pe (port contention), which
           is what makes "more PEs" non-free.
Energy   = MAC + L1 + L2 + DRAM access energy + leakage(pe,L1)*latency.
Area/Power = linear models over PEs, L1 bytes, L2 bytes (=2*pe*L1: the
           double-buffered next tile, exactly how the paper sizes L2), NoC.

Absolute numbers are NOT calibrated against the MAESTRO binary (DESIGN.md S5)
-- the paper's claims we reproduce are *relative* search-quality /
sample-efficiency comparisons, which depend on the landscape structure, not
on absolute cycle counts.

Hard / soft split
-----------------

The model core is parameterized over the plateau-op primitives of
:mod:`repro.costmodel.primitives`:

  * the **hard** path (:func:`core_cost` / :func:`evaluate` /
    :func:`model_cost`, ``prims=HARD``) lowers the exact ``ceil``/``floor``/
    ``where`` ops, bit-identical to the pre-split implementation -- it is the
    oracle for ``kernels/ref.py``, the Pallas kernel and every benchmark;
  * the **soft** path (:func:`soft_core_cost` / :func:`soft_evaluate` /
    :func:`soft_model_cost`) runs the SAME dataflow-term math with
    temperature-controlled smooth surrogates and a dataflow *simplex*
    (weights over dla/eye/shi instead of an integer id), so
    ``jax.grad`` of latency/energy/EDP w.r.t. continuous per-layer
    ``(pe, kt)`` and the dataflow weights is finite and non-zero everywhere
    -- including on the hard model's over-provisioning plateaus.  The
    ``relaxed`` one-shot engine (:mod:`repro.core.relaxed`) descends it
    directly.
"""
from __future__ import annotations

import functools
import hashlib
from typing import NamedTuple

import jax.numpy as jnp

from repro.costmodel import primitives as prim_lib
from repro.costmodel.dataflows import (
    DLA,
    EYE,
    SHI,
    l1_bytes_by_style,
    l1_bytes_formula,
)
from repro.costmodel.layers import (
    F_C,
    F_K,
    F_R,
    F_REPEAT,
    F_S,
    F_TYPE,
    F_X,
    F_Y,
    DWCONV,
)

HARD = prim_lib.HARD

# ---------------------------------------------------------------------------
# Hardware constants (45nm-era, order-of-magnitude; units documented).
# ---------------------------------------------------------------------------
E_MAC = 1.0          # pJ / MAC
E_L1 = 1.0           # pJ / L1 access (element)
E_L2 = 6.0           # pJ / L2 access (element)
E_DRAM = 200.0       # pJ / DRAM access (element)
L1_ACC_PER_MAC = 3.0  # weight + act read + psum rmw

P_MAC_MW = 1.0       # mW / PE (dynamic, peak)
P_L1_MW_B = 0.005    # mW / L1 byte
P_L2_MW_B = 0.002    # mW / L2 byte
P_NOC_MW_PE = 0.1    # mW / PE of NoC

LEAK_PE_MW = 0.05    # mW leakage / PE
LEAK_L1_MW_B = 0.001  # mW leakage / L1 byte

A_MAC_UM2 = 2000.0   # um^2 / PE (MAC + control)
A_L1_UM2_B = 50.0    # um^2 / L1 byte
A_L2_UM2_B = 25.0    # um^2 / L2 byte
A_NOC_UM2_PE = 300.0  # um^2 / PE of NoC

DRAM_BW = 16.0       # elements / cycle
L2_BW_BASE = 8.0     # elements / cycle
L2_BW_SQRT = 8.0     # + L2_BW_SQRT * sqrt(pe)
FILL_CYCLES = 20.0   # pipeline fill


class CostOut(NamedTuple):
    """Per-layer (or aggregated) cost estimates."""

    latency: jnp.ndarray   # cycles
    energy: jnp.ndarray    # nJ
    area: jnp.ndarray      # um^2
    power: jnp.ndarray     # mW (peak)
    l1_bytes: jnp.ndarray  # per-PE L1 buffer
    l2_bytes: jnp.ndarray  # shared L2
    macs: jnp.ndarray      # true MACs of the layer
    util: jnp.ndarray      # MACs / (latency * pe)


def _ceil_div(a, b):
    return jnp.ceil(a / jnp.maximum(b, 1.0))


def _factorize(pe, d1, d2, prims=HARD):
    """Split ``pe`` PEs over two parallel dims (d1 outer): p1*p2 <= pe."""
    p1 = prims.clip(pe, 1.0, prims.maximum(d1, 1.0))
    p2 = prims.clip(prims.floor_div(pe, p1), 1.0, prims.maximum(d2, 1.0))
    return p1, p2


def _dataflow_terms(df_is, is_dw, K_out, C_red, Yp, Xp, R, S, pe, kt,
                    W_u, A_u, O_u, prims=HARD):
    """compute cycles + (W, A, O) L2 traffic for one dataflow style.

    ``df_is`` selects the style branch-free via weights: exact one-hots on
    the hard path, a simplex on the soft path (every term below is already
    a convex combination over styles, so the relaxation reuses it verbatim).
    Returns (compute_cycles, l2_traffic) for the *selected* style.

    DWCONV activations: output channel k reads ONLY input channel k, so
    temporal K-iterations touch *disjoint* activation slices -- the total
    activation traffic is A_u once, not A_u x #passes.  (Regular conv: every
    output channel reduces over all C input channels, so each temporal K
    block re-reads the full A_u.)  This is what makes DWCONV indifferent to
    the tile size under NVDLA-style -- the paper's Layer-23 observation.
    """
    is_dla, is_eye, is_shi = df_is
    cdiv = prims.ceil_div
    Ku = cdiv(K_out, kt)

    # ---- dla: parallel (Ku, C_red) --------------------------------------
    p1d, p2d = _factorize(pe, Ku, C_red, prims)
    t1d = cdiv(Ku, p1d)
    t2d = cdiv(C_red, p2d)
    kt_eff_d = prims.minimum(kt, cdiv(K_out, p1d * t1d))
    comp_dla = t1d * t2d * kt_eff_d * R * S * Yp * Xp
    a_passes_dla = prims.blend(is_dw, 1.0, t1d)     # disjoint dw channels
    l2_dla = (W_u                      # weight-stationary: once
              + A_u * a_passes_dla     # activation multicast / K-iteration
              + O_u * p2d)             # psum collection width

    # ---- eye: parallel (Y', R); temporal over C and Ku -------------------
    p1e, p2e = _factorize(pe, Yp, R, prims)
    t1e = cdiv(Yp, p1e)
    t2e = cdiv(R, p2e)
    kt_eff_e = prims.minimum(kt, K_out)
    comp_eye = t1e * t2e * C_red * Ku * kt_eff_e * S * Xp
    halo_e = (p1e + R - 1.0) / prims.maximum(p1e, 1.0)
    a_passes_eye = prims.blend(is_dw, 1.0, Ku)      # disjoint dw channels
    l2_eye = (W_u * t1e                # rows re-staged per temporal block
              + A_u * a_passes_eye * halo_e  # per filter-group + row halo
              + O_u * p2e)

    # ---- shi: parallel (Y', X'); temporal over C and Ku ------------------
    p1s, p2s = _factorize(pe, Yp, Xp, prims)
    t1s = cdiv(Yp, p1s)
    t2s = cdiv(Xp, p2s)
    kt_eff_s = prims.minimum(kt, K_out)
    comp_shi = t1s * t2s * C_red * Ku * kt_eff_s * R * S
    halo_s = ((p1s + R - 1.0) * (p2s + S - 1.0)) / prims.maximum(
        p1s * p2s, 1.0)
    l2_shi = (W_u * t1s * t2s          # weights streamed per output tile
              + A_u * halo_s           # neighbour-shift reuse, halo only
              + O_u)

    comp = is_dla * comp_dla + is_eye * comp_eye + is_shi * comp_shi
    l2 = is_dla * l2_dla + is_eye * l2_eye + is_shi * l2_shi
    # Outer passes over the weight / activation tensors (DRAM refetch when
    # the L2 cannot capture the reuse): dla re-touches activations per
    # temporal K-iteration; eye re-touches weights per row-block and
    # activations per filter-group; shi re-streams weights per output tile.
    passes_w = is_dla * 1.0 + is_eye * t1e + is_shi * (t1s * t2s)
    passes_a = is_dla * a_passes_dla + is_eye * a_passes_eye + is_shi * 1.0
    return comp, l2, passes_w, passes_a


def _gated_cost(K, C, Y, X, R, S, repeat, pe, kt, df_w, is_dw, l1_bytes,
                prims):
    """The shared model body below the gates: one set of dataflow-term math.

    ``df_w = (w_dla, w_eye, w_shi)`` are style weights (exact one-hots on the
    hard path, a simplex on the soft path); ``is_dw`` the depthwise gate;
    ``l1_bytes`` the style-selected L1 size (nested-``where`` hard, weighted
    blend soft).  Every plateau op routes through ``prims``; data-side shape
    arithmetic (Yp/Xp/macs/traffic volumes) is smooth already and stays
    shared verbatim.
    """
    Yp = jnp.maximum(Y - R + 1.0, 1.0)
    Xp = jnp.maximum(X - S + 1.0, 1.0)
    C_red = prims.blend(is_dw, 1.0, C)       # reduction channels
    K_out = prims.blend(is_dw, C, K)         # independent output dims

    macs = K_out * C_red * Yp * Xp * R * S
    W_u = K_out * C_red * R * S              # unique weights
    A_u = C * Y * X                          # unique activations
    O_u = K_out * Yp * Xp                    # unique outputs

    comp, l2_traffic, passes_w, passes_a = _dataflow_terms(
        df_w, is_dw, K_out, C_red, Yp, Xp, R, S, pe, kt,
        W_u, A_u, O_u, prims)

    l2_bytes = 2.0 * pe * l1_bytes

    # DRAM refetch: an outer pass re-reads its tensor from DRAM only for the
    # fraction that spilled out of L2 (spill -> refetch ~ #passes; tensor
    # resident -> single streaming read).  This is what makes small-buffer
    # designs energy-catastrophic (Fig. 4's 2-orders-of-magnitude spread).
    spill_w = prims.clip01(1.0 - l2_bytes / jnp.maximum(W_u, 1.0))
    spill_a = prims.clip01(1.0 - l2_bytes / jnp.maximum(A_u, 1.0))
    dram_traffic = (W_u * (1.0 + (passes_w - 1.0) * spill_w)
                    + A_u * (1.0 + (passes_a - 1.0) * spill_a)
                    + O_u)
    l2_bw = L2_BW_BASE + L2_BW_SQRT * jnp.sqrt(pe)
    lat = (prims.max3(comp, l2_traffic / l2_bw, dram_traffic / DRAM_BW)
           + jnp.sqrt(pe) + FILL_CYCLES)

    leak_mw = LEAK_PE_MW * pe + LEAK_L1_MW_B * l1_bytes * pe
    energy_pj = (E_MAC * macs
                 + E_L1 * (L1_ACC_PER_MAC * macs + l2_traffic)
                 + E_L2 * l2_traffic
                 + E_DRAM * dram_traffic
                 + leak_mw * lat)            # 1 mW * 1 cycle @1GHz = 1 pJ

    area = (A_MAC_UM2 * pe + A_L1_UM2_B * l1_bytes * pe
            + A_L2_UM2_B * l2_bytes + A_NOC_UM2_PE * pe)
    power = (P_MAC_MW * pe + P_L1_MW_B * l1_bytes * pe
             + P_L2_MW_B * l2_bytes + P_NOC_MW_PE * pe)

    return CostOut(
        latency=lat * repeat,
        energy=(energy_pj * repeat) * 1e-3,  # pJ -> nJ
        area=area * repeat,
        power=power * repeat,
        l1_bytes=l1_bytes,
        l2_bytes=l2_bytes,
        macs=macs * repeat,
        util=macs / prims.maximum(comp * pe, 1.0),
    )


def core_cost(K, C, Y, X, R, S, ltype, repeat, pe, kt, df):
    """The HARD model core on unpacked float32 field arrays (broadcastable).

    Shared verbatim between the pure-jnp oracle (:func:`evaluate`, which is
    ``kernels/ref.py``'s ground truth) and the Pallas TPU kernel
    (``kernels/costmodel_eval.py``) -- both lower exactly these ops.  Bit-
    identical to the pre hard/soft-split implementation (locked by the
    golden-value tests in ``tests/test_relaxed.py``).
    """
    pe = jnp.maximum(pe, 1.0)
    kt = jnp.maximum(kt, 1.0)
    gate = HARD.eq_gate
    df_w = (gate(df, DLA), gate(df, EYE), gate(df, SHI))
    is_dw = gate(ltype, DWCONV)
    l1_bytes = l1_bytes_formula(df, kt, R, S)
    return _gated_cost(K, C, Y, X, R, S, repeat, pe, kt, df_w, is_dw,
                       l1_bytes, HARD)


def soft_core_cost(K, C, Y, X, R, S, ltype, repeat, pe, kt, df_weights, tau):
    """The SOFT model core: smooth surrogates + a dataflow simplex.

    ``df_weights``: (..., 3) weights over (dla, eye, shi) -- any convex
    combination (e.g. a temperature-annealed softmax over logits); pass an
    exact one-hot for a fixed-dataflow relaxation.  ``tau`` is the shared
    surrogate temperature (traced scalar is fine).  Gradients w.r.t. ``pe``,
    ``kt`` and ``df_weights`` are finite and non-zero everywhere, including
    on the hard model's ceil-effect plateaus.
    """
    prims = prim_lib.soft(tau)
    pe = prims.maximum(pe, 1.0)
    kt = prims.maximum(kt, 1.0)
    df_weights = jnp.asarray(df_weights, jnp.float32)
    df_w = tuple(jnp.moveaxis(df_weights, -1, 0))
    is_dw = prims.eq_gate(ltype, DWCONV)
    dla_b, eye_b, shi_b = l1_bytes_by_style(kt, R, S)
    l1_bytes = df_w[0] * dla_b + df_w[1] * eye_b + df_w[2] * shi_b
    return _gated_cost(K, C, Y, X, R, S, repeat, pe, kt, df_w, is_dw,
                       l1_bytes, prims)


def evaluate(layers, pe, kt, dataflow):
    """Evaluate design points against layers.  Fully broadcastable.

    Args:
      layers:   (..., NUM_FIELDS) int/float array of layer descriptors.
      pe:       (...,) #PEs   >= 1.
      kt:       (...,) per-PE tile count >= 1.
      dataflow: (...,) in {DLA, EYE, SHI} (scalar or per-layer for MIX).

    Returns CostOut of broadcast shape; all values are per-layer *including*
    the ``repeat`` multiplicity (latency/energy/area/power all scale by it:
    repeated identical layers are separate pipeline partitions with tied
    assignments -- see layers.py).
    """
    layers = jnp.asarray(layers)
    f = lambda i: layers[..., i].astype(jnp.float32)
    return core_cost(
        f(F_K), f(F_C), f(F_Y), f(F_X), f(F_R), f(F_S),
        f(F_TYPE), f(F_REPEAT),
        jnp.asarray(pe, jnp.float32), jnp.asarray(kt, jnp.float32),
        jnp.asarray(dataflow))


def evaluate_point(layer_row, pe, kt, dataflow):
    """Single layer x single design point (still jit-friendly)."""
    return evaluate(layer_row, pe, kt, dataflow)


def model_cost(layers, pe, kt, dataflow, scenario: str = "LP"):
    """Aggregate whole-model cost for a per-layer assignment.

    scenario "LP": every layer is its own partition -> latency/energy/area/
                   power all sum over layers.
    scenario "LS": one shared accelerator -> latency/energy sum (layers run
                   sequentially) but area/power are the max over layers (the
                   single design must provision for the largest demand).
    """
    out = evaluate(layers, pe, kt, dataflow)
    lat = jnp.sum(out.latency, axis=-1)
    en = jnp.sum(out.energy, axis=-1)
    if scenario == "LP":
        area = jnp.sum(out.area, axis=-1)
        power = jnp.sum(out.power, axis=-1)
    elif scenario == "LS":
        area = jnp.max(out.area, axis=-1)
        power = jnp.max(out.power, axis=-1)
    else:
        raise ValueError(f"unknown scenario {scenario!r}")
    return CostOut(lat, en, area, power,
                   jnp.max(out.l1_bytes, axis=-1),
                   jnp.max(out.l2_bytes, axis=-1),
                   jnp.sum(out.macs, axis=-1),
                   jnp.mean(out.util, axis=-1))


# ---------------------------------------------------------------------------
# Soft (differentiable) evaluators -- same core, smooth primitives.
# ---------------------------------------------------------------------------
def soft_evaluate(layers, pe, kt, df_weights, tau=1.0):
    """Differentiable twin of :func:`evaluate`.

    Args:
      layers:     (..., NUM_FIELDS) layer descriptors (data; not smoothed).
      pe, kt:     (...,) CONTINUOUS design variables (any real >= ~1).
      df_weights: (..., 3) dataflow simplex weights over (dla, eye, shi).
      tau:        surrogate temperature; ``tau -> 0`` recovers the hard model
                  pointwise (away from the staircase jump points).

    Returns a :class:`CostOut` whose every field is smooth in ``pe``, ``kt``
    and ``df_weights`` -- the input to ``jax.grad`` for the relaxed engine.
    """
    layers = jnp.asarray(layers)
    f = lambda i: layers[..., i].astype(jnp.float32)
    return soft_core_cost(
        f(F_K), f(F_C), f(F_Y), f(F_X), f(F_R), f(F_S),
        f(F_TYPE), f(F_REPEAT),
        jnp.asarray(pe, jnp.float32), jnp.asarray(kt, jnp.float32),
        df_weights, tau)


def soft_model_cost(layers, pe, kt, df_weights, tau=1.0,
                    scenario: str = "LP"):
    """Differentiable twin of :func:`model_cost`.

    Aggregation mirrors the hard semantics: objectives sum over layers in
    both scenarios; the LS constraint ``max`` over layers (one shared design
    provisioned for the largest demand) becomes the scale-invariant smooth
    maximum so constraint gradients reach *every* layer's variables, not
    just the argmax layer's.
    """
    out = soft_evaluate(layers, pe, kt, df_weights, tau)
    lat = jnp.sum(out.latency, axis=-1)
    en = jnp.sum(out.energy, axis=-1)
    if scenario == "LP":
        area = jnp.sum(out.area, axis=-1)
        power = jnp.sum(out.power, axis=-1)
    elif scenario == "LS":
        p = 12.0 / jnp.clip(jnp.asarray(tau, jnp.float32), 1e-3, 1.0)
        area = prim_lib.smooth_amax(out.area, p)
        power = prim_lib.smooth_amax(out.power, p)
    else:
        raise ValueError(f"unknown scenario {scenario!r}")
    return CostOut(lat, en, area, power,
                   jnp.max(out.l1_bytes, axis=-1),
                   jnp.max(out.l2_bytes, axis=-1),
                   jnp.sum(out.macs, axis=-1),
                   jnp.mean(out.util, axis=-1))


@functools.lru_cache(maxsize=1)
def content_hash() -> str:
    """Content hash of the cost-model definition (16 hex chars).

    Covers every module whose source participates in a cost value: the model
    core (this file), the plateau primitives, the dataflow tables/L1
    formulas and the layer-descriptor packing.  Any math change -- hard or
    soft, constants included -- changes the hash.  ``CostMemoCache`` mixes
    it into every key so a cache (in-process today, disk/fleet-shared
    tomorrow) can never serve a stale ``(lat, en, area, pw)`` tuple computed
    by a different model.
    """
    import repro.costmodel.dataflows as _dataflows
    import repro.costmodel.layers as _layers
    import repro.costmodel.maestro as _maestro
    import repro.costmodel.primitives as _primitives

    h = hashlib.sha256()
    for mod in (_maestro, _primitives, _dataflows, _layers):
        with open(mod.__file__, "rb") as fh:
            h.update(fh.read())
    return h.hexdigest()[:16]
