"""Workload (layer-list) definitions for the paper's evaluation DNNs.

The paper evaluates three CNNs -- MobileNet-V2 [62], MnasNet [76],
ResNet-50 [27] -- and three GEMM-based models -- GNMT [85], Transformer [80],
NCF [28].  Each is lowered to the (K, C, Y, X, R, S, type) descriptors of
``layers.py``.

Strided convolutions: the cost model computes output spatial dims as
Y' = Y - R + 1, so strided layers are encoded with *effective* input size
Y = Y_out + R - 1 (MAC counts then match the true strided layer).

The assigned architectures (qwen3 / zamba2 / ... ) are lowered by
``repro.costmodel.arch_workloads`` from their configs; both registries are
reachable through :func:`get_workload`.
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.costmodel.layers import LayerSpec


def _conv(K, C, out_y, out_x, R, S, name=""):
    return LayerSpec.conv(K, C, out_y + R - 1, out_x + S - 1, R, S, name=name)


def _dw(C, out_y, out_x, R, S, name=""):
    return LayerSpec.dwconv(C, out_y + R - 1, out_x + S - 1, R, S, name=name)


# ---------------------------------------------------------------------------
# MobileNet-V2 (52-ish conv layers; the paper's headline workload).
# ---------------------------------------------------------------------------
def mobilenet_v2() -> List[LayerSpec]:
    layers: List[LayerSpec] = [_conv(32, 3, 112, 112, 3, 3, "conv0")]
    cin, res = 32, 112
    # (expansion t, out channels c, repeats n, stride s)
    table = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
             (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    for t, c, n, s in table:
        for i in range(n):
            stride = s if i == 0 else 1
            out_res = res // stride
            hidden = cin * t
            blk = f"b{len(layers)}"
            if t != 1:
                layers.append(_conv(hidden, cin, res, res, 1, 1,
                                    blk + ".expand"))
            layers.append(_dw(hidden, out_res, out_res, 3, 3, blk + ".dw"))
            layers.append(_conv(c, hidden, out_res, out_res, 1, 1,
                                blk + ".proj"))
            cin, res = c, out_res
    layers.append(_conv(1280, cin, res, res, 1, 1, "conv_last"))
    layers.append(LayerSpec.gemm(1, 1000, 1280, name="fc"))
    return layers


# ---------------------------------------------------------------------------
# ResNet-50.
# ---------------------------------------------------------------------------
def resnet50() -> List[LayerSpec]:
    layers: List[LayerSpec] = [_conv(64, 3, 112, 112, 7, 7, "conv1")]
    cfg = [(64, 256, 3, 56), (128, 512, 4, 28),
           (256, 1024, 6, 14), (512, 2048, 3, 7)]
    cin = 64
    for width, cout, n, res in cfg:
        for i in range(n):
            blk = f"s{res}.b{i}"
            layers.append(_conv(width, cin, res, res, 1, 1, blk + ".r"))
            layers.append(_conv(width, width, res, res, 3, 3, blk + ".c"))
            layers.append(_conv(cout, width, res, res, 1, 1, blk + ".e"))
            if i == 0:
                layers.append(_conv(cout, cin, res, res, 1, 1, blk + ".d"))
            cin = cout
    layers.append(LayerSpec.gemm(1, 1000, 2048, name="fc"))
    return layers


# ---------------------------------------------------------------------------
# MnasNet-B1.
# ---------------------------------------------------------------------------
def mnasnet() -> List[LayerSpec]:
    layers: List[LayerSpec] = [_conv(32, 3, 112, 112, 3, 3, "conv0")]
    layers += [_dw(32, 112, 112, 3, 3, "sep.dw"),
               _conv(16, 32, 112, 112, 1, 1, "sep.pw")]
    cin, res = 16, 112
    # (expansion, out c, n, stride, kernel)
    table = [(3, 24, 3, 2, 3), (3, 40, 3, 2, 5), (6, 80, 3, 2, 5),
             (6, 96, 2, 1, 3), (6, 192, 4, 2, 5), (6, 320, 1, 1, 3)]
    for t, c, n, s, k in table:
        for i in range(n):
            stride = s if i == 0 else 1
            out_res = res // stride
            hidden = cin * t
            blk = f"mb{len(layers)}"
            layers.append(_conv(hidden, cin, res, res, 1, 1, blk + ".expand"))
            layers.append(_dw(hidden, out_res, out_res, k, k, blk + ".dw"))
            layers.append(_conv(c, hidden, out_res, out_res, 1, 1,
                                blk + ".proj"))
            cin, res = c, out_res
    layers.append(_conv(1280, cin, res, res, 1, 1, "conv_last"))
    layers.append(LayerSpec.gemm(1, 1000, 1280, name="fc"))
    return layers


# ---------------------------------------------------------------------------
# GEMM-based models (footnote 3: GEMMs as (M, N, K)).
# ---------------------------------------------------------------------------
def gnmt(seq: int = 128, hidden: int = 1024, vocab: int = 32000
         ) -> List[LayerSpec]:
    layers: List[LayerSpec] = []
    for l in range(8):  # encoder LSTMs
        layers.append(LayerSpec.gemm(seq, 4 * hidden, hidden,
                                     name=f"enc{l}.W"))
        layers.append(LayerSpec.gemm(seq, 4 * hidden, hidden,
                                     name=f"enc{l}.U"))
    layers.append(LayerSpec.gemm(seq, hidden, hidden, name="attn.q"))
    layers.append(LayerSpec.gemm(seq, seq, hidden, name="attn.score"))
    layers.append(LayerSpec.gemm(seq, hidden, seq, name="attn.ctx"))
    for l in range(8):  # decoder LSTMs
        layers.append(LayerSpec.gemm(seq, 4 * hidden, 2 * hidden,
                                     name=f"dec{l}.W"))
        layers.append(LayerSpec.gemm(seq, 4 * hidden, hidden,
                                     name=f"dec{l}.U"))
    layers.append(LayerSpec.gemm(seq, vocab, hidden, name="softmax"))
    return layers


def transformer(seq: int = 64, d: int = 512, heads: int = 8, ff: int = 2048,
                vocab: int = 37000, n_enc: int = 6, n_dec: int = 6
                ) -> List[LayerSpec]:
    dh = d // heads
    layers: List[LayerSpec] = []

    def attn_block(prefix: str, kv_seq: int):
        return [
            LayerSpec.gemm(seq, 3 * d, d, name=prefix + ".qkv"),
            LayerSpec.gemm(seq, kv_seq, dh, repeat=heads,
                           name=prefix + ".score"),
            LayerSpec.gemm(seq, dh, kv_seq, repeat=heads,
                           name=prefix + ".ctx"),
            LayerSpec.gemm(seq, d, d, name=prefix + ".out"),
        ]

    def ffn_block(prefix: str):
        return [LayerSpec.gemm(seq, ff, d, name=prefix + ".ff1"),
                LayerSpec.gemm(seq, d, ff, name=prefix + ".ff2")]

    for l in range(n_enc):
        layers += attn_block(f"enc{l}.self", seq) + ffn_block(f"enc{l}")
    for l in range(n_dec):
        layers += (attn_block(f"dec{l}.self", seq)
                   + attn_block(f"dec{l}.cross", seq)
                   + ffn_block(f"dec{l}"))
    layers.append(LayerSpec.gemm(seq, vocab, d, name="softmax"))
    return layers


def ncf(batch: int = 1024, embed: int = 128) -> List[LayerSpec]:
    dims = [4 * embed, 2 * embed, embed, embed // 2]
    layers: List[LayerSpec] = []
    cin = 2 * embed  # concat(user, item)
    for i, dout in enumerate(dims):
        layers.append(LayerSpec.gemm(batch, dout, cin, name=f"mlp{i}"))
        cin = dout
    layers.append(LayerSpec.gemm(batch, 1, cin + embed, name="predict"))
    return layers


_PAPER_WORKLOADS: Dict[str, Callable[..., List[LayerSpec]]] = {
    "mobilenet_v2": mobilenet_v2,
    "resnet50": resnet50,
    "mnasnet": mnasnet,
    "gnmt": gnmt,
    "transformer": transformer,
    "ncf": ncf,
}


# ---------------------------------------------------------------------------
# Multi-DNN co-design: one HW assignment against a mix of models.
# ---------------------------------------------------------------------------
def multi_dnn(names: List[str] = None, tokens: int = 32) -> List[LayerSpec]:
    """Concatenate several models into one workload (the co-design mix).

    The paper searches per "DNN(s) of interest"; this lowers a *set* of
    them -- by default every assigned architecture config in
    ``repro.configs`` -- into one layer list, so one search assigns
    resources that must serve the whole mix (each member's layers keep
    their own per-layer (PE, Buf) slots; under LP they share one chip
    budget, under LS one shared design).  Layer counts are ragged across
    members, which is exactly what stresses the multi-workload Pallas path
    (``ops.batched_cost_multi``) through the serving batcher.
    """
    from repro.costmodel import arch_workloads

    if names is None:
        names = arch_workloads.arch_names()
    import dataclasses

    out: List[LayerSpec] = []
    for n in names:
        if n in _PAPER_WORKLOADS:
            layers = _PAPER_WORKLOADS[n]()
        else:
            layers = arch_workloads.lower_arch(n, tokens=tokens)
        out.extend(dataclasses.replace(l, name=f"{n}.{l.name}")
                   for l in layers)
    return out


def get_workload(name: str, **kwargs) -> List[LayerSpec]:
    """Look up a workload by name (paper models + assigned architectures +
    the ``multi_dnn`` co-design mix)."""
    if name in _PAPER_WORKLOADS:
        return _PAPER_WORKLOADS[name](**kwargs)
    if name == "multi_dnn":
        return multi_dnn(**kwargs)
    # Assigned architectures are lowered from their configs.
    from repro.costmodel import arch_workloads

    return arch_workloads.lower_arch(name, **kwargs)


def workload_names() -> List[str]:
    from repro.costmodel import arch_workloads

    return sorted(_PAPER_WORKLOADS) + arch_workloads.arch_names()
