"""ConfuciuX-as-a-service: concurrent resource-assignment searches.

``SearchService`` accepts any number of unified-API :class:`SearchRequest`\\ s
and multiplexes them onto shared hardware:

  * every request runs on a worker-pool thread through the SAME registry
    adapters as ``api.run_search`` -- outcomes are identical to serial runs;
  * the host-loop methods (``random``, ``grid``, ``bo``) route their genome
    evaluations through one shared :class:`CostEvalBatcher`, so N users'
    searches produce one fused dispatch stream and share the per-point
    :class:`CostMemoCache` (popular workloads re-evaluate almost nothing);
  * ``ga``, ``sa`` and ``relaxed`` run as chunked engines whose
    per-generation / per-candidate / per-round fitness goes through the
    SAME batcher via a raw-array
    ``eval_fn`` -- GA populations are the largest eval batches in the
    system, so a whole generation fuses with concurrent traffic and hits
    the memo cache; ``nsga2`` does the same through a (b, 4)-costs variant
    of the hook (frontier searches share per-point cache entries with
    scalar searches -- the point costs are the same rows);
  * the chunked JAX engines (``reinforce``, ``two_stage``, ``a2c``, ``ppo2``,
    ``fanout``) interleave at chunk granularity -- XLA releases the GIL
    during compile and execute -- and stream per-request progress through
    the service's wrapper, which doubles as the cancellation point;
  * the batcher's fused dispatch runs on a small pool
    (``ServiceConfig.dispatch_workers``): up to N fused dispatches execute
    concurrently, still bit-identical to single-thread dispatch;
  * ``ticket.cancel()`` stops a search at its next progress chunk (chunked
    engines) or next evaluation batch (batched methods, including every
    GA generation and SA step); a cancelled request never stalls the
    batcher -- its in-flight points are simply computed and dropped.

Typical use::

    from repro import api
    from repro.serving import SearchService

    with SearchService() as svc:
        tickets = [svc.submit(api.SearchRequest(workload="mobilenet_v2",
                                                eps=2000, method="random",
                                                seed=u))
                   for u in range(16)]
        outs = [t.result() for t in tickets]
        print(svc.stats()["cache_hit_rate"])
"""
from __future__ import annotations

import copy
import dataclasses
import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import registry as api_registry
from repro.api import types as api_types
from repro.core import env as env_lib
from repro.obs import instrument as obs_instrument
from repro.obs import state as obs_state
from repro.obs import trace as obs_trace
from repro.serving.batcher import CostEvalBatcher
from repro.serving.cost_cache import CostMemoCache, PersistentCostCache


class SearchCancelled(Exception):
    """Raised inside a worker when its ticket was cancelled mid-search."""


def _clone_exception(err: BaseException) -> BaseException:
    """Per-caller copy of a stored exception.

    ``raise`` assigns ``__traceback__`` on the raised *object*, so re-raising
    one shared instance from concurrent ``result()`` callers would let them
    mutate each other's tracebacks mid-flight.  Each caller gets a fresh
    copy chained (``__cause__``) to the original, whose worker-side traceback
    stays pinned.  Exceptions that defeat ``copy`` (exotic constructors)
    fall back to the shared instance -- correctness over isolation.
    """
    try:
        clone = copy.copy(err)
    except Exception:  # noqa: BLE001 -- uncopyable exception type
        return err
    if clone is err:   # a __copy__ that returns self defeats the point
        return err
    clone.__traceback__ = None
    clone.__cause__ = err
    return clone


# Methods whose host-side eval loop accepts an injected genome-level
# ``eval_fn`` and can therefore be fused by the cross-request batcher.
BATCHED_METHODS = ("random", "grid", "bo")

# Chunked engines whose ``eval_fn`` takes already-decoded raw ``(pe, kt,
# df)`` arrays instead of level genomes: GA populations, SA candidates and
# the relaxed engine's per-round hard probes route through the same batcher
# (fusion + dedup + memo cache) via
# :meth:`SearchService._make_raw_eval_fn`.  The RL family keeps its
# env-in-the-graph engines (the whole search is one XLA program) and
# multiplexes at chunk granularity only.
RAW_BATCHED_METHODS = ("ga", "sa", "relaxed")

# Chunked multi-objective engines whose ``eval_fn(pe, kt, df)`` returns
# (b, 4) aggregated whole-model costs instead of scalar fitness: NSGA-II
# populations fuse through the same batcher (same per-point dedup + memo
# cache -- a point evaluated for a scalar search is a cache hit for a
# frontier search and vice versa) via
# :meth:`SearchService._make_costs_eval_fn`.
COSTS_BATCHED_METHODS = ("nsga2",)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    max_workers: int = 8          # concurrent searches in flight
    cache_entries: int = 2 ** 20  # per-point memo capacity
    window_ms: float = 2.0        # batcher accumulation window
    use_kernel: Optional[bool] = None   # None: Pallas kernel on TPU only
    batched_methods: Tuple[str, ...] = BATCHED_METHODS
    raw_batched_methods: Tuple[str, ...] = RAW_BATCHED_METHODS
    costs_batched_methods: Tuple[str, ...] = COSTS_BATCHED_METHODS
    dispatch_workers: int = 1     # fused-dispatch pool size (batcher threads)
    default_progress_every: int = 200   # service-side chunking when the
    #                                     request carries no callback
    cache_dir: Optional[str] = None     # persistent CostMemoCache root; the
    #                                     memo then survives restarts and is
    #                                     shared across processes
    cache_flush_every: int = 4096       # fresh entries buffered per shard


class SearchTicket:
    """Handle for one submitted search: result / progress / cancellation."""

    def __init__(self, uid: int, request: api_types.SearchRequest):
        self.uid = uid
        self.request = request
        self.status = "queued"     # queued|running|done|cancelled|failed
        self.trials: List[api_types.Trial] = []
        self.submitted_at = time.time()
        self.wall_seconds = 0.0
        self._outcome: Optional[api_types.SearchOutcome] = None
        self._error: Optional[BaseException] = None
        self._done = threading.Event()
        self._cancel = threading.Event()
        # Lifecycle lock: serializes the queued -> running claim against
        # cancel()'s queued -> cancelled claim, so exactly one side finishes
        # a ticket and a still-queued cancel completes IMMEDIATELY instead
        # of waiting for a saturated pool to dequeue work it will only
        # throw away.
        self._state_lock = threading.Lock()
        self._started = False
        self._callbacks: List[Callable[["SearchTicket"], None]] = []

    # -- client side --------------------------------------------------------
    def cancel(self) -> None:
        """Request cancellation.

        A still-queued ticket finishes right here (status ``cancelled``,
        ``result()`` unblocked) -- the worker pool later skips it.  A
        running ticket observes the flag at its next chunk/batch.
        """
        self._cancel.set()
        with self._state_lock:
            if self._started or self._done.is_set():
                return   # running (flag observed at next chunk) or finished
            callbacks = self._finish_locked(
                "cancelled",
                error=SearchCancelled(f"search {self.uid} cancelled"))
        for fn in callbacks:
            fn(self)

    @property
    def cancelled(self) -> bool:
        return self._cancel.is_set()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None
               ) -> api_types.SearchOutcome:
        """Block for the outcome; raises SearchCancelled / the run's error."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"search {self.uid} still running")
        if self._error is not None:
            raise _clone_exception(self._error)
        return self._outcome

    def add_done_callback(self, fn: Callable[["SearchTicket"], None]) -> None:
        """Run ``fn(ticket)`` when the ticket finishes (immediately if it
        already has).  Callbacks run on whichever thread finishes the
        ticket and must not block."""
        with self._state_lock:
            if not self._done.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    # -- service side -------------------------------------------------------
    def _begin(self) -> bool:
        """Worker-side claim: queued -> running.  False when the ticket was
        already finished (cancelled while queued) -- the worker must skip."""
        with self._state_lock:
            if self._done.is_set():
                return False
            self._started = True
            self.status = "running"
            return True

    def _finish(self, status: str, outcome=None, error=None) -> bool:
        with self._state_lock:
            if self._done.is_set():
                return False
            callbacks = self._finish_locked(status, outcome, error)
        for fn in callbacks:
            fn(self)
        return True

    def _finish_locked(self, status: str, outcome=None, error=None) -> list:
        self.status = status
        self._outcome = outcome
        self._error = error
        self.wall_seconds = time.time() - self.submitted_at
        callbacks, self._callbacks = self._callbacks, []
        self._done.set()
        return callbacks


class SearchService:
    """Multiplexes concurrent SearchRequests onto shared hardware."""

    def __init__(self, cfg: ServiceConfig = ServiceConfig()):
        self.cfg = cfg
        if cfg.cache_dir:
            self.cache: CostMemoCache = PersistentCostCache(
                cfg.cache_dir, cfg.cache_entries,
                flush_every=cfg.cache_flush_every)
        else:
            self.cache = CostMemoCache(cfg.cache_entries)
        self.batcher = CostEvalBatcher(self.cache, window_ms=cfg.window_ms,
                                       use_kernel=cfg.use_kernel,
                                       dispatch_workers=cfg.dispatch_workers)
        self._pool = ThreadPoolExecutor(
            max_workers=cfg.max_workers, thread_name_prefix="search-worker")
        self._uids = itertools.count()
        self._lock = threading.Lock()
        self._counts = {"submitted": 0, "completed": 0, "cancelled": 0,
                        "failed": 0}
        # (layer bytes, EnvConfig) -> (layers, pe_table, kt_table, budget):
        # popular queries skip re-deriving the platform budget (the
        # baseline engine still builds its own env internally).
        self._env_memo: Dict[tuple, tuple] = {}
        self._closed = False

    # -- public API ---------------------------------------------------------
    def submit(self, request: api_types.SearchRequest) -> SearchTicket:
        """Enqueue one search; returns immediately with a ticket."""
        ticket = SearchTicket(next(self._uids), request)
        # Check-and-submit under the lock: close() flips _closed under the
        # same lock BEFORE shutting the pool down, so a submit that passed
        # the check has already handed its work to a live executor.  An
        # unlocked check raced close() -- submit could count the ticket,
        # then hit the shut-down pool's RuntimeError and leak a ticket
        # whose result() blocked forever.
        with self._lock:
            if self._closed:
                raise RuntimeError("SearchService is closed")
            self._counts["submitted"] += 1
            try:
                self._pool.submit(self._run, ticket)
            except RuntimeError as e:   # belt-and-braces: pool rejected it
                ticket._finish("failed", error=e)
                self._counts["failed"] += 1
                return ticket
        # Registered after release so a callback firing immediately (the
        # worker already finished, or the pool rejected above) never
        # re-enters self._lock while submit() holds it.
        ticket.add_done_callback(self._on_ticket_done)
        return ticket

    def run_all(self, requests: Sequence[api_types.SearchRequest]
                ) -> List[api_types.SearchOutcome]:
        """Submit a batch of requests and block for all outcomes (in order)."""
        tickets = [self.submit(r) for r in requests]
        return [t.result() for t in tickets]

    def stats(self) -> Dict[str, float]:
        with self._lock:
            s = dict(self._counts)
        b = self.batcher.stats()
        overlap = set(s) & set(b)
        assert not overlap, f"service/batcher stats keys collide: {overlap}"
        s.update(b)
        return s

    def close(self) -> None:
        with self._lock:
            self._closed = True
        self._pool.shutdown(wait=True)
        self.batcher.close()
        self.cache.close()   # final flush for persistent caches

    def __enter__(self) -> "SearchService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker -------------------------------------------------------------
    _STATUS_KEY = {"done": "completed", "cancelled": "cancelled",
                   "failed": "failed"}

    def _on_ticket_done(self, ticket: SearchTicket) -> None:
        """Single counting point for every way a ticket can finish --
        worker completion, worker error, AND a queued-cancel that never
        reaches a worker."""
        key = self._STATUS_KEY[ticket.status]
        if obs_state.enabled:
            obs_instrument.SERVICE_REQUESTS.inc(status=key)
        with self._lock:
            self._counts[key] += 1

    def _run(self, ticket: SearchTicket) -> None:
        if not ticket._begin():
            return   # cancelled while queued: already finished and counted
        obs_instrument.SERVICE_ACTIVE.inc()
        sp = obs_trace.span("service.search", uid=ticket.uid,
                            method=ticket.request.method).__enter__()
        try:
            if ticket.cancelled:
                raise SearchCancelled(f"search {ticket.uid} cancelled")
            sub = self._instrument(ticket)
            out = api_registry.run_search(sub)
            ticket._finish("done", outcome=out)
            key = "completed"
        except SearchCancelled as e:
            ticket._finish("cancelled", error=e)
            key = "cancelled"
        except BaseException as e:  # noqa: BLE001 -- reported via ticket
            ticket._finish("failed", error=e)
            key = "failed"
        finally:
            obs_instrument.SERVICE_ACTIVE.dec()
        sp.set(status=key).__exit__(None, None, None)

    def _instrument(self, ticket: SearchTicket) -> api_types.SearchRequest:
        """Wrap the request with progress recording, cancellation and --
        for batchable methods -- the shared-batcher eval_fn."""
        request = ticket.request
        user_cb = request.on_progress

        def on_progress(trial: api_types.Trial) -> None:
            ticket.trials.append(trial)
            if ticket.cancelled:
                raise SearchCancelled(f"search {ticket.uid} cancelled")
            if user_cb is not None:
                user_cb(trial)

        progress_every = (request.progress_every if user_cb is not None
                          else self.cfg.default_progress_every)
        options = dict(request.options)
        method = api_registry.get_optimizer(request.method).name
        if method in self.cfg.batched_methods:
            options["eval_fn"] = self._make_eval_fn(ticket)
        elif method in self.cfg.raw_batched_methods:
            options["eval_fn"] = self._make_raw_eval_fn(ticket)
        elif method in self.cfg.costs_batched_methods:
            options["eval_fn"] = self._make_costs_eval_fn(ticket)
        return dataclasses.replace(
            request, options=options, on_progress=on_progress,
            progress_every=progress_every)

    def _make_eval_fn(self, ticket: SearchTicket):
        """Drop-in for the baselines' jitted ``_decode_and_eval`` that
        routes through the shared batcher (decode stays exact: the same f32
        level tables the serial engine gathers from)."""
        request = ticket.request
        ecfg = request.env
        layers, pe_table, kt_table, budget = self._decode_tables(request)
        batcher = self.batcher

        def eval_fn(genomes):
            if ticket.cancelled:
                raise SearchCancelled(f"search {ticket.uid} cancelled")
            g = np.asarray(genomes)
            pe = pe_table[g[..., 0]]
            kt = kt_table[g[..., 1]]
            fit = batcher.evaluate(layers, pe, kt,
                                   np.float32(ecfg.dataflow), ecfg, budget)
            return fit, pe, kt

        return eval_fn

    def _make_raw_eval_fn(self, ticket: SearchTicket):
        """Raw-array eval hook for the chunked GA/SA engines.

        ``eval_fn(pe, kt, df) -> (b,) fitness`` with already-decoded raw
        values (the engines own their genome decode -- the same f32 table
        gather either way).  GA populations are the largest eval batches in
        the system, so fusing them here is what lets one dispatch serve a
        whole generation alongside concurrent random/grid/bo traffic.  Every
        call doubles as a cancellation point, which is how GA/SA observe
        ``ticket.cancel()`` within one generation / annealing step.
        """
        request = ticket.request
        ecfg = request.env
        layers, _, _, budget = self._decode_tables(request)
        batcher = self.batcher

        def eval_fn(pe, kt, df):
            if ticket.cancelled:
                raise SearchCancelled(f"search {ticket.uid} cancelled")
            return batcher.evaluate(layers, pe, kt, df, ecfg, budget)

        return eval_fn

    def _make_costs_eval_fn(self, ticket: SearchTicket):
        """Raw-array eval hook for the multi-objective engines: the same
        batcher routing as :meth:`_make_raw_eval_fn` but returning (b, 4)
        aggregated (lat, en, area, pw) costs -- what NSGA-II's constrained
        dominance ranks on.  Also the per-generation cancellation point."""
        request = ticket.request
        ecfg = request.env
        layers, _, _, budget = self._decode_tables(request)
        batcher = self.batcher

        def eval_fn(pe, kt, df):
            if ticket.cancelled:
                raise SearchCancelled(f"search {ticket.uid} cancelled")
            return batcher.evaluate_costs(layers, pe, kt, df, ecfg, budget)

        return eval_fn

    def _decode_tables(self, request: api_types.SearchRequest):
        """(layers, pe/kt tables, budget) for eval_fn decode, memoized per
        (workload, EnvConfig) so popular queries pay the platform-budget
        derivation (``max_constraint``: a whole-model cost eval) once."""
        from repro.costmodel.layers import layers_to_array

        wl = request.resolve_workload()
        arr = (layers_to_array(wl) if isinstance(wl, (list, tuple))
               else np.asarray(wl))
        key = (arr.astype(np.float32).tobytes(), request.env)
        with self._lock:
            hit = self._env_memo.get(key)
        if hit is not None:
            return hit
        env = env_lib.make_env(wl, request.env)
        entry = (np.asarray(env.layers, np.float32),
                 np.asarray(env.pe_table, np.float32),
                 np.asarray(env.kt_table, np.float32),
                 np.float32(env.budget))
        with self._lock:
            self._env_memo[key] = entry
        return entry
