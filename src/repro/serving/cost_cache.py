"""Cost-model memo cache: per-point results shared across searches.

The cache key is one *point* of the cost model -- ``(layer descriptor,
dataflow, PE, buffer)`` packed as the raw float32 bytes of the row -- and the
value is the point's ``(latency, energy, area, power)`` 4-vector.  Keying on
the raw model inputs (not on a workload name or an objective) is what lets
hits cross user boundaries: two users searching mobilenet under different
objectives, or two different workloads that share a layer shape, reuse each
other's evaluations.  The per-layer action space is small (``levels**2``
(PE, Buf) pairs per layer per dataflow), so popular workloads saturate the
cache after a few thousand samples and later searches evaluate almost
nothing fresh.

Thread-safe LRU with hit/miss/eviction accounting; all counting happens at
*unique-row* granularity (the batcher dedupes duplicates inside a dispatch
before consulting the cache -- see ``CostEvalBatcher``).

Every key is namespaced by a cost-model *version* -- by default the content
hash of the model's source modules (:func:`repro.costmodel.content_hash`).
A point row evaluated under one version of the model can therefore never be
served under another: edit ``maestro.py`` (or its primitives) and every
cached ``(lat, en, area, pw)`` tuple from the old semantics misses cleanly
instead of silently poisoning new searches.

:class:`PersistentCostCache` extends the in-memory cache with a disk-backed
store under ``cache_dir/<version>/``: inserts are buffered and flushed as
*append-only shard files* (each flush writes one immutable shard via
tmp-file + ``os.replace``, so a crash mid-flush can never corrupt existing
shards), and opening a cache loads every shard in one vectorized
``np.frombuffer`` pass.  Because the version namespace is the directory
name, a model edit simply opens an empty directory -- old shards stay on
disk for the old version, new points accumulate under the new hash.  Shards
from concurrent processes coexist (PID-tagged file names), which is what
makes warm-start hit rates survive restarts AND apply across processes.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple


import numpy as np

from repro.obs import instrument as obs_instrument
from repro.obs import state as obs_state


def model_version() -> str:
    """The default cache namespace: the cost model's content hash."""
    from repro.costmodel import maestro

    return maestro.content_hash()


class CostMemoCache:
    """LRU memo of per-point cost evaluations.

    Keys are ``bytes`` (the packed f32 point row), internally prefixed with
    the model ``version`` tag; values are ``(4,)`` float32 arrays
    ``[latency, energy, area, power]``.
    """

    def __init__(self, capacity: int = 2 ** 20,
                 version: Optional[str] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.version = model_version() if version is None else str(version)
        self._vprefix = self.version.encode("ascii") + b":"
        self._data: "OrderedDict[bytes, np.ndarray]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def get_many(self, keys) -> tuple:
        """Look up a batch of keys under one lock acquisition.

        Returns (values, miss_index): ``values`` is a list aligned with
        ``keys`` (None where missing); ``miss_index`` the positions to
        evaluate.  Counts one hit/miss per key.
        """
        t0 = time.perf_counter() if obs_state.enabled else 0.0
        values = []
        miss_index = []
        pre = self._vprefix
        with self._lock:
            for i, k in enumerate(keys):
                k = pre + k
                v = self._data.get(k)
                if v is None:
                    self.misses += 1
                    miss_index.append(i)
                else:
                    self.hits += 1
                    self._data.move_to_end(k)
                values.append(v)
        if obs_state.enabled:
            obs_instrument.CACHE_LOOKUP_SECONDS.observe(
                time.perf_counter() - t0)
            n_miss = len(miss_index)
            if n_miss:
                obs_instrument.CACHE_LOOKUPS.inc(n_miss, result="miss")
            if len(values) - n_miss:
                obs_instrument.CACHE_LOOKUPS.inc(
                    len(values) - n_miss, result="hit")
        return values, miss_index

    def put_many(self, keys, vals: np.ndarray) -> None:
        """Insert key->(4,) rows; evicts least-recently-used past capacity."""
        pre = self._vprefix
        fresh: List[Tuple[bytes, np.ndarray]] = []
        with self._lock:
            ev0 = self.evictions
            for k, v in zip(keys, vals):
                pk = pre + k
                if pk not in self._data:
                    fresh.append((k, v))
                self._data[pk] = v
                self._data.move_to_end(pk)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1
            evicted = self.evictions - ev0
        if evicted and obs_state.enabled:
            obs_instrument.CACHE_EVICTIONS.inc(evicted)
        if fresh:
            self._on_insert(fresh)

    def _on_insert(self, fresh: List[Tuple[bytes, np.ndarray]]) -> None:
        """First-insertion hook (unprefixed key, (4,) f32 value pairs) --
        the persistence layer's write-behind point.  No-op in memory."""

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "entries": len(self._data),
                "capacity": self.capacity,
                "version": self.version,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hit_rate,
            }

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def flush(self) -> int:
        """Persist buffered inserts; returns entries written (0 here --
        the in-memory cache has nothing to flush)."""
        return 0

    def close(self) -> None:
        """Release any backing resources (final flush for disk caches)."""


# --------------------------------------------------------------------------
# Disk-backed persistence.
# --------------------------------------------------------------------------
_SHARD_MAGIC = b"RPCC1\n"


class PersistentCostCache(CostMemoCache):
    """A :class:`CostMemoCache` whose entries survive restarts.

    Layout: ``cache_dir/<version>/shard-<pid>-<seq>.bin`` -- each shard is
    an immutable append-only unit holding homogeneous fixed-width records
    ``[key bytes | 4 x f32 value]`` behind a one-line JSON header, written
    crash-safely (tmp file + atomic ``os.replace``; a torn write leaves a
    ``.tmp`` orphan that loading ignores).  ``open`` -> one ``np.frombuffer``
    pass per shard; corrupt or truncated shards are skipped and counted,
    never fatal.  Writes are buffered and flushed every ``flush_every``
    fresh entries, on :meth:`flush`, and on :meth:`close`.

    The version namespace (default :func:`model_version`) is the directory
    name, so a cost-model edit can never serve stale tuples: the new hash
    opens a different, initially empty directory.
    """

    def __init__(self, cache_dir: str, capacity: int = 2 ** 20,
                 version: Optional[str] = None, flush_every: int = 4096):
        super().__init__(capacity, version)
        if flush_every < 1:
            raise ValueError(f"flush_every must be >= 1, got {flush_every}")
        self.cache_dir = str(cache_dir)
        self._dir = os.path.join(self.cache_dir, self.version)
        os.makedirs(self._dir, exist_ok=True)
        self._flush_every = int(flush_every)
        self._io_lock = threading.Lock()
        self._pending: List[Tuple[bytes, np.ndarray]] = []
        self._seq = 0
        self.persisted = 0        # entries on disk (loaded + flushed)
        self.shards_loaded = 0
        self.corrupt_shards = 0
        self._load()

    # -- write-behind --------------------------------------------------------
    def _on_insert(self, fresh: List[Tuple[bytes, np.ndarray]]) -> None:
        with self._io_lock:
            self._pending.extend(
                (k, np.asarray(v, np.float32)) for k, v in fresh)
            due = len(self._pending) >= self._flush_every
        if due:
            self.flush()

    def flush(self) -> int:
        """Write buffered entries as one new shard per key width; atomic
        per shard (tmp + rename).  Returns the number of entries written."""
        with self._io_lock:
            pending, self._pending = self._pending, []
            if not pending:
                return 0
            by_len: Dict[int, list] = {}
            for k, v in pending:
                by_len.setdefault(len(k), []).append((k, v))
            for keylen, pairs in by_len.items():
                arr = np.empty((len(pairs), keylen + 16), np.uint8)
                for i, (k, v) in enumerate(pairs):
                    arr[i, :keylen] = np.frombuffer(k, np.uint8)
                    arr[i, keylen:] = np.frombuffer(
                        np.asarray(v, np.float32).tobytes(), np.uint8)
                head = _SHARD_MAGIC + json.dumps(
                    {"keylen": keylen, "count": len(pairs)}).encode() + b"\n"
                final = os.path.join(
                    self._dir, f"shard-{os.getpid()}-{self._seq:06d}.bin")
                self._seq += 1
                tmp = final + ".tmp"
                with open(tmp, "wb") as f:
                    f.write(head)
                    f.write(arr.tobytes())
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, final)
            self.persisted += len(pending)
        return len(pending)

    def close(self) -> None:
        self.flush()

    # -- load ----------------------------------------------------------------
    def _load(self) -> None:
        names = sorted(n for n in os.listdir(self._dir)
                       if n.startswith("shard-") and n.endswith(".bin"))
        pre = self._vprefix
        for name in names:
            try:
                with open(os.path.join(self._dir, name), "rb") as f:
                    blob = f.read()
                if not blob.startswith(_SHARD_MAGIC):
                    raise ValueError("bad magic")
                nl = blob.index(b"\n", len(_SHARD_MAGIC))
                meta = json.loads(blob[len(_SHARD_MAGIC):nl])
                keylen, count = int(meta["keylen"]), int(meta["count"])
                width = keylen + 16
                body = np.frombuffer(blob, np.uint8, offset=nl + 1)
                if body.size < count * width:
                    raise ValueError("truncated shard")
                body = body[:count * width].reshape(count, width)
            except (ValueError, KeyError, json.JSONDecodeError, OSError):
                self.corrupt_shards += 1
                continue
            vals = body[:, keylen:].copy().view(np.float32)
            with self._lock:
                for i in range(count):
                    k = pre + body[i, :keylen].tobytes()
                    self._data[k] = vals[i]
                    self._data.move_to_end(k)
                while len(self._data) > self.capacity:
                    self._data.popitem(last=False)
            self.shards_loaded += 1
            self.persisted += count
        # Continue shard numbering past what this PID may have left behind
        # in an earlier incarnation (names are PID-tagged, so only a PID
        # reuse could collide; scanning once keeps even that impossible).
        tag = f"shard-{os.getpid()}-"
        seqs = [int(n[len(tag):-4]) for n in names if n.startswith(tag)]
        self._seq = max(seqs) + 1 if seqs else 0

    def stats(self) -> Dict[str, object]:
        s = super().stats()
        with self._io_lock:
            s.update({"persisted": self.persisted,
                      "pending_flush": len(self._pending),
                      "shards_loaded": self.shards_loaded,
                      "corrupt_shards": self.corrupt_shards,
                      "dir": self._dir})
        return s
