"""Cost-model memo cache: per-point results shared across searches.

The cache key is one *point* of the cost model -- ``(layer descriptor,
dataflow, PE, buffer)`` packed as the raw float32 bytes of the row -- and the
value is the point's ``(latency, energy, area, power)`` 4-vector.  Keying on
the raw model inputs (not on a workload name or an objective) is what lets
hits cross user boundaries: two users searching mobilenet under different
objectives, or two different workloads that share a layer shape, reuse each
other's evaluations.  The per-layer action space is small (``levels**2``
(PE, Buf) pairs per layer per dataflow), so popular workloads saturate the
cache after a few thousand samples and later searches evaluate almost
nothing fresh.

Thread-safe LRU with hit/miss/eviction accounting; all counting happens at
*unique-row* granularity (the batcher dedupes duplicates inside a dispatch
before consulting the cache -- see ``CostEvalBatcher``).

Every key is namespaced by a cost-model *version* -- by default the content
hash of the model's source modules (:func:`repro.costmodel.content_hash`).
A point row evaluated under one version of the model can therefore never be
served under another: edit ``maestro.py`` (or its primitives) and every
cached ``(lat, en, area, pw)`` tuple from the old semantics misses cleanly
instead of silently poisoning new searches.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Dict, Optional


import numpy as np

from repro.obs import instrument as obs_instrument
from repro.obs import state as obs_state


def model_version() -> str:
    """The default cache namespace: the cost model's content hash."""
    from repro.costmodel import maestro

    return maestro.content_hash()


class CostMemoCache:
    """LRU memo of per-point cost evaluations.

    Keys are ``bytes`` (the packed f32 point row), internally prefixed with
    the model ``version`` tag; values are ``(4,)`` float32 arrays
    ``[latency, energy, area, power]``.
    """

    def __init__(self, capacity: int = 2 ** 20,
                 version: Optional[str] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.version = model_version() if version is None else str(version)
        self._vprefix = self.version.encode("ascii") + b":"
        self._data: "OrderedDict[bytes, np.ndarray]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def get_many(self, keys) -> tuple:
        """Look up a batch of keys under one lock acquisition.

        Returns (values, miss_index): ``values`` is a list aligned with
        ``keys`` (None where missing); ``miss_index`` the positions to
        evaluate.  Counts one hit/miss per key.
        """
        t0 = time.perf_counter() if obs_state.enabled else 0.0
        values = []
        miss_index = []
        pre = self._vprefix
        with self._lock:
            for i, k in enumerate(keys):
                k = pre + k
                v = self._data.get(k)
                if v is None:
                    self.misses += 1
                    miss_index.append(i)
                else:
                    self.hits += 1
                    self._data.move_to_end(k)
                values.append(v)
        if obs_state.enabled:
            obs_instrument.CACHE_LOOKUP_SECONDS.observe(
                time.perf_counter() - t0)
            n_miss = len(miss_index)
            if n_miss:
                obs_instrument.CACHE_LOOKUPS.inc(n_miss, result="miss")
            if len(values) - n_miss:
                obs_instrument.CACHE_LOOKUPS.inc(
                    len(values) - n_miss, result="hit")
        return values, miss_index

    def put_many(self, keys, vals: np.ndarray) -> None:
        """Insert key->(4,) rows; evicts least-recently-used past capacity."""
        pre = self._vprefix
        with self._lock:
            ev0 = self.evictions
            for k, v in zip(keys, vals):
                k = pre + k
                self._data[k] = v
                self._data.move_to_end(k)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1
            evicted = self.evictions - ev0
        if evicted and obs_state.enabled:
            obs_instrument.CACHE_EVICTIONS.inc(evicted)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "entries": len(self._data),
                "capacity": self.capacity,
                "version": self.version,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hit_rate,
            }

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
