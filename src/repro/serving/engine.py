"""Batched serving engine: bucketed prefill + lockstep greedy decode.

Design
------
* **Bucketed batching.** Requests are grouped by prompt length, so each
  batch prefill/decode runs in lockstep with one scalar cache position --
  no per-request position bookkeeping, no attention over pad tokens, and
  every step is a fixed-shape jitted call (no recompilation churn).
* **Prefill via the decode path.** The prompt is teacher-forced through
  ``decode_step`` under ``lax.scan``; this populates the KV cache (or SSM
  state -- the same code serves every family) token by token.  It trades
  prefill FLOP efficiency for universality; the dry-run's ``prefill``
  lowering covers the fused large-batch prefill path.
* **Early-stop masking.** Finished requests (hit ``stop_token`` or their
  token budget) keep decoding in lockstep but their outputs are masked;
  the batch retires when all requests are done.
* **Fixed cache pool.** One cache of (batch, max_len) is allocated per
  bucket shape and donated across steps -- steady-state decode does zero
  allocation.

The engine is mesh-agnostic: pass ``pol``/shardings for multi-device
serving (launch/serve.py wires the production mesh policies).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512           # cache capacity (prompt + generation)
    max_batch: int = 8           # requests per bucket batch
    stop_token: int = -1         # -1: never stop early
    greedy: bool = True


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0


class Engine:
    """Batched greedy-decode engine over a fixed parameter set."""

    def __init__(self, cfg, params, scfg: ServeConfig = ServeConfig(),
                 *, pol=None, cross_feats=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.pol = pol or lm.NO_SHARDING
        self.cross_feats = cross_feats     # (B, S, D) for audio/vlm families
        self._decode = jax.jit(self._decode_impl, donate_argnums=(0,))
        self._prefill = jax.jit(self._prefill_impl, donate_argnums=(0,))
        self._tok_count = 0
        self._decode_s = 0.0

    # -- jitted cores -----------------------------------------------------
    def _decode_impl(self, cache, token):
        return lm.serve_step(self.params, cache, token, self.cfg,
                             pol=self.pol)

    def _prefill_impl(self, cache, prompt_toks):
        """Teacher-force the prompt: (B, Tp) -> populated cache + last ids."""
        def step(cache, tok_t):
            nxt, cache = lm.serve_step(self.params, cache, tok_t, self.cfg,
                                       pol=self.pol)
            return cache, nxt

        cache, nxts = jax.lax.scan(step, cache, prompt_toks.T)
        return cache, nxts[-1]

    # -- cache management --------------------------------------------------
    def _fresh_cache(self, batch: int):
        cache = lm.init_cache(self.cfg, batch, self.scfg.max_len,
                              dtype=self.cfg.compute_dtype)
        if self.cfg.family in ("audio", "vlm"):
            assert self.cross_feats is not None, (
                "audio/vlm serving needs precomputed frontend features")
            feats = jnp.broadcast_to(
                self.cross_feats[:1],
                (batch,) + self.cross_feats.shape[1:])
            k, v = lm.precompute_cross_kv(self.params, self.cfg, feats)
            cache = cache._replace(cross_k=k, cross_v=v)
        return cache

    # -- serving loop -------------------------------------------------------
    def run_batch(self, requests: Sequence[Request]) -> None:
        """Prefill + decode one equal-prompt-length batch, in place."""
        assert len({len(r.prompt) for r in requests}) == 1, "bucket invariant"
        t0 = time.time()
        B = len(requests)
        prompts = jnp.asarray([r.prompt for r in requests], jnp.int32)
        cache = self._fresh_cache(B)
        cache, token = self._prefill(cache, prompts)

        budget = max(r.max_new_tokens for r in requests)
        budget = min(budget, self.scfg.max_len - prompts.shape[1] - 1)
        alive = np.ones(B, bool)
        for _ in range(budget):
            token, cache = self._decode(cache, token)
            ids = np.asarray(token)
            for i, r in enumerate(requests):
                if not alive[i]:
                    continue
                r.output.append(int(ids[i]))
                if (len(r.output) >= r.max_new_tokens
                        or int(ids[i]) == self.scfg.stop_token):
                    alive[i] = False
            if not alive.any():
                break
        dt = time.time() - t0
        for r in requests:
            r.done = True
            r.latency_s = dt
        self._tok_count += sum(len(r.output) for r in requests)
        self._decode_s += dt

    def serve(self, requests: Sequence[Request]) -> Dict[str, float]:
        """Bucket by prompt length, run every bucket, return stats."""
        buckets: Dict[int, List[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        t0 = time.time()
        for _, bucket in sorted(buckets.items()):
            for i in range(0, len(bucket), self.scfg.max_batch):
                self.run_batch(bucket[i:i + self.scfg.max_batch])
        wall = time.time() - t0
        toks = sum(len(r.output) for r in requests)
        return {"requests": len(requests), "tokens": toks,
                "wall_s": wall,
                "tok_per_s": toks / wall if wall else 0.0,
                "buckets": len(buckets)}


def synthetic_requests(n: int, vocab: int, *, prompt_lens=(8, 16),
                       max_new: int = 16, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = int(rng.choice(prompt_lens))
        out.append(Request(
            uid=i,
            prompt=rng.integers(0, vocab, size=plen).tolist(),
            max_new_tokens=max_new))
    return out
