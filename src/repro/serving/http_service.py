"""HTTP/JSON front door over :class:`~repro.serving.SearchService`.

``SearchHTTPService`` puts a network face on the in-process search service:
tickets become URLs, progress becomes a chunked JSONL stream, and the
process-wide metrics registry is scrapable at ``/metrics``.  Zero new
dependencies -- the server is stdlib ``http.server.ThreadingHTTPServer``,
the client ``http.client``.

Endpoints::

    POST   /v1/search                  submit -> 202 {uid, url, tenant}
                                       or 429 + Retry-After when the
                                       admission queue is full
    GET    /v1/search/<uid>            status; includes "result" once done
    DELETE /v1/search/<uid>            cancel (queued jobs finish instantly)
    GET    /v1/search/<uid>/progress   chunked application/x-ndjson: one
                                       Trial per line, then a terminal
                                       {"status": ..., "done": true} line
    GET    /v1/stats                   service + front-door + tenant stats
    GET    /metrics                    Prometheus text exposition (the
                                       repro.obs registry)

Scheduling semantics -- the part that makes this a *front door* rather
than a proxy:

  * **admission control**: at most ``HttpConfig.max_queue`` jobs wait for
    a worker slot; past that, submissions get ``429`` with a
    ``Retry-After`` header instead of unbounded queue growth;
  * **per-tenant fairness**: queued jobs are dequeued weighted
    round-robin across the ``tenant`` field of the request body, so one
    tenant's 10k-eval GA backlog cannot starve another tenant's
    interactive random/bo probes -- an interactive job waits at most one
    full WRR rotation, not the whole backlog;
  * **per-tenant accounting**: submissions, rejections, outcomes and
    eval budgets (``eps``) per tenant, surfaced in ``/v1/stats``.

Exactness carries over the wire: the front door drives the same
``SearchService.submit`` path as in-process callers, and JSON float
round-tripping is exact (``repr`` shortest-float), so a fixed-seed search
submitted over HTTP returns bit-identical history/assignment to the same
request run in-process (``tests/test_http_service.py`` locks this in).
Non-finite floats follow Python's JSON dialect (``Infinity``/``NaN``).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import threading
import time
from collections import deque
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.api import types as api_types
from repro.core import env as env_lib
from repro.costmodel import dataflows as dfl
from repro.obs import instrument as obs_instrument
from repro.obs import metrics as obs_metrics
from repro.serving.search_service import (SearchService, SearchTicket,
                                          ServiceConfig)


class QueueFull(Exception):
    """Admission control rejected a submission (HTTP 429)."""


@dataclasses.dataclass(frozen=True)
class HttpConfig:
    host: str = "127.0.0.1"
    port: int = 8731              # 0 -> ephemeral (tests)
    max_queue: int = 64           # jobs waiting for a slot; beyond -> 429
    max_running: Optional[int] = None   # None: the service's max_workers
    retry_after_s: float = 1.0    # advertised in the 429 Retry-After header
    default_tenant: str = "anon"  # jobs without a "tenant" field
    tenant_weights: Tuple[Tuple[str, int], ...] = ()   # WRR weights
    default_weight: int = 1       # weight of tenants not listed above
    default_eps: int = 600        # request defaults when the body omits them
    default_platform: str = "cloud"
    progress_poll_s: float = 0.05  # progress-stream poll granularity


# --------------------------------------------------------------------------
# Request / response JSON codecs.
# --------------------------------------------------------------------------
def request_from_spec(spec: dict, *, default_platform: str = "cloud",
                      default_eps: int = 600, default_tenant: str = "anon"
                      ) -> Tuple[api_types.SearchRequest, str]:
    """One request dict -> (SearchRequest, tenant).

    Env fields (``objective``/``constraint``/``platform``/``scenario``/
    ``dataflow``) and the core fields are popped; leftover unknown keys
    merge into ``options`` (an explicit ``options`` dict wins on
    conflicts) -- the same convention as the ``serve_search`` spec files.
    """
    spec = dict(spec)
    tenant = str(spec.pop("tenant", default_tenant))
    ecfg = env_lib.EnvConfig(
        objective=spec.pop("objective", "latency"),
        constraint=spec.pop("constraint", "area"),
        platform=spec.pop("platform", default_platform),
        scenario=spec.pop("scenario", "LP"),
        dataflow=dfl.DATAFLOW_NAMES.index(spec.pop("dataflow", "dla")))
    workload = spec.pop("workload")
    eps = int(spec.pop("eps", default_eps))
    seed = int(spec.pop("seed", 0))
    method = spec.pop("method", "two_stage")
    explicit = spec.pop("options", {})
    options = {**spec, **explicit}
    return api_types.SearchRequest(workload=workload, env=ecfg, eps=eps,
                                   seed=seed, method=method,
                                   options=options), tenant


def outcome_to_json(out: api_types.SearchOutcome) -> dict:
    d = {
        "method": out.method, "best_value": out.best_value,
        "feasible": out.feasible, "eps": out.eps, "seed": out.seed,
        "samples_to_convergence": out.samples_to_convergence,
        "wall_seconds": out.wall_seconds,
        "pe": np.asarray(out.pe).tolist(),
        "kt": np.asarray(out.kt).tolist(),
        "df": np.asarray(out.df).tolist(),
        "history": np.asarray(out.history).tolist(),
    }
    if out.frontier is not None:
        d["frontier"] = {k: np.asarray(v).tolist()
                         for k, v in out.frontier.items()}
    if out.telemetry is not None:
        d["telemetry"] = out.telemetry
    return d


# --------------------------------------------------------------------------
# Front-door scheduler: admission control + weighted round-robin fairness.
# --------------------------------------------------------------------------
class _Job:
    """One front-door submission: queued here first, a service ticket once
    a worker slot frees up."""

    __slots__ = ("uid", "tenant", "request", "created_at", "finished_at",
                 "ticket", "cancel_requested", "error", "_status", "_done")

    def __init__(self, uid: str, tenant: str,
                 request: api_types.SearchRequest):
        self.uid = uid
        self.tenant = tenant
        self.request = request
        self.created_at = time.time()
        self.finished_at: Optional[float] = None
        self.ticket: Optional[SearchTicket] = None
        self.cancel_requested = False
        self.error: Optional[str] = None
        self._status = "queued"    # pre-ticket: queued|cancelled|failed
        self._done = threading.Event()

    @property
    def status(self) -> str:
        t = self.ticket
        return t.status if t is not None else self._status

    def done(self) -> bool:
        t = self.ticket
        return t.done() if t is not None else self._done.is_set()

    def to_json(self, include_result: bool = True) -> dict:
        d = {"uid": self.uid, "url": f"/v1/search/{self.uid}",
             "tenant": self.tenant, "status": self.status,
             "method": self.request.method, "eps": self.request.eps,
             "seed": self.request.seed, "created_at": self.created_at}
        t = self.ticket
        if t is not None:
            d["trials"] = len(t.trials)
            if t.trials:
                d["best_value"] = t.trials[-1].best_value
                d["step"] = t.trials[-1].step
            if t.done():
                d["wall_seconds"] = t.wall_seconds
                if include_result and t.status == "done":
                    d["result"] = outcome_to_json(t._outcome)
                elif t._error is not None:
                    d["error"] = repr(t._error)
        elif self.error is not None:
            d["error"] = self.error
        return d


_TENANT_KEYS = ("submitted", "rejected", "completed", "cancelled", "failed",
                "eps_requested", "eps_finished")
_STATUS_KEY = {"done": "completed", "cancelled": "cancelled",
               "failed": "failed"}


class _FrontDoor:
    """Bounded admission queue feeding a SearchService, dequeued weighted
    round-robin across tenants.

    At most ``max_running`` jobs occupy service workers at once; the rest
    wait in per-tenant FIFO queues.  Each WRR turn grants a tenant
    ``weight`` consecutive dequeues before rotating, so relative long-run
    shares follow the weights while any single tenant's backlog depth is
    irrelevant to everyone else's wait.
    """

    def __init__(self, svc: SearchService, max_queue: int, max_running: int,
                 weights: Dict[str, int], default_weight: int = 1):
        self._svc = svc
        self.max_queue = int(max_queue)
        self.max_running = int(max_running)
        self._weights = dict(weights)
        self._default_weight = max(int(default_weight), 1)
        self._cv = threading.Condition()
        self._queues: Dict[str, deque] = {}
        self._order: List[str] = []     # tenants in first-seen order
        self._jobs: Dict[str, _Job] = {}
        self._uids = itertools.count()
        self._queued = 0
        self._running = 0
        self._rr_idx = 0
        self._rr_credit = 0
        self._rejected = 0
        self._tenants: Dict[str, Dict[str, int]] = {}
        self._closed = False
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="front-door-scheduler")
        self._thread.start()

    def _weight(self, tenant: str) -> int:
        return max(int(self._weights.get(tenant, self._default_weight)), 1)

    def _tenant_entry(self, tenant: str) -> Dict[str, int]:
        e = self._tenants.get(tenant)
        if e is None:
            e = self._tenants[tenant] = {k: 0 for k in _TENANT_KEYS}
        return e

    # -- client side --------------------------------------------------------
    def submit(self, request: api_types.SearchRequest, tenant: str) -> _Job:
        with self._cv:
            if self._closed:
                raise RuntimeError("front door is closed")
            e = self._tenant_entry(tenant)
            if self._queued >= self.max_queue:
                self._rejected += 1
                e["rejected"] += 1
                raise QueueFull(
                    f"admission queue full ({self._queued}/{self.max_queue})")
            job = _Job(str(next(self._uids)), tenant, request)
            q = self._queues.get(tenant)
            if q is None:
                q = self._queues[tenant] = deque()
                self._order.append(tenant)
                if len(self._order) == 1:
                    self._rr_credit = self._weight(tenant)
            q.append(job)
            self._jobs[job.uid] = job
            self._queued += 1
            e["submitted"] += 1
            e["eps_requested"] += request.eps
            obs_instrument.HTTP_QUEUE_DEPTH.set(self._queued)
            self._cv.notify_all()
        return job

    def get(self, uid: str) -> Optional[_Job]:
        with self._cv:
            return self._jobs.get(uid)

    def cancel(self, uid: str) -> Optional[_Job]:
        """Cancel a job: a still-queued one finishes right here; a running
        one is cancelled through its service ticket."""
        with self._cv:
            job = self._jobs.get(uid)
            if job is None:
                return None
            job.cancel_requested = True
            if job.ticket is None and job._status == "queued":
                self._queues[job.tenant].remove(job)
                self._queued -= 1
                self._finish_pre_ticket(job, "cancelled")
                obs_instrument.HTTP_QUEUE_DEPTH.set(self._queued)
                self._cv.notify_all()
                return job
            ticket = job.ticket
        if ticket is not None:
            ticket.cancel()
        return job

    def stats(self) -> dict:
        with self._cv:
            tenants = {}
            for t, e in self._tenants.items():
                d = dict(e)
                d["queued"] = len(self._queues.get(t, ()))
                d["weight"] = self._weight(t)
                tenants[t] = d
            return {"queued": self._queued, "running": self._running,
                    "rejected": self._rejected,
                    "max_queue": self.max_queue,
                    "max_running": self.max_running,
                    "jobs": len(self._jobs), "tenants": tenants}

    def close(self) -> None:
        with self._cv:
            self._closed = True
            # Queued jobs will never get a slot: terminate them so any
            # result()/progress waiter unblocks instead of hanging.
            for q in self._queues.values():
                while q:
                    job = q.popleft()
                    self._queued -= 1
                    self._finish_pre_ticket(job, "cancelled")
            self._cv.notify_all()
        self._thread.join(timeout=5.0)

    # -- scheduler side -----------------------------------------------------
    def _finish_pre_ticket(self, job: _Job, status: str,
                           error: Optional[str] = None) -> None:
        """Terminate a job that never reached the service (under _cv)."""
        job._status = status
        job.error = error
        job.finished_at = time.time()
        e = self._tenant_entry(job.tenant)
        e[_STATUS_KEY.get(status, "failed")] += 1
        job._done.set()

    def _next_job_locked(self) -> Optional[_Job]:
        """Weighted round-robin dequeue across tenants (under _cv)."""
        if not self._order or not any(self._queues.values()):
            return None
        n = len(self._order)
        for _ in range(n + 1):
            tenant = self._order[self._rr_idx % n]
            q = self._queues[tenant]
            if q and self._rr_credit > 0:
                self._rr_credit -= 1
                job = q.popleft()
                if not q or self._rr_credit == 0:
                    self._rr_idx += 1
                    self._rr_credit = self._weight(
                        self._order[self._rr_idx % n])
                return job
            self._rr_idx += 1
            self._rr_credit = self._weight(self._order[self._rr_idx % n])
        return None

    def _loop(self) -> None:
        while True:
            with self._cv:
                while (not self._closed
                       and (self._queued == 0
                            or self._running >= self.max_running)):
                    self._cv.wait()
                if self._closed:
                    return
                job = self._next_job_locked()
                if job is None:
                    continue
                self._queued -= 1
                self._running += 1
                obs_instrument.HTTP_QUEUE_DEPTH.set(self._queued)
            try:
                ticket = self._svc.submit(job.request)
            except BaseException as e:  # noqa: BLE001 -- job reports it
                with self._cv:
                    self._running -= 1
                    self._finish_pre_ticket(job, "failed", error=repr(e))
                    self._cv.notify_all()
                continue
            job.ticket = ticket
            if job.cancel_requested:    # cancelled in the hand-off window
                ticket.cancel()
            ticket.add_done_callback(
                lambda _t, job=job: self._job_finished(job))

    def _job_finished(self, job: _Job) -> None:
        job.finished_at = time.time()
        key = _STATUS_KEY.get(job.status, "failed")
        with self._cv:
            self._running -= 1
            e = self._tenant_entry(job.tenant)
            e[key] += 1
            if key == "completed":
                e["eps_finished"] += job.request.eps
            self._cv.notify_all()


# --------------------------------------------------------------------------
# The HTTP layer.
# --------------------------------------------------------------------------
class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-search"

    def log_message(self, *args) -> None:   # route metrics, not stderr spam
        pass

    @property
    def hub(self) -> "SearchHTTPService":
        return self.server.hub  # type: ignore[attr-defined]

    # -- plumbing -----------------------------------------------------------
    def _send_json(self, code: int, obj: dict, headers=()) -> int:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in headers:
            self.send_header(k, str(v))
        self.end_headers()
        self.wfile.write(body)
        return code

    def _send_text(self, code: int, text: str, ctype: str) -> int:
        body = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)
        return code

    def _chunk(self, text: str) -> None:
        data = text.encode()
        self.wfile.write(f"{len(data):X}\r\n".encode() + data + b"\r\n")
        self.wfile.flush()

    def _observe(self, route: str, code: int, t0: float) -> None:
        obs_instrument.HTTP_REQUESTS.inc(route=route, code=str(code))
        obs_instrument.HTTP_REQUEST_SECONDS.observe(
            time.perf_counter() - t0, route=route)

    def _dispatch(self, verb: str) -> None:
        t0 = time.perf_counter()
        route, code = "other", 500
        try:
            route, code = self._route(verb)
        except (BrokenPipeError, ConnectionResetError):
            route, code = "disconnect", 0
        except Exception as e:  # noqa: BLE001 -- never kill the connection
            try:
                code = self._send_json(500, {"error": repr(e)})
            except OSError:
                pass
        finally:
            self._observe(route, code, t0)

    do_GET = lambda self: self._dispatch("GET")          # noqa: E731
    do_POST = lambda self: self._dispatch("POST")        # noqa: E731
    do_DELETE = lambda self: self._dispatch("DELETE")    # noqa: E731

    # -- routing ------------------------------------------------------------
    def _route(self, verb: str) -> Tuple[str, int]:
        parts = [p for p in self.path.split("?")[0].split("/") if p]
        if verb == "POST" and parts == ["v1", "search"]:
            return "/v1/search", self._post_search()
        if verb == "GET" and parts == ["v1", "stats"]:
            return "/v1/stats", self._send_json(200, self.hub.stats())
        if verb == "GET" and parts == ["metrics"]:
            return "/metrics", self._send_text(
                200, obs_metrics.REGISTRY.prometheus_text(),
                "text/plain; version=0.0.4")
        if len(parts) == 3 and parts[:2] == ["v1", "search"]:
            uid = parts[2]
            if verb == "GET":
                return "/v1/search/{uid}", self._get_search(uid)
            if verb == "DELETE":
                return "/v1/search/{uid}", self._delete_search(uid)
        if (len(parts) == 4 and parts[:2] == ["v1", "search"]
                and parts[3] == "progress" and verb == "GET"):
            return "/v1/search/{uid}/progress", self._stream_progress(
                parts[2])
        return "other", self._send_json(404, {"error": "no such route"})

    def _post_search(self) -> int:
        try:
            n = int(self.headers.get("Content-Length", 0))
            spec = json.loads(self.rfile.read(n) or b"{}")
            cfg = self.hub.http_cfg
            request, tenant = request_from_spec(
                spec, default_platform=cfg.default_platform,
                default_eps=cfg.default_eps,
                default_tenant=cfg.default_tenant)
        except Exception as e:  # noqa: BLE001 -- malformed body
            return self._send_json(400, {"error": f"bad request: {e!r}"})
        try:
            job = self.hub.front.submit(request, tenant)
        except QueueFull as e:
            return self._send_json(
                429, {"error": str(e)},
                headers=[("Retry-After",
                          f"{self.hub.http_cfg.retry_after_s:g}")])
        return self._send_json(202, job.to_json(include_result=False))

    def _get_search(self, uid: str) -> int:
        job = self.hub.front.get(uid)
        if job is None:
            return self._send_json(404, {"error": f"no such search {uid}"})
        return self._send_json(200, job.to_json())

    def _delete_search(self, uid: str) -> int:
        job = self.hub.front.cancel(uid)
        if job is None:
            return self._send_json(404, {"error": f"no such search {uid}"})
        return self._send_json(200, {"uid": job.uid, "status": job.status,
                                     "cancel_requested": True})

    def _stream_progress(self, uid: str) -> int:
        job = self.hub.front.get(uid)
        if job is None:
            return self._send_json(404, {"error": f"no such search {uid}"})
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()
        poll = self.hub.http_cfg.progress_poll_s
        sent = 0
        while True:
            trials = job.ticket.trials if job.ticket is not None else ()
            while sent < len(trials):
                tr = trials[sent]
                sent += 1
                rec = {"step": tr.step, "value": tr.value,
                       "best_value": tr.best_value}
                if tr.shard is not None:
                    rec["shard"] = tr.shard
                self._chunk(json.dumps(rec) + "\n")
            if job.done():
                break
            time.sleep(poll)
        self._chunk(json.dumps({"status": job.status, "done": True}) + "\n")
        self._chunk("")   # 0\r\n\r\n terminator
        return 200


class SearchHTTPService:
    """The network front door: one SearchService + scheduler + HTTP server.

    ::

        with SearchHTTPService(http_cfg=HttpConfig(port=0)) as hub:
            hub.start()                      # serve on a background thread
            client = SearchClient(port=hub.port)
            uid = client.submit({"workload": "ncf", "method": "random",
                                 "eps": 300, "tenant": "alice"})["uid"]
            out = client.result(uid)

    Or ``serve_forever()`` on the main thread (the
    ``repro.launch.serve_http`` CLI does exactly that).
    """

    def __init__(self, service_cfg: Optional[ServiceConfig] = None,
                 http_cfg: Optional[HttpConfig] = None,
                 service: Optional[SearchService] = None):
        self.http_cfg = http_cfg or HttpConfig()
        self.service = service if service is not None else SearchService(
            service_cfg or ServiceConfig())
        self._owns_service = service is None
        max_running = (self.http_cfg.max_running
                       if self.http_cfg.max_running is not None
                       else self.service.cfg.max_workers)
        self.front = _FrontDoor(self.service, self.http_cfg.max_queue,
                                max_running,
                                dict(self.http_cfg.tenant_weights),
                                self.http_cfg.default_weight)
        self.httpd = ThreadingHTTPServer(
            (self.http_cfg.host, self.http_cfg.port), _Handler)
        self.httpd.daemon_threads = True
        self.httpd.hub = self   # type: ignore[attr-defined]
        self._thread: Optional[threading.Thread] = None
        self._closed = False

    @property
    def port(self) -> int:
        return self.httpd.server_address[1]

    @property
    def url(self) -> str:
        host = self.httpd.server_address[0]
        return f"http://{host}:{self.port}"

    def start(self) -> "SearchHTTPService":
        """Serve on a daemon thread; returns self (fluent for tests)."""
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        name="http-front-door", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self._thread = threading.current_thread()
        self.httpd.serve_forever()

    def stats(self) -> dict:
        return {"service": self.service.stats(),
                "front_door": self.front.stats()}

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._thread is not None and self._thread is not \
                threading.current_thread():
            self.httpd.shutdown()
            self._thread.join(timeout=10.0)
        self.httpd.server_close()
        self.front.close()
        if self._owns_service:
            self.service.close()

    def __enter__(self) -> "SearchHTTPService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# --------------------------------------------------------------------------
# Minimal stdlib client (tests, CI smoke, benchmarks).
# --------------------------------------------------------------------------
class SearchClient:
    """Thin ``http.client`` wrapper speaking the front door's JSON dialect.

    One fresh connection per call (progress streams hold theirs open), so
    instances are safe to share across threads.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 8731,
                 timeout: float = 300.0):
        self.host = host
        self.port = int(port)
        self.timeout = timeout

    def _request(self, verb: str, path: str, body: Optional[dict] = None):
        import http.client

        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            payload = json.dumps(body).encode() if body is not None else None
            conn.request(verb, path, body=payload,
                         headers={"Content-Type": "application/json"}
                         if payload else {})
            resp = conn.getresponse()
            data = resp.read()
            return resp.status, dict(resp.getheaders()), data
        finally:
            conn.close()

    def submit(self, spec: dict) -> dict:
        """POST a search; raises :class:`QueueFull` on 429."""
        status, headers, data = self._request("POST", "/v1/search", spec)
        if status == 429:
            raise QueueFull(
                f"429: retry after {headers.get('Retry-After')}s")
        if status != 202:
            raise RuntimeError(f"submit failed: {status} {data!r}")
        return json.loads(data)

    def status(self, uid: str) -> dict:
        status, _, data = self._request("GET", f"/v1/search/{uid}")
        if status != 200:
            raise KeyError(f"search {uid}: {status} {data!r}")
        return json.loads(data)

    def cancel(self, uid: str) -> dict:
        status, _, data = self._request("DELETE", f"/v1/search/{uid}")
        if status != 200:
            raise KeyError(f"search {uid}: {status} {data!r}")
        return json.loads(data)

    def result(self, uid: str, timeout: float = 300.0,
               poll_s: float = 0.05) -> dict:
        """Poll until the search finishes; returns the result dict.
        Raises RuntimeError for cancelled/failed searches."""
        deadline = time.time() + timeout
        while True:
            d = self.status(uid)
            if d["status"] == "done":
                return d["result"]
            if d["status"] in ("cancelled", "failed"):
                raise RuntimeError(
                    f"search {uid} {d['status']}: {d.get('error')}")
            if time.time() > deadline:
                raise TimeoutError(f"search {uid} still {d['status']}")
            time.sleep(poll_s)

    def progress(self, uid: str):
        """Yield progress records from the chunked JSONL stream, the
        terminal ``{"status": ..., "done": true}`` record last."""
        import http.client

        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            conn.request("GET", f"/v1/search/{uid}/progress")
            resp = conn.getresponse()
            if resp.status != 200:
                raise KeyError(f"search {uid}: {resp.status}")
            for line in resp:   # http.client decodes the chunking
                line = line.strip()
                if line:
                    yield json.loads(line)
        finally:
            conn.close()

    def stats(self) -> dict:
        status, _, data = self._request("GET", "/v1/stats")
        if status != 200:
            raise RuntimeError(f"stats: {status}")
        return json.loads(data)

    def metrics_text(self) -> str:
        status, _, data = self._request("GET", "/metrics")
        if status != 200:
            raise RuntimeError(f"metrics: {status}")
        return data.decode()
