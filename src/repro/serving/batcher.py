"""Cross-request cost-eval batcher: one dispatch stream for N searches.

Concurrent searches running on worker threads each produce host-side batches
of genome evaluations (random/grid/bo route their ``eval_fn`` here).  Instead
of every search driving its own serial jit-dispatch loop, evaluations are
funneled through one dispatcher thread that:

  1. flattens every pending request's genomes into per-layer *points*
     ``(layer fields, pe, kt, df)`` -- the cost model is per-point, so points
     from different workloads concatenate freely (multi-tenant batching);
  2. dedupes identical points across (and within) requests with one
     ``np.unique`` pass;
  3. consults the :class:`~repro.serving.cost_cache.CostMemoCache` and
     evaluates only the genuinely new points in ONE fused call -- the Pallas
     per-row-layers kernel (``ops.batched_cost_multi``) on TPU, the jitted
     jnp oracle elsewhere;
  4. re-assembles each request's per-layer value tensor and aggregates it
     with the exact jnp reductions of :func:`repro.core.env.genome_cost`.

Exactness: per-point cost values are bit-identical whatever batch they are
computed in (the model is elementwise), and the final per-genome reduction
runs over the same ``(b, N)`` shape the serial engine reduces over -- so a
search through the batcher returns bit-identical fitness to the same search
run serially, cache hits and cross-request fusion included.  This is the
property ``tests/test_search_service.py`` locks in.  (It holds on the jnp
oracle path, i.e. everywhere but TPU; the TPU Pallas kernel agrees with
the oracle to float32 allclose, like every kernel/oracle pair here.)
"""
from __future__ import annotations

import functools
import threading
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as env_lib
from repro.costmodel import maestro
from repro.costmodel.layers import NUM_FIELDS
from repro.obs import instrument as obs_instrument
from repro.obs import recorder as obs_recorder
from repro.obs import state as obs_state
from repro.obs import trace as obs_trace
from repro.serving.cost_cache import CostMemoCache

_PE_COL = NUM_FIELDS
_KT_COL = NUM_FIELDS + 1
_DF_COL = NUM_FIELDS + 2
ROW_WIDTH = NUM_FIELDS + 3   # layer fields + pe + kt + df


@functools.lru_cache(maxsize=None)
def _agg_fn(ecfg: "env_lib.EnvConfig"):
    """Jitted (b, N, 4) -> (b,) fitness: the SAME ``env.aggregate_costs``
    reduction ``genome_cost``/``_decode_and_eval`` run, over the same
    (b, N) shape, which is what keeps batched results bit-identical to
    serial ones."""

    @jax.jit
    def f(vals, budget):
        perf, _, feas = env_lib.aggregate_costs(
            vals[..., 0], vals[..., 1], vals[..., 2], vals[..., 3],
            ecfg, budget)
        return jnp.where(feas, perf, jnp.inf)

    return f


@functools.lru_cache(maxsize=None)
def _agg_multi_fn(ecfg: "env_lib.EnvConfig"):
    """Jitted (b, N, 4) -> (b, 4) aggregated (lat, en, area, pw): the SAME
    ``env.aggregate_costs_multi`` reduction the NSGA-II in-graph fitness
    runs, over the same (b, N) shape -- batched multi-objective results
    stay bit-identical to serial ones."""

    @jax.jit
    def f(vals, budget):
        tl, te, ta, tp, _ = env_lib.aggregate_costs_multi(
            vals[..., 0], vals[..., 1], vals[..., 2], vals[..., 3],
            ecfg, budget)
        return jnp.stack([tl, te, ta, tp], axis=-1)

    return f


@jax.jit
def _flat_cost(layers, pe, kt, df):
    """(M, NUM_FIELDS) x (M,) -> (M, 4) point costs via the jnp oracle."""
    out = maestro.evaluate(layers, pe, kt, df)
    return jnp.stack([out.latency, out.energy, out.area, out.power], axis=-1)


def _next_pow2(n: int, lo: int = 256) -> int:
    m = lo
    while m < n:
        m *= 2
    return m


class _Item:
    """One in-flight eval request: points + how to aggregate them."""

    __slots__ = ("points", "shape", "agg_key", "budget", "multi", "event",
                 "fit", "error", "recorder", "t_enqueue")

    def __init__(self, points, shape, agg_key, budget, multi=False):
        self.points = points          # (b*N, ROW_WIDTH) f32
        self.shape = shape            # (b, N)
        self.agg_key = agg_key        # the request's EnvConfig (hashable)
        self.budget = budget          # f32 scalar
        self.multi = multi            # (b, 4) aggregated costs vs (b,) fit
        self.event = threading.Event()
        self.fit: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None
        # Telemetry attribution: the submitting search's flight recorder is
        # captured at submit time (on the search worker's thread), so the
        # dispatcher thread credits queue-wait / fuse / cache stats to the
        # right search even when one dispatch fuses N searches' requests.
        self.recorder = None
        self.t_enqueue = 0.0


class CostEvalBatcher:
    """Fuses concurrent searches' cost evaluations into single dispatches.

    ``window_ms`` is the accumulation window after the first pending item;
    while a dispatch executes, new arrivals queue up naturally, so steady-
    state fusion widths track the number of concurrently evaluating
    searches.  ``use_kernel=None`` auto-selects the Pallas per-row-layers
    kernel on TPU and the jitted jnp oracle elsewhere (interpret-mode Pallas
    would dominate CPU runs).

    ``dispatch_workers`` sizes the dispatch pool: with N > 1, up to N fused
    dispatches execute concurrently (XLA releases the GIL during execution,
    and the host-side flatten/unique/reassemble work overlaps too).  Fusion
    grouping never changes values -- the cost model is elementwise per point
    and each item aggregates only its own points -- so pooled dispatch stays
    bit-identical to the single-thread dispatcher, cache races included
    (two workers evaluating the same point store the same bytes).
    """

    def __init__(self, cache: Optional[CostMemoCache] = None,
                 window_ms: float = 2.0,
                 use_kernel: Optional[bool] = None,
                 dispatch_workers: int = 1,
                 join_timeout_s: float = 5.0):
        self.cache = cache if cache is not None else CostMemoCache()
        self._window_s = max(window_ms, 0.0) / 1e3
        self._join_timeout_s = float(join_timeout_s)
        self._use_kernel = (use_kernel if use_kernel is not None
                            else jax.default_backend() == "tpu")
        self._pending: List[_Item] = []
        self._cv = threading.Condition()
        self._closed = False
        self._stats_lock = threading.Lock()
        self._active = 0
        self._stats = {
            "dispatches": 0, "fused_dispatches": 0, "items": 0,
            "points": 0, "unique_points": 0, "fresh_points": 0,
            "max_items_per_dispatch": 0, "max_points_per_dispatch": 0,
            "dispatch_workers": max(int(dispatch_workers), 1),
            "max_concurrent_dispatches": 0,
            "leaked_dispatch_threads": 0,
        }
        self._threads = [
            threading.Thread(target=self._loop,
                             name=f"cost-eval-batcher-{i}", daemon=True)
            for i in range(max(int(dispatch_workers), 1))]
        for t in self._threads:
            t.start()

    # -- client side --------------------------------------------------------
    def evaluate(self, layers, pe, kt, df, ecfg, budget) -> np.ndarray:
        """Blocking genome-batch evaluation; safe from any thread.

        layers: (N, NUM_FIELDS); pe/kt: (b, N) raw f32 values; df: scalar or
        (b, N); ecfg: the request's EnvConfig; budget: the env's constraint
        budget.  Returns (b,) f32 fitness (+inf = infeasible), bit-identical
        to ``_decode_and_eval`` on the same genomes.
        """
        return self._submit(layers, pe, kt, df, ecfg, budget, multi=False)

    def evaluate_costs(self, layers, pe, kt, df, ecfg, budget) -> np.ndarray:
        """Like :meth:`evaluate` but returns (b, 4) aggregated whole-model
        (lat, en, area, pw) costs instead of scalar fitness -- the eval hook
        of the multi-objective ``nsga2`` engine.  Bit-identical to the
        engine's in-graph ``fitness`` on the same genomes; shares the same
        per-point dedup, memo cache and fused dispatch as everything else.
        """
        return self._submit(layers, pe, kt, df, ecfg, budget, multi=True)

    def _submit(self, layers, pe, kt, df, ecfg, budget,
                multi: bool) -> np.ndarray:
        if self._closed:
            raise RuntimeError("CostEvalBatcher is closed")
        pe = np.asarray(pe, np.float32)
        points = pack_point_rows(layers, pe, kt, df)
        item = _Item(points, pe.shape, ecfg, np.float32(budget), multi=multi)
        if obs_state.enabled:
            item.recorder = obs_recorder.current_recorder()
            item.t_enqueue = time.perf_counter()
        with self._cv:
            if self._closed:
                raise RuntimeError("CostEvalBatcher is closed")
            self._pending.append(item)
            obs_instrument.BATCHER_QUEUE_DEPTH.set(len(self._pending))
            self._cv.notify()
        item.event.wait()
        if item.error is not None:
            raise item.error
        return item.fit

    def stats(self) -> Dict[str, float]:
        with self._stats_lock:
            s = dict(self._stats)
        cache = {f"cache_{k}": v for k, v in self.cache.stats().items()}
        # The cache_ prefix must keep the two stat families disjoint: a
        # batcher-native key that ever starts with cache_ would silently
        # shadow (or be shadowed by) a cache stat in this merge.
        overlap = set(s) & set(cache)
        assert not overlap, f"batcher/cache stats keys collide: {overlap}"
        s.update(cache)
        return s

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        leaked = 0
        for t in self._threads:
            t.join(timeout=self._join_timeout_s)
            # join() returning proves nothing by itself: with a timeout it
            # returns whether or not the thread died.  A still-alive worker
            # is hung inside a dispatch -- it will never drain _pending, so
            # every queued waiter would block forever if we stayed silent.
            if t.is_alive():
                leaked += 1
        if leaked:
            with self._cv:
                stranded, self._pending = self._pending, []
            err = RuntimeError(
                f"CostEvalBatcher closed with {leaked} hung dispatch "
                f"thread(s); pending evaluations abandoned")
            for it in stranded:
                if not it.event.is_set():
                    it.error = err
                    it.event.set()
        with self._stats_lock:
            self._stats["leaked_dispatch_threads"] = leaked

    # -- dispatcher side ----------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if self._closed and not self._pending:
                    return
            if self._window_s:
                time.sleep(self._window_s)
            with self._cv:
                items, self._pending = self._pending, []
                obs_instrument.BATCHER_QUEUE_DEPTH.set(0)
            if not items:
                continue
            with self._stats_lock:
                self._active += 1
                self._stats["max_concurrent_dispatches"] = max(
                    self._stats["max_concurrent_dispatches"], self._active)
            try:
                self._dispatch(items)
            except BaseException as e:  # noqa: BLE001 -- never stall waiters
                for it in items:
                    if not it.event.is_set():
                        it.error = e
                        it.event.set()
            finally:
                with self._stats_lock:
                    self._active -= 1

    def _dispatch(self, items: List[_Item]) -> None:
        t0 = time.perf_counter() if obs_state.enabled else 0.0
        sp = obs_trace.span("batcher.dispatch").__enter__()
        rows = (items[0].points if len(items) == 1
                else np.concatenate([it.points for it in items], axis=0))
        uniq, inv = np.unique(rows, axis=0, return_inverse=True)
        keys = [u.tobytes() for u in uniq]
        values, miss_index = self.cache.get_many(keys)
        t_eval = 0.0
        if miss_index:
            te = time.perf_counter() if obs_state.enabled else 0.0
            fresh = self._eval_points(uniq[miss_index])
            if obs_state.enabled:
                t_eval = time.perf_counter() - te
            # Cache per-row COPIES: a row view would pin the whole dispatch's
            # result array in memory for as long as any one point stays hot.
            self.cache.put_many([keys[i] for i in miss_index],
                                [f.copy() for f in fresh])
            for i, v in zip(miss_index, fresh):
                values[i] = v
        per_point = np.stack(values)[inv]          # (P, 4)
        sp.set(items=len(items), points=len(rows), unique=len(uniq),
               fresh=len(miss_index)).__exit__(None, None, None)
        if obs_state.enabled:
            self._record_dispatch(items, t0, time.perf_counter() - t0,
                                  t_eval, len(uniq), miss_index, inv)

        with self._stats_lock:
            s = self._stats
            s["dispatches"] += 1
            s["fused_dispatches"] += len(items) > 1
            s["items"] += len(items)
            s["points"] += len(rows)
            s["unique_points"] += len(uniq)
            s["fresh_points"] += len(miss_index)
            s["max_items_per_dispatch"] = max(
                s["max_items_per_dispatch"], len(items))
            s["max_points_per_dispatch"] = max(
                s["max_points_per_dispatch"], len(rows))

        off = 0
        for it in items:
            n = it.points.shape[0]
            vals = per_point[off:off + n].reshape(it.shape + (4,))
            off += n
            agg = _agg_multi_fn(it.agg_key) if it.multi else _agg_fn(
                it.agg_key)
            it.fit = np.asarray(agg(jnp.asarray(vals), it.budget))
            it.event.set()

    def _record_dispatch(self, items: List[_Item], t0: float, dt: float,
                         t_eval: float, n_uniq: int, miss_index, inv) -> None:
        """Telemetry for one finished dispatch: process-wide metrics plus
        per-item flight-recorder attribution (each rider is credited its own
        share of the fused batch, including its own cached-vs-fresh split).

        Fresh credit is *first-claim*: when several submitted points (same
        item or different riders) collapse onto one fresh unique row, only
        the first submitted occurrence is credited ``fresh`` -- the rest
        ride the same evaluation and count ``cached``.  That keeps
        ``sum(per-rider fresh) == dispatcher fresh_points`` exact instead
        of drifting whenever duplicates happen to fuse."""
        n_points = sum(it.points.shape[0] for it in items)
        obs_instrument.BATCHER_DISPATCHES.inc()
        obs_instrument.BATCHER_POINTS.inc(n_points, kind="submitted")
        obs_instrument.BATCHER_POINTS.inc(n_uniq, kind="unique")
        obs_instrument.BATCHER_POINTS.inc(len(miss_index), kind="fresh")
        obs_instrument.BATCHER_FUSE_WIDTH.observe(len(items))
        obs_instrument.BATCHER_DISPATCH_SECONDS.observe(dt)
        fresh_pp = None
        if any(it.recorder is not None for it in items):
            inv = np.asarray(inv).ravel()
            first = np.full(n_uniq, len(inv), dtype=np.int64)
            np.minimum.at(first, inv, np.arange(len(inv)))
            fresh_pp = np.zeros(len(inv), bool)   # per submitted point
            fresh_pp[first[miss_index]] = True    # first claimant only
        off = 0
        for it in items:
            n = it.points.shape[0]
            wait = (t0 - it.t_enqueue) if it.t_enqueue else 0.0
            obs_instrument.BATCHER_QUEUE_WAIT.observe(max(wait, 0.0))
            rec = it.recorder
            if rec is not None:
                n_fresh = int(fresh_pp[off:off + n].sum())
                rec.add("eval_batches")
                rec.add("points", n)
                rec.add("fresh_points", n_fresh)
                rec.add("cached_points", n - n_fresh)
                if it.t_enqueue:
                    rec.observe("queue_wait_s", max(wait, 0.0))
                rec.observe("dispatch_s", dt)
                rec.observe("device_s", t_eval)
                rec.observe("fuse_width", len(items))
            off += n

    def _eval_points(self, rows: np.ndarray) -> np.ndarray:
        return eval_point_rows(rows, self._use_kernel)


def eval_point_rows(rows: np.ndarray, use_kernel: bool) -> np.ndarray:
    """Evaluate (M, ROW_WIDTH) fresh points -> (M, 4) f32 costs.

    Per-row results are bit-stable across batch size and padding (the
    computation is elementwise per row), so any caller packing the same row
    gets the same bytes -- the property both the memo cache and serial ==
    service-batched byte-identity rest on.
    """
    M = rows.shape[0]
    if use_kernel:
        from repro.kernels import ops

        # Tile the flat point list into the kernel's (B', TN) lanes.
        from repro.kernels.costmodel_eval import TN
        Mp = -(-M // TN) * TN
        pad = np.ones((Mp - M, ROW_WIDTH), np.float32)
        pad[:, NUM_FIELDS - 1] = 0.0            # repeat=0: benign rows
        rp = np.concatenate([rows, pad], axis=0) if Mp > M else rows
        with obs_instrument.dispatch_span("cost_eval_kernel", key=Mp):
            lat, en, area, pw = ops.batched_cost_multi(
                rp[:, :NUM_FIELDS].reshape(-1, TN, NUM_FIELDS),
                rp[:, _PE_COL].reshape(-1, TN),
                rp[:, _KT_COL].reshape(-1, TN),
                rp[:, _DF_COL].reshape(-1, TN))
        out = np.stack([np.asarray(lat), np.asarray(en),
                        np.asarray(area), np.asarray(pw)],
                       axis=-1).reshape(Mp, 4)
        return out[:M]
    # jnp-oracle path: pad to pow2 buckets to bound recompiles.
    Mp = _next_pow2(M)
    rp = np.ones((Mp, ROW_WIDTH), np.float32)
    rp[:M] = rows
    with obs_instrument.dispatch_span("cost_eval_jnp", key=Mp):
        out = _flat_cost(rp[:, :NUM_FIELDS], rp[:, _PE_COL],
                         rp[:, _KT_COL], rp[:, _DF_COL])
        out = np.asarray(out)
    return out[:M]


def pack_point_rows(layers: np.ndarray, pe, kt, df) -> np.ndarray:
    """(N, NUM_FIELDS) layers x (b, N) assignments -> (b*N, ROW_WIDTH) rows
    in the batcher/cache key format."""
    layers = np.asarray(layers, np.float32)
    pe = np.asarray(pe, np.float32)
    b, N = pe.shape
    kt = np.broadcast_to(np.asarray(kt, np.float32), (b, N))
    df = np.broadcast_to(np.asarray(df, np.float32), (b, N))
    points = np.empty((b * N, ROW_WIDTH), np.float32)
    points[:, :NUM_FIELDS] = np.broadcast_to(
        layers, (b, N, NUM_FIELDS)).reshape(-1, NUM_FIELDS)
    points[:, _PE_COL] = pe.ravel()
    points[:, _KT_COL] = kt.ravel()
    points[:, _DF_COL] = df.ravel()
    return points


def make_local_costs_eval(env, ecfg, use_kernel: Optional[bool] = None):
    """Serial nsga2's default fitness hook: ``eval_fn(pe, kt, df) -> (b, 4)``
    running the EXACT per-point and aggregation programs a
    :class:`CostEvalBatcher` dispatches -- minus the queue, fusion window
    and memo cache.  Because ``eval_point_rows`` is bit-stable per row and
    ``_agg_multi_fn`` is the same jitted program over the same (b, N, 4)
    shape, a serial ``run_search`` and a service-batched one produce
    byte-identical outcomes by construction (benchmarks/bench_frontier.py
    asserts it end to end).
    """
    layers = np.asarray(env.layers, np.float32)
    budget = np.float32(env.budget)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    agg = _agg_multi_fn(ecfg)

    def eval_fn(pe, kt, df):
        pe = np.asarray(pe, np.float32)
        b, N = pe.shape
        rows = pack_point_rows(layers, pe, kt, df)
        vals = eval_point_rows(rows, use_kernel).reshape(b, N, 4)
        return np.asarray(agg(jnp.asarray(vals), budget))

    return eval_fn
