from repro.serving.engine import Engine, Request, ServeConfig  # noqa: F401
