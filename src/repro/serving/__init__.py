from repro.serving.batcher import CostEvalBatcher  # noqa: F401
from repro.serving.cost_cache import (  # noqa: F401
    CostMemoCache,
    PersistentCostCache,
)
from repro.serving.engine import Engine, Request, ServeConfig  # noqa: F401
from repro.serving.http_service import (  # noqa: F401
    HttpConfig,
    QueueFull,
    SearchClient,
    SearchHTTPService,
    outcome_to_json,
    request_from_spec,
)
from repro.serving.search_service import (  # noqa: F401
    BATCHED_METHODS,
    RAW_BATCHED_METHODS,
    SearchCancelled,
    SearchService,
    SearchTicket,
    ServiceConfig,
)
