from repro.serving.batcher import CostEvalBatcher  # noqa: F401
from repro.serving.cost_cache import CostMemoCache  # noqa: F401
from repro.serving.engine import Engine, Request, ServeConfig  # noqa: F401
from repro.serving.search_service import (  # noqa: F401
    BATCHED_METHODS,
    RAW_BATCHED_METHODS,
    SearchCancelled,
    SearchService,
    SearchTicket,
    ServiceConfig,
)
