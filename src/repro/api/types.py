"""Canonical request/outcome schema shared by every search method.

One ``SearchRequest`` describes a resource-assignment search independently
of the optimizer that runs it; one ``SearchOutcome`` reports the result in
the same shape for REINFORCE, GA, SA, BO, random, grid, A2C/PPO2 and the
two-stage ConfuciuX pipeline alike.  This is what lets the Table IV/V
benchmarks (sample-efficiency vs. alternatives) iterate over method *names*
instead of per-method configs and result types.

Sample accounting: ``eps`` counts whole-model evaluations -- one RL episode,
one GA individual, one random/grid/SA/BO probe each cost exactly one sample,
matching how the paper budgets "epochs" across methods (SIV-A3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import numpy as np

from repro.core import env as env_lib
from repro.costmodel import workloads as workloads_lib


class Trial(NamedTuple):
    """One streamed progress report from a running optimizer.

    ``step`` is the number of samples (whole-model evaluations) consumed so
    far; ``value`` the best objective inside the reported span; ``best_value``
    the best-so-far across the whole run (inf until a feasible point shows).

    ``shard`` tags multi-worker streams: the ``fanout`` optimizer merges its
    shards' live traces into one callback and stamps each chunk with the
    shard index it came from (``best_value`` is then the *ensemble*
    best-so-far).  Single-worker optimizers leave it None; ``step`` stays
    monotone per shard, not across the interleaved merged stream.
    """

    step: int
    value: float
    best_value: float
    shard: Optional[int] = None


ProgressFn = Callable[[Trial], None]


@dataclasses.dataclass
class SearchRequest:
    """Method-agnostic description of one resource-assignment search.

    workload: a paper workload name (str), a list of LayerSpec, or an
        (N, NUM_FIELDS) layer array.
    env:     the environment config (objective/constraint/platform/dataflow).
    eps:     sample budget in whole-model evaluations (paper: 5000).
    seed:    RNG seed threaded to whichever method runs.
    method:  registry name used by :func:`repro.api.run_search` dispatch.
    options: method-specific knobs (e.g. ``{"episodes_per_epoch": 4}`` for
        the RL family, ``{"population": 100}`` for GA, ``{"temperature": 10}``
        for SA).  Adapters ignore options they do not understand, so one
        options dict can be shared across a method sweep.
    on_progress / progress_every: optional streaming hook; optimizers emit a
        :class:`Trial` roughly every ``progress_every`` samples.  Chunked
        engines (reinforce, two_stage, a2c, ppo2) stream live; single-shot
        engines emit the trace when their underlying run returns.  ``fanout``
        merges all of its shards into this one hook, tagging each Trial with
        its shard index.
    """

    workload: Any
    env: env_lib.EnvConfig = dataclasses.field(
        default_factory=env_lib.EnvConfig)
    eps: int = 5000
    seed: int = 0
    method: str = "two_stage"
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)
    on_progress: Optional[ProgressFn] = None
    progress_every: int = 100

    def __post_init__(self):
        if self.eps < 1:
            raise ValueError(f"eps must be >= 1, got {self.eps}")

    def resolve_workload(self):
        if isinstance(self.workload, str):
            return workloads_lib.get_workload(self.workload)
        return self.workload

    @property
    def num_layers(self) -> int:
        wl = self.resolve_workload()
        if isinstance(wl, (list, tuple)):
            return len(wl)
        return int(np.asarray(wl).shape[0])


@dataclasses.dataclass
class SearchOutcome:
    """Unified search result: every registered optimizer returns this.

    history is the best-so-far objective per sample: length == eps, monotone
    non-increasing, +inf while nothing feasible has been seen (the paper's
    "NAN").  pe/kt/df are the per-layer raw assignment of the best solution
    (NaN-filled when the method never found a feasible point).

    frontier is optional (multi-objective engines only, today ``nsga2``):
    the final Pareto-frontier of feasible designs as a dict of arrays
    sorted by latency -- ``lat``/``en``/``area``/``pw`` of shape (F,) plus
    the realizing per-layer ``pe``/``kt``/``df`` of shape (F, N); every
    point is mutually non-dominating on (lat, en) and satisfies the
    platform budget.  Chunk-by-chunk frontier snapshots ride in
    ``extras["frontier_trace"]`` (list of (F_i, 4) cost arrays).

    telemetry is the search's flight-recorder summary (hard evals, cache
    hit rate, queue-wait/dispatch timings, JIT compiles...) -- populated by
    :func:`repro.api.run_search` when ``repro.obs`` telemetry is enabled,
    None otherwise.  Purely observational: the same search with telemetry
    on and off returns byte-identical results everywhere else.
    """

    method: str
    best_value: float
    pe: np.ndarray
    kt: np.ndarray
    df: np.ndarray
    history: np.ndarray
    eps: int
    seed: int
    samples_to_convergence: int
    wall_seconds: float
    feasible: bool
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)
    frontier: Optional[Dict[str, np.ndarray]] = None
    telemetry: Optional[Dict[str, Any]] = None

    def summary(self) -> str:
        """One human-readable report of the run -- the launcher prints this
        at end-of-run; handy in notebooks too."""
        lines = [
            f"method={self.method}  seed={self.seed}  eps={self.eps}",
            (f"best_value={self.best_value:.6g}  "
             f"feasible={self.feasible}  "
             f"converged@{self.samples_to_convergence}  "
             f"wall={self.wall_seconds:.2f}s"),
        ]
        if self.feasible:
            lines.append(
                f"assignment: pe={np.asarray(self.pe).tolist()} "
                f"kt={np.asarray(self.kt).tolist()} "
                f"df={np.asarray(self.df).tolist()}")
        if self.frontier is not None:
            lines.append(f"frontier: {len(self.frontier['lat'])} "
                         "non-dominated feasible designs")
        t = self.telemetry
        if t:
            bits = []
            if "hard_evals" in t:
                bits.append(f"hard_evals={int(t['hard_evals'])}")
            if "chunks" in t:
                bits.append(f"chunks={int(t['chunks'])}")
            if "cache_hit_rate" in t:
                bits.append(f"cache_hit_rate={t['cache_hit_rate']:.2%}")
            if "jit_compiles" in t:
                bits.append(f"jit_compiles={int(t['jit_compiles'])}")
            for key, label in (("queue_wait_s", "queue_wait"),
                               ("dispatch_s", "dispatch"),
                               ("device_s", "device")):
                s = t.get(key)
                if isinstance(s, dict):
                    bits.append(f"{label}={s['sum']:.3f}s")
            if bits:
                lines.append("telemetry: " + "  ".join(bits))
        return "\n".join(lines)


def samples_to_convergence(trace: np.ndarray, tol: float = 0.05) -> int:
    """First sample index (1-based) within ``tol`` of the final best value.

    Infeasible-forever traces converge only at the full budget -- reported
    speedups against them are lower bounds (Table V footnote).
    """
    trace = np.asarray(trace, dtype=float)
    finite = np.isfinite(trace)
    if not finite.any():
        return len(trace)
    final = trace[finite][-1]
    ok = finite & (trace <= final * (1 + tol))
    return int(np.argmax(ok)) + 1 if ok.any() else len(trace)


def expand_trace(per_span_best, span: int) -> np.ndarray:
    """Expand a per-generation/per-epoch best-so-far trace to per-sample.

    A span's best is only known after all of its samples are evaluated, so
    it is credited to the span's *last* sample; earlier samples inherit the
    previous span's best (inf for the first span).  Plain ``np.repeat``
    would credit up to span-1 samples ahead of being drawn -- the same
    look-ahead bug fixed in the random/grid/bo engines.
    """
    per_span_best = np.asarray(per_span_best, dtype=float).ravel()
    if span <= 1:
        return per_span_best
    t = np.full(len(per_span_best) * span, np.inf)
    t[span - 1::span] = per_span_best
    return np.minimum.accumulate(t)


def fit_trace(trace, eps: int) -> np.ndarray:
    """Normalize a raw trace to the outcome schema: (eps,) monotone best-so-
    far, padded with its last value / truncated as needed."""
    tr = np.asarray(trace, dtype=float).ravel()
    if tr.size == 0:
        tr = np.array([np.inf])
    tr = np.minimum.accumulate(tr)
    if len(tr) >= eps:
        return tr[:eps]
    return np.concatenate([tr, np.full(eps - len(tr), tr[-1])])


def build_outcome(request: SearchRequest, method: str, best_value, pe, kt,
                  df, trace, t0: float, extras=None,
                  streamed: bool = False,
                  frontier=None) -> SearchOutcome:
    """Normalize a finished run into the unified schema.

    ``pe``/``kt`` may be None (nothing feasible found -> NaN-filled arrays);
    ``df`` may be None (fixed-dataflow method -> the env's dataflow id).
    ``t0`` is the run's start time (``time.time()``).  ``frontier`` is the
    optional Pareto-frontier dict of a multi-objective engine.
    """
    best_value = float(best_value)
    N = request.num_layers
    if pe is None or kt is None:
        pe = np.full((N,), np.nan)
        kt = np.full((N,), np.nan)
    if df is None:
        df = np.full((N,), request.env.dataflow, np.int32)
    history = fit_trace(trace, request.eps)
    if not streamed:
        emit_trace(request, history)
    return SearchOutcome(
        method=method, best_value=best_value,
        pe=np.asarray(pe), kt=np.asarray(kt),
        df=np.broadcast_to(np.asarray(df), (N,)).copy(),
        history=history, eps=request.eps, seed=request.seed,
        samples_to_convergence=samples_to_convergence(history),
        wall_seconds=time.time() - t0,
        feasible=bool(np.isfinite(best_value)),
        extras=dict(extras or {}), frontier=frontier)


def emit_trace(request: SearchRequest, history: np.ndarray) -> None:
    """Fire the request's progress callback over a finished best-so-far
    trace at ``progress_every`` granularity (used by single-shot backends)."""
    cb = request.on_progress
    if cb is None:
        return
    n = len(history)
    every = max(int(request.progress_every), 1)
    last = 0
    for step in range(every, n + 1, every):
        cb(Trial(step, float(history[step - 1]), float(history[step - 1])))
        last = step
    if last < n:
        cb(Trial(n, float(history[-1]), float(history[-1])))
