"""Built-in optimizer adapters: every search method behind one API.

Each adapter translates ``SearchRequest`` into the legacy engine's config,
runs it, and normalizes the result into ``SearchOutcome`` (trace length ==
eps, monotone best-so-far, per-layer (pe, kt, df) arrays).  The engines in
``repro.core`` are unchanged and remain callable directly -- these are the
canonical entry points the launcher, benchmarks, examples and the
distributed layer all share.
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import types
from repro.api.registry import register
from repro.api.types import SearchOutcome, SearchRequest, Trial
from repro.core import baselines
from repro.core import env as env_lib
from repro.core import ga as ga_lib
from repro.core import nsga2 as nsga2_lib
from repro.core import policy as policy_lib
from repro.core import reinforce
from repro.core import relaxed as relaxed_lib
from repro.core import rl_baselines
from repro.core import search as search_lib


_outcome = types.build_outcome


def _policy_config(ecfg: env_lib.EnvConfig, opts) -> policy_lib.PolicyConfig:
    pol = dict(opts.get("policy", {}))
    return policy_lib.PolicyConfig(
        obs_dim=ecfg.obs_dim, mix=ecfg.mix, levels=ecfg.levels,
        hidden=pol.get("hidden", policy_lib.HIDDEN),
        kind=pol.get("kind", "rnn"),
        use_kernel=pol.get("use_kernel"))


# ---------------------------------------------------------------------------
# Classic baselines (single-shot engines; progress streams post-hoc).
# ---------------------------------------------------------------------------
@register("random")
class RandomOptimizer:
    name = "random"

    def run(self, request: SearchRequest) -> SearchOutcome:
        t0 = time.time()
        opts = request.options
        res = baselines.random_search(
            request.resolve_workload(), request.env, eps=request.eps,
            seed=request.seed, batch=opts.get("batch", 512),
            eval_fn=opts.get("eval_fn"))
        return _outcome(request, self.name, res.best_value, res.best_pe,
                        res.best_kt, None, res.history, t0)


@register("grid")
class GridOptimizer:
    name = "grid"

    def run(self, request: SearchRequest) -> SearchOutcome:
        t0 = time.time()
        opts = request.options
        res = baselines.grid_search(
            request.resolve_workload(), request.env, eps=request.eps,
            stride=opts.get("stride", 1), batch=opts.get("batch", 512),
            eval_fn=opts.get("eval_fn"))
        return _outcome(request, self.name, res.best_value, res.best_pe,
                        res.best_kt, None, res.history, t0)


@register("sa")
class SimulatedAnnealingOptimizer:
    """Chunked annealing: streams live, resumes, and accepts an injected
    ``eval_fn`` so the search service batches its candidate evaluations."""

    name = "sa"

    def run(self, request: SearchRequest) -> SearchOutcome:
        t0 = time.time()
        opts = request.options
        cfg = baselines.SAConfig(
            temperature=opts.get("temperature", 10.0),
            step=opts.get("step", 1),
            decay=opts.get("decay", 0.999),
            seed=request.seed)
        wl = request.resolve_workload()
        env = env_lib.make_env(wl, request.env)
        if request.on_progress is None:
            chunk, on_chunk = None, None
        else:
            def on_chunk(state, hist, steps_done):
                request.on_progress(Trial(
                    min(steps_done, request.eps),
                    float(np.min(hist)), float(state.best_fit)))

            chunk = max(request.progress_every, 1)
        state, hist = baselines.run_sa_search(
            wl, request.env, eps=request.eps, cfg=cfg, chunk=chunk,
            on_chunk=on_chunk, eval_fn=opts.get("eval_fn"), env=env)
        pe, kt = baselines.sa_solution(env, state)
        return _outcome(request, self.name, float(state.best_fit), pe, kt,
                        None, hist, t0,
                        extras={"steps": int(state.step)},
                        streamed=request.on_progress is not None)


@register("bo", aliases=("bayes",))
class BayesOptOptimizer:
    name = "bo"

    def run(self, request: SearchRequest) -> SearchOutcome:
        t0 = time.time()
        opts = request.options
        res = baselines.bayes_opt(
            request.resolve_workload(), request.env, eps=request.eps,
            seed=request.seed,
            n_candidates=opts.get("n_candidates", 64),
            gamma=opts.get("gamma", 0.15),
            init_random=opts.get("init_random", 64),
            batch=opts.get("batch", 16),
            eval_fn=opts.get("eval_fn"))
        return _outcome(request, self.name, res.best_value, res.best_pe,
                        res.best_kt, None, res.history, t0)


def _ga_cfg(request: SearchRequest) -> ga_lib.GAConfig:
    """One GAConfig derivation for the serial adapter AND the fanout device
    backend -- a default drifting between them would silently break the
    bit-identical-backends guarantee."""
    opts = request.options
    pop = int(opts.get("population", 100))
    gens = int(opts.get("generations", 0)) or max(request.eps // pop, 1)
    return ga_lib.GAConfig(
        population=pop, generations=gens,
        mutation_rate=opts.get("mutation_rate", 0.05),
        crossover_rate=opts.get("crossover_rate", 0.05),
        seed=request.seed, use_kernel=opts.get("use_kernel"))


@register("ga")
class GeneticAlgorithmOptimizer:
    """Baseline GA; ``eps`` buys population * generations individuals.

    Chunked like the RL family: the generation scan runs in
    ``progress_every``-sized chunks when a callback is set (live streaming +
    cancellation between chunks), and an injected ``eval_fn`` routes the
    per-generation fitness batches through the search service's
    cross-request batcher -- byte-identical outcomes either way.
    """

    name = "ga"

    def run(self, request: SearchRequest) -> SearchOutcome:
        t0 = time.time()
        cfg = _ga_cfg(request)
        wl = request.resolve_workload()
        env = env_lib.make_env(wl, request.env)
        if request.on_progress is None:
            chunk, on_chunk = None, None
        else:
            def on_chunk(state, hist, gens_done):
                request.on_progress(Trial(
                    min(gens_done * cfg.population, request.eps),
                    float(np.min(hist)), float(state.best_val)))

            chunk = max(request.progress_every // cfg.population, 1)
        state, hist = ga_lib.run_ga_search(
            wl, request.env, cfg, chunk=chunk, on_chunk=on_chunk,
            eval_fn=request.options.get("eval_fn"), env=env)
        pe, kt, df = ga_lib.ga_solution(env, request.env, state)
        trace = types.expand_trace(hist, cfg.population)
        return _outcome(request, self.name, float(state.best_val),
                        np.asarray(pe), np.asarray(kt), np.asarray(df),
                        trace, t0,
                        extras={"generations": cfg.generations,
                                "population": cfg.population},
                        streamed=request.on_progress is not None)


def _nsga2_cfg(request: SearchRequest) -> nsga2_lib.NSGA2Config:
    opts = request.options
    pop = int(opts.get("population", 64))
    gens = int(opts.get("generations", 0)) or max(request.eps // pop, 1)
    return nsga2_lib.NSGA2Config(
        population=pop, generations=gens,
        mutation_rate=opts.get("mutation_rate", 0.05),
        crossover_rate=opts.get("crossover_rate", 0.5),
        archive=int(opts.get("archive", 128)),
        seed=request.seed, use_kernel=opts.get("use_kernel"))


@register("nsga2", aliases=("pareto", "moo"))
class NSGA2Optimizer:
    """Constrained multi-objective NSGA-II over (latency, energy).

    Chunked like GA: ``eps`` buys population * generations evaluations, the
    generation scan runs in ``progress_every``-sized chunks when a callback
    is set, and an injected ``eval_fn(pe, kt, df) -> (P, 4) costs`` routes
    whole populations through the search service's cross-request batcher --
    byte-identical outcomes either way.

    ``best_value``/``history`` follow the unified single-objective contract
    (the env's primary objective, feasible points only); the trade-off
    curve lands in ``SearchOutcome.frontier`` and its per-chunk snapshots
    in ``extras["frontier_trace"]``.
    """

    name = "nsga2"

    def run(self, request: SearchRequest) -> SearchOutcome:
        t0 = time.time()
        cfg = _nsga2_cfg(request)
        wl = request.resolve_workload()
        env = env_lib.make_env(wl, request.env)
        trace_snapshots = []
        user_cb = request.on_progress

        def on_chunk(state, hist, gens_done):
            trace_snapshots.append(nsga2_lib.frontier_points(state))
            if user_cb is not None:
                user_cb(Trial(
                    min(gens_done * cfg.population, request.eps),
                    float(np.min(hist)), float(state.best_val)))

        chunk = (max(request.progress_every // cfg.population, 1)
                 if user_cb is not None else None)
        eval_fn = request.options.get("eval_fn")
        if eval_fn is None:
            # Serial runs evaluate through the same flat per-point +
            # standalone-aggregation programs the service's batcher
            # dispatches: byte-identical outcomes by construction (the
            # in-graph scan fitness fuses the f32 reductions differently
            # and drifts an ulp on some workloads).
            from repro.serving import batcher as batcher_lib

            eval_fn = batcher_lib.make_local_costs_eval(
                env, request.env, use_kernel=cfg.use_kernel)
        state, hist = nsga2_lib.run_nsga2_search(
            wl, request.env, cfg, chunk=chunk, on_chunk=on_chunk,
            eval_fn=eval_fn, env=env)
        pe, kt, df = nsga2_lib.nsga2_solution(env, request.env, state)
        trace = types.expand_trace(hist, cfg.population)
        frontier = nsga2_lib.nsga2_frontier(env, request.env, state)
        return _outcome(request, self.name, float(state.best_val),
                        np.asarray(pe), np.asarray(kt), np.asarray(df),
                        trace, t0,
                        extras={"generations": cfg.generations,
                                "population": cfg.population,
                                "archive": cfg.archive,
                                "frontier_size": len(frontier["lat"]),
                                "frontier_trace": trace_snapshots},
                        streamed=user_cb is not None,
                        frontier=frontier)


@register("relaxed", aliases=("oneshot", "gradient"))
class RelaxedOptimizer:
    """One-shot gradient descent through the differentiable soft cost model.

    Chunked like SA: descent rounds stream live through ``on_chunk`` (the
    search service's cancellation point), the state resumes, and an injected
    ``eval_fn`` routes the per-round hard probes through the cross-request
    batcher -- byte-identical outcomes either way.  ``eps`` counts hard
    evaluations; the gradient steps in between ride on the soft model and
    are free of hard-model cost.
    """

    name = "relaxed"

    def run(self, request: SearchRequest) -> SearchOutcome:
        t0 = time.time()
        opts = request.options
        cfg = relaxed_lib.RelaxedConfig(
            lr=opts.get("lr", 0.05),
            steps_per_eval=opts.get("steps_per_eval", 25),
            restarts=opts.get("restarts", 4),
            tau_start=opts.get("tau_start", 1.0),
            tau_min=opts.get("tau_min", 0.05),
            tau_decay=opts.get("tau_decay", 0.92),
            penalty=opts.get("penalty", 10.0),
            topk=opts.get("topk", 4),
            seed=request.seed)
        wl = request.resolve_workload()
        env = env_lib.make_env(wl, request.env)
        if request.on_progress is None:
            chunk, on_chunk = None, None
        else:
            def on_chunk(state, hist, evals_done):
                request.on_progress(Trial(
                    min(evals_done, request.eps),
                    float(np.min(hist)), float(state.best_fit)))

            chunk = max(request.progress_every, 1)
        state, hist = relaxed_lib.run_relaxed_search(
            wl, request.env, eps=request.eps, cfg=cfg, chunk=chunk,
            on_chunk=on_chunk, eval_fn=opts.get("eval_fn"), env=env)
        pe, kt, df = relaxed_lib.relaxed_solution(state)
        feasible = bool(np.isfinite(float(state.best_fit)))
        return _outcome(request, self.name, float(state.best_fit),
                        pe if feasible else None, kt if feasible else None,
                        df if feasible else None, hist, t0,
                        extras={"gradient_steps": int(state.gstep),
                                "hard_evals": int(state.evals),
                                "final_tau": float(state.tau)},
                        streamed=request.on_progress is not None)


# ---------------------------------------------------------------------------
# RL family (chunked engines; all four stream live through on_chunk).
# ---------------------------------------------------------------------------
def _reinforce_cfg(request: SearchRequest):
    opts = request.options
    E = int(opts.get("episodes_per_epoch", 1))
    epochs = max(request.eps // E, 1)
    rcfg = reinforce.ReinforceConfig(
        epochs=epochs, episodes_per_epoch=E,
        lr=opts.get("lr", 3e-3),
        discount=opts.get("discount", 0.9),
        entropy_coef=opts.get("entropy_coef", 0.0),
        seed=request.seed)
    return rcfg, E


def _chunk_args(request: SearchRequest, E: int):
    """(chunk, on_chunk) for the stage-1 engine: stream live when asked.

    The engine reuses its compiled epoch function across chunks, so a small
    streaming chunk costs no extra XLA compilation.
    """
    if request.on_progress is None:
        return 500, None

    def on_chunk(state, hist, epochs_done):
        request.on_progress(Trial(
            min(epochs_done * E, request.eps),
            float(np.min(hist["best_value"])), float(state.best_value)))

    return max(request.progress_every // E, 1), on_chunk


@register("reinforce", aliases=("rl", "conx_global"))
class ReinforceOptimizer:
    """Stage-1 ConfuciuX: REINFORCE global search (no GA fine-tune)."""

    name = "reinforce"

    def run(self, request: SearchRequest) -> SearchOutcome:
        t0 = time.time()
        wl = request.resolve_workload()
        rcfg, E = _reinforce_cfg(request)
        pcfg = _policy_config(request.env, request.options)
        chunk, on_chunk = _chunk_args(request, E)
        state, hist = reinforce.run_search(wl, request.env, rcfg, pcfg,
                                           chunk=chunk, on_chunk=on_chunk)
        env = env_lib.make_env(wl, request.env)
        pe, kt, df = reinforce.solution_arrays(state, env)
        trace = types.expand_trace(hist["best_value"], E)
        return _outcome(
            request, self.name, state.best_value, np.asarray(pe),
            np.asarray(kt), np.asarray(df), trace, t0,
            extras={"epochs": rcfg.epochs, "history": hist},
            streamed=request.on_progress is not None)


@register("two_stage", aliases=("conx", "confuciux"))
class TwoStageOptimizer:
    """The full ConfuciuX pipeline: RL global search -> local-GA fine-tune."""

    name = "two_stage"

    def run(self, request: SearchRequest) -> SearchOutcome:
        t0 = time.time()
        wl = request.resolve_workload()
        opts = request.options
        rcfg, E = _reinforce_cfg(request)
        ga = dict(opts.get("ga", {}))
        gcfg = ga_lib.LocalGAConfig(
            population=ga.get("population", 20),
            generations=ga.get("generations", 2000),
            mutation_rate=ga.get("mutation_rate", 0.05),
            crossover_rate=ga.get("crossover_rate", 0.2),
            mutation_step=ga.get("mutation_step", 4),
            seed=request.seed)
        pcfg = _policy_config(request.env, opts)
        chunk, on_chunk = _chunk_args(request, E)
        if request.on_progress is None:
            ga_chunk, ga_on_chunk = None, None
        else:
            # Stage-2 evaluations run past the eps budget, so its Trials
            # stay pinned at step == eps; streaming them keeps the pipeline
            # preemptible (and the ticket's trace honest) during the
            # fine-tune instead of going dark after stage 1.
            def ga_on_chunk(state, hist, gens_done):
                request.on_progress(Trial(
                    request.eps, float(np.min(hist)),
                    min(float(state.best_val), seen_best[0])))

            seen_best = [float("inf")]
            user_on_chunk = on_chunk

            def on_chunk(state, hist, epochs_done):  # noqa: F811
                seen_best[0] = min(seen_best[0], float(state.best_value))
                user_on_chunk(state, hist, epochs_done)

            ga_chunk = max(request.progress_every // gcfg.population, 1)
        res = search_lib.confuciux_search(
            wl, request.env, rcfg, gcfg, pcfg,
            fine_tune=opts.get("fine_tune", True),
            chunk=chunk, on_chunk=on_chunk,
            ga_chunk=ga_chunk, ga_on_chunk=ga_on_chunk)
        # Stage-2 GA evaluations happen after the eps budget; its gain is
        # reflected at the trace's final sample so history[-1] equals the
        # post-fine-tune best (full stage-2 curve: extras["ga_history"]).
        trace = types.expand_trace(res.history["best_value"], E)
        if len(trace):
            trace[-1] = min(trace[-1], float(res.best_value))
        return _outcome(
            request, self.name, res.best_value, res.pe, res.kt, res.df,
            trace, t0,
            extras={"stage1_value": float(res.stage1_value),
                    "initial_valid_value": float(res.initial_valid_value),
                    "ga_history": np.asarray(res.ga_history),
                    "history": res.history, "epochs": rcfg.epochs},
            streamed=request.on_progress is not None)


class _ActorCriticOptimizer:
    algo = "a2c"
    name = "a2c"

    def run(self, request: SearchRequest) -> SearchOutcome:
        t0 = time.time()
        wl = request.resolve_workload()
        opts = request.options
        E = int(opts.get("episodes_per_epoch", 1))
        epochs = max(request.eps // E, 1)
        acfg = rl_baselines.ACConfig(
            algo=self.algo, epochs=epochs, episodes_per_epoch=E,
            lr=opts.get("lr", 1e-3),
            discount=opts.get("discount", 0.9),
            gae_lambda=opts.get("gae_lambda", 0.95),
            clip_eps=opts.get("clip_eps", 0.2),
            ppo_updates=opts.get("ppo_updates", 4),
            value_coef=opts.get("value_coef", 0.5),
            entropy_coef=opts.get("entropy_coef", 0.01),
            seed=request.seed)
        pcfg = _policy_config(request.env, opts)
        chunk, on_chunk = _chunk_args(request, E)
        state, hist = rl_baselines.run_ac_search(wl, request.env, acfg, pcfg,
                                                 chunk=chunk,
                                                 on_chunk=on_chunk)
        env = env_lib.make_env(wl, request.env)
        pe, kt, df = reinforce.solution_arrays(state, env)
        trace = types.expand_trace(hist["best_value"], E)
        return _outcome(
            request, self.name, state.best_value, np.asarray(pe),
            np.asarray(kt), np.asarray(df), trace, t0,
            extras={"epochs": epochs, "history": hist},
            streamed=request.on_progress is not None)


@register("a2c")
class A2COptimizer(_ActorCriticOptimizer):
    algo = "a2c"
    name = "a2c"


@register("ppo2", aliases=("ppo",))
class PPO2Optimizer(_ActorCriticOptimizer):
    algo = "ppo2"
    name = "ppo2"
