"""String-keyed optimizer registry: ``get_optimizer("reinforce"|"ga"|...)``.

Adding a new search method is one file: implement the :class:`Optimizer`
protocol and decorate the class with ``@register("name")``.  Built-in
adapters live in :mod:`repro.api.optimizers`; the distributed wrappers
register themselves from :mod:`repro.distributed.dist_search`.  Both are
imported lazily on first lookup so ``repro.api`` stays cheap to import.

Every name returns the same :class:`SearchOutcome` schema; multi-objective
engines (``nsga2``) additionally fill ``SearchOutcome.frontier``.  The
conformance suite (tests/test_optimizer_conformance.py) runs the whole
registry against the contract -- including the registry-wide guarantee
that a reported best is feasible under the platform budget.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Protocol, Tuple, runtime_checkable

from repro.api.types import SearchOutcome, SearchRequest
from repro.obs import recorder as obs_recorder
from repro.obs import state as obs_state
from repro.obs import trace as obs_trace

# Modules that register optimizers as an import side effect.
_PLUGIN_MODULES = (
    "repro.api.optimizers",
    "repro.distributed.dist_search",
)

_FACTORIES: Dict[str, Callable[[], "Optimizer"]] = {}
_ALIASES: Dict[str, str] = {}
_loaded = False


@runtime_checkable
class Optimizer(Protocol):
    """Anything with a ``name`` and ``run(SearchRequest) -> SearchOutcome``."""

    name: str

    def run(self, request: SearchRequest) -> SearchOutcome:
        ...


def register(name: str, *, aliases: Tuple[str, ...] = ()):
    """Class/factory decorator adding an optimizer under ``name``."""

    def deco(factory: Callable[[], Optimizer]):
        if name in _FACTORIES:
            raise ValueError(f"optimizer {name!r} already registered")
        _FACTORIES[name] = factory
        for alias in aliases:
            _ALIASES[alias] = name
        return factory

    return deco


def _load_plugins() -> None:
    global _loaded
    if _loaded:
        return
    # Mark loaded only after every plugin imports, so a failing plugin
    # raises on each lookup instead of leaving a silently half-filled
    # registry (modules that did import are cached; re-import is a no-op).
    for mod in _PLUGIN_MODULES:
        importlib.import_module(mod)
    _loaded = True


def get_optimizer(name: str) -> Optimizer:
    """Resolve a registered optimizer by name (or alias) and instantiate it."""
    _load_plugins()
    key = _ALIASES.get(name, name)
    if key not in _FACTORIES:
        raise KeyError(
            f"unknown optimizer {name!r}; registered: "
            f"{', '.join(sorted(_FACTORIES))}")
    return _FACTORIES[key]()


def list_optimizers() -> Tuple[str, ...]:
    """All registered canonical names (aliases excluded), sorted."""
    _load_plugins()
    return tuple(sorted(_FACTORIES))


def run_search(request: SearchRequest) -> SearchOutcome:
    """One-call entry point: dispatch ``request`` to ``request.method``.

    With :mod:`repro.obs` telemetry enabled, the run executes under a fresh
    :class:`~repro.obs.recorder.FlightRecorder` (installed thread-locally,
    so concurrent service searches each get their own) inside a
    ``search.run`` span, and the recorder's summary lands on
    ``outcome.telemetry``.  Telemetry is observational only -- the outcome
    is byte-identical with it on or off (asserted registry-wide in
    tests/test_optimizer_conformance.py).
    """
    opt = get_optimizer(request.method)
    if not obs_state.enabled:
        return opt.run(request)
    rec = obs_recorder.FlightRecorder(engine=opt.name)
    with obs_recorder.recording(rec), \
            obs_trace.span("search.run", method=opt.name, eps=request.eps,
                           seed=request.seed):
        out = opt.run(request)
    out.telemetry = rec.summary()
    return out
