"""Unified Optimizer API: one registry, one request/outcome schema.

    from repro import api

    out = api.run_search(api.SearchRequest(
        workload="mobilenet_v2",
        env=api.EnvConfig(platform="iot"),
        eps=5000, method="two_stage"))
    print(out.best_value, out.samples_to_convergence)

    for name in api.list_optimizers():
        out = api.get_optimizer(name).run(request)   # same schema for all

Registered methods: reinforce (stage-1 Con'X), two_stage (Con'X + local-GA
fine-tune), ga, sa, bo, random, grid, a2c, ppo2, plus the distributed
wrappers fanout and dist_reinforce.
"""
from repro.api.registry import (Optimizer, get_optimizer, list_optimizers,
                                register, run_search)
from repro.api.types import (SearchOutcome, SearchRequest, Trial,
                             samples_to_convergence)
from repro.core.env import EnvConfig

__all__ = [
    "EnvConfig",
    "Optimizer",
    "SearchOutcome",
    "SearchRequest",
    "Trial",
    "get_optimizer",
    "list_optimizers",
    "register",
    "run_search",
    "samples_to_convergence",
]
