"""Fault-tolerant checkpointing (pure numpy + JSON manifest, no orbax here).

Design (DESIGN.md S6):
  * atomic   -- a checkpoint is written to ``<dir>/tmp.<step>`` and renamed
                to ``<dir>/step_<step>`` only when complete; readers never
                see partial state after a mid-save crash.
  * elastic  -- leaves are stored as host numpy; ``restore`` re-shards onto
                whatever mesh/sharding the *restoring* job uses (scale from
                256 to 512 chips, or down to 1 CPU for debugging).
  * complete -- model params, optimizer moments, RNG keys, data cursor,
                search state (P_min, best-so-far) all round-trip, so resume
                is bit-deterministic (tested in tests/test_checkpoint.py).
  * async    -- ``save(..., blocking=False)`` snapshots to host then writes
                in a background thread, overlapping with the next step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save(directory: str, step: int, tree: Any,
         meta: Optional[dict] = None, *, blocking: bool = True,
         keep: int = 3) -> threading.Thread | None:
    """Write checkpoint ``<directory>/step_<step>`` atomically."""
    os.makedirs(directory, exist_ok=True)
    leaves, paths, _ = _flatten(tree)
    # Snapshot to host *now* (device buffers may be donated by the next step).
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

    def _write():
        tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
        final = os.path.join(directory, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "meta": meta or {}, "leaves": []}
        for i, (leaf, path) in enumerate(zip(host_leaves, paths)):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), leaf)
            manifest["leaves"].append(
                {"path": path, "file": fname,
                 "shape": list(leaf.shape), "dtype": str(leaf.dtype)})
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _cleanup(directory, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _cleanup(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_")
                   and os.path.exists(os.path.join(directory, d, _MANIFEST)))
    if not steps:
        return None
    return int(steps[-1].split("_")[1])


def restore(directory: str, like: Any, step: Optional[int] = None,
            sharding_fn: Optional[Callable[[str, Any], Any]] = None):
    """Restore into the structure of ``like``.

    ``like`` supplies the treedef and (by default) the target shardings: each
    loaded leaf is ``device_put`` with the corresponding ``like`` leaf's
    sharding when it has one -- this is the elastic-rescale path.
    ``sharding_fn(path, host_array)`` overrides per-leaf placement.
    Returns (tree, step, meta).
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    cdir = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(cdir, _MANIFEST)) as f:
        manifest = json.load(f)
    like_leaves, paths, treedef = _flatten(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    for leaf, path in zip(like_leaves, paths):
        entry = by_path.get(path)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = np.load(os.path.join(cdir, entry["file"]))
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {path}: ckpt {arr.shape} vs "
                f"restore target {np.shape(leaf)}")
        if sharding_fn is not None:
            out.append(sharding_fn(path, arr))
        elif hasattr(leaf, "sharding"):
            out.append(jax.device_put(arr.astype(leaf.dtype), leaf.sharding))
        else:
            out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), step, manifest["meta"]
