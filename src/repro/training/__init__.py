"""Training substrate: optimizers, data pipeline, checkpointing, loops."""
