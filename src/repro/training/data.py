"""Data pipeline: deterministic synthetic LM streams + memmap token files.

Both sources are *stateless functions of (step, shard)*: batch contents
depend only on the global step and the data-shard index, never on process
history.  That is the property that makes checkpoint/restart and elastic
rescaling exact -- a resumed (or re-sharded) job regenerates precisely the
batches it would have seen (tested in tests/test_data.py).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    source: str = "synthetic"       # "synthetic" | "memmap"
    path: Optional[str] = None      # token file for memmap
    seed: int = 1234


class SyntheticLM:
    """Markov-ish synthetic tokens: learnable structure, deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # A sparse bigram table gives the model something to learn.
        self._next = rng.integers(0, cfg.vocab_size,
                                  size=(cfg.vocab_size, 4), dtype=np.int32)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + shard)
        toks = np.empty((b, cfg.seq_len + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
        choices = rng.integers(0, 4, size=(b, cfg.seq_len))
        noise = rng.random((b, cfg.seq_len)) < 0.1
        rand = rng.integers(0, cfg.vocab_size, size=(b, cfg.seq_len))
        for t in range(cfg.seq_len):
            nxt = self._next[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MemmapLM:
    """Token-file dataset: windows sampled deterministically per step."""

    def __init__(self, cfg: DataConfig):
        assert cfg.path and os.path.exists(cfg.path), cfg.path
        self.cfg = cfg
        self._tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch(self, step: int, shard: int = 0, n_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b = cfg.global_batch // n_shards
        n = len(self._tokens) - cfg.seq_len - 1
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + shard)
        starts = rng.integers(0, n, size=b)
        rows = np.stack([np.asarray(self._tokens[s:s + cfg.seq_len + 1])
                         for s in starts])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_dataset(cfg: DataConfig):
    if cfg.source == "synthetic":
        return SyntheticLM(cfg)
    if cfg.source == "memmap":
        return MemmapLM(cfg)
    raise ValueError(cfg.source)


def device_batch(host_batch: Dict[str, np.ndarray], sharding=None):
    """Place a host batch on device(s) (sharded when a sharding is given)."""
    if sharding is None:
        return {k: jax.numpy.asarray(v) for k, v in host_batch.items()}
    return {k: jax.device_put(v, sharding) for k, v in host_batch.items()}


def write_token_file(path: str, n_tokens: int, vocab: int, seed: int = 0):
    """Utility: materialize a synthetic token file for the memmap source."""
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, vocab, size=n_tokens, dtype=np.int32)
    arr.tofile(path)
    return path
