"""Pure-JAX optimizers over arbitrary pytrees (no optax in this environment).

Adam / AdamW / SGD with the usual bias correction, plus global-norm clipping
and simple LR schedules.  State is a pytree of the same structure as params,
so it checkpoints and re-shards like any other model state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray   # ()
    mu: Any             # first moment (pytree like params)
    nu: Any             # second moment


@dataclasses.dataclass(frozen=True)
class Adam:
    """Adam/AdamW.  ``lr`` may be a float or a step -> lr schedule fn."""

    lr: Any = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = None

    def init(self, params) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        return OptState(jnp.zeros((), jnp.int32), zeros,
                        jax.tree.map(lambda p: jnp.zeros_like(p), params))

    def update(self, grads, state: OptState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        new_params = jax.tree.map(
            lambda p, m, v: p - lr * ((m / bc1) / (jnp.sqrt(v / bc2)
                                                   + self.eps)
                                      + self.weight_decay * p),
            params, mu, nu)
        return new_params, OptState(step, mu, nu)


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: Any = 1e-2
    momentum: float = 0.0

    def init(self, params) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        return OptState(jnp.zeros((), jnp.int32), zeros, zeros)

    def update(self, grads, state: OptState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        mu = jax.tree.map(lambda m, g: self.momentum * m + g,
                          state.mu, grads)
        new_params = jax.tree.map(lambda p, m: p - lr * m, params, mu)
        return new_params, OptState(step, mu, state.nu)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    floor: float = 0.0) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = floor + (base_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn
