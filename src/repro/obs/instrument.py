"""Cross-cutting instrumentation helpers + the repo's metric catalog.

Every instrumented module pulls its metric handles from here so the full
catalog lives in one place (mirrored in docs/observability.md).  All
handles are created lazily at import of this module -- creation is cheap
and updates are no-ops while telemetry is disabled.

Also home of the JIT-compile tracker: XLA compiles a program once per
(program, shape-bucket) and the first dispatch therefore pays compile +
execute.  ``dispatch_span`` times every dispatch, tags the first sighting
of a key as ``compile=True``, and feeds both the per-search flight
recorder and the process-wide metrics -- giving the compile-vs-execute
split at the Pallas/XLA boundary without touching any JAX internals.
"""
from __future__ import annotations

import threading
import time
from typing import Hashable, Tuple

from repro.obs import metrics as _metrics
from repro.obs import recorder as _recorder
from repro.obs import state as _state
from repro.obs import trace as _trace

# --------------------------------------------------------------------------
# Metric catalog (names, types, labels).  docs/observability.md documents
# every entry; tests/test_obs.py asserts the two stay in sync.
# --------------------------------------------------------------------------
SEARCH_HARD_EVALS = _metrics.counter(
    "repro_search_hard_evals", "Whole-model hard cost evaluations consumed",
    labels=("engine",))
SEARCH_CHUNKS = _metrics.counter(
    "repro_search_chunks", "Engine chunks executed", labels=("engine",))
SEARCH_CHUNK_SECONDS = _metrics.histogram(
    "repro_search_chunk_seconds", "Wall-clock per engine chunk",
    labels=("engine",))
JIT_COMPILES = _metrics.counter(
    "repro_jit_compiles", "First-dispatch (compile) events per XLA program",
    labels=("program",))
DISPATCH_SECONDS = _metrics.histogram(
    "repro_dispatch_seconds", "XLA/Pallas dispatch wall-clock",
    labels=("program",))

BATCHER_DISPATCHES = _metrics.counter(
    "repro_batcher_dispatches", "Fused-dispatch rounds executed")
BATCHER_POINTS = _metrics.counter(
    "repro_batcher_points", "Per-layer points through the batcher",
    labels=("kind",))   # kind: submitted|unique|fresh
BATCHER_QUEUE_DEPTH = _metrics.gauge(
    "repro_batcher_queue_depth", "Eval requests awaiting dispatch")
BATCHER_FUSE_WIDTH = _metrics.histogram(
    "repro_batcher_fuse_width", "Requests fused per dispatch",
    buckets=_metrics.DEFAULT_SIZE_BUCKETS)
BATCHER_QUEUE_WAIT = _metrics.histogram(
    "repro_batcher_queue_wait_seconds",
    "Submit-to-dispatch-start wait per eval request")
BATCHER_DISPATCH_SECONDS = _metrics.histogram(
    "repro_batcher_dispatch_seconds", "Fused dispatch wall-clock")

CACHE_LOOKUPS = _metrics.counter(
    "repro_cache_lookups", "Cost-memo lookups", labels=("result",))
CACHE_EVICTIONS = _metrics.counter(
    "repro_cache_evictions", "Cost-memo LRU evictions")
CACHE_LOOKUP_SECONDS = _metrics.histogram(
    "repro_cache_lookup_seconds", "Batched cache lookup latency")

SERVICE_ACTIVE = _metrics.gauge(
    "repro_service_active_searches", "Searches currently executing")
SERVICE_REQUESTS = _metrics.counter(
    "repro_service_requests", "Search tickets finished",
    labels=("status",))   # status: completed|cancelled|failed

HTTP_REQUESTS = _metrics.counter(
    "repro_http_requests", "HTTP front-door requests served",
    labels=("route", "code"))   # route is the template, not the raw path
HTTP_REQUEST_SECONDS = _metrics.histogram(
    "repro_http_request_seconds", "HTTP request handling wall-clock",
    labels=("route",))
HTTP_QUEUE_DEPTH = _metrics.gauge(
    "repro_http_queue_depth", "Front-door jobs awaiting a worker slot")

METRIC_NAMES = tuple(sorted(
    m.name for m in _metrics.REGISTRY.metrics()))

# Span taxonomy (documented in docs/observability.md).
SPAN_NAMES = (
    "service.search",     # one ticket end-to-end (uid, method, status)
    "search.run",         # one api.run_search call (method, eps, seed)
    "search.chunk",       # one engine chunk (engine, start, steps, evals)
    "batcher.dispatch",   # one fused dispatch (items, points, unique, fresh)
    "xla.dispatch",       # one device program dispatch (program, compile)
)


# --------------------------------------------------------------------------
# JIT-compile tracking.
# --------------------------------------------------------------------------
_seen_lock = threading.Lock()
_seen_programs: set = set()


def first_dispatch(program: str, key: Hashable) -> bool:
    """True exactly once per (program, key) -- the compile-paying dispatch."""
    with _seen_lock:
        if (program, key) in _seen_programs:
            return False
        _seen_programs.add((program, key))
        return True


def reset_seen_programs() -> None:
    with _seen_lock:
        _seen_programs.clear()


class dispatch_span:
    """Time one device dispatch; tag and count its compile event.

    ``with dispatch_span("cost_eval", key=(kernel, Mp)):`` records an
    ``xla.dispatch`` span, a ``repro_dispatch_seconds`` observation and --
    on the first sighting of (program, key) -- a ``repro_jit_compiles``
    count plus ``jit_compiles`` in the current flight recorder.  Disabled
    telemetry reduces this to two perf_counter reads skipped entirely.
    """

    __slots__ = ("program", "key", "_span", "_t0", "_compile")

    def __init__(self, program: str, key: Hashable = ()):
        self.program = program
        self.key = key

    def __enter__(self):
        if not _state.enabled:
            self._t0 = None
            return self
        self._compile = first_dispatch(self.program, self.key)
        self._span = _trace.span("xla.dispatch", program=self.program,
                                 compile=self._compile).__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        if self._t0 is None:
            return False
        dt = time.perf_counter() - self._t0
        self._span.__exit__(*exc)
        DISPATCH_SECONDS.observe(dt, program=self.program)
        if self._compile:
            JIT_COMPILES.inc(program=self.program)
            _recorder.record("jit_compiles")
        _recorder.observe(f"{self.program}_dispatch_s", dt)
        return False


def chunk_metrics(engine: str, steps: int, evals: int,
                  seconds: float) -> None:
    """One chunk finished: registry counters + flight-recorder entries."""
    SEARCH_CHUNKS.inc(engine=engine)
    SEARCH_HARD_EVALS.inc(evals, engine=engine)
    SEARCH_CHUNK_SECONDS.observe(seconds, engine=engine)
    _recorder.record("chunks")
    _recorder.record("hard_evals", evals)
    _recorder.observe("chunk_s", seconds)


def hard_evals(engine: str, n: int) -> None:
    """Count ``n`` hard evaluations outside the chunk loop (the host-batch
    baselines -- random/grid/bo -- burn their budget in plain batched loops).
    Self-gated: free while telemetry is off."""
    if not _state.enabled:
        return
    SEARCH_HARD_EVALS.inc(n, engine=engine)
    _recorder.record("hard_evals", n)
