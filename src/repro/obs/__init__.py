"""``repro.obs``: zero-dependency observability for the serving + engine stack.

Three pieces, one switch:

  * **metrics** -- a process-wide registry of counters / gauges / fixed-
    bucket histograms with Prometheus text exposition and a JSON snapshot
    (:mod:`repro.obs.metrics`);
  * **tracing** -- nested spans with monotonic timestamps over a ring
    buffer, an optional JSONL sink, and a Chrome-trace/Perfetto export
    (:mod:`repro.obs.trace`);
  * **flight recorder** -- a per-search accumulator whose summary lands in
    ``SearchOutcome.telemetry`` (:mod:`repro.obs.recorder`).

Everything is off by default and observational by contract: enabling
telemetry never changes a search result (byte-identity is asserted across
the whole optimizer registry in tests/test_optimizer_conformance.py), and
the disabled path costs one bool check per call site
(benchmarks/bench_obs_overhead.py keeps it under 2% on the 8-way service
mix).

Typical use::

    from repro import api, obs

    obs.enable(trace=True)
    out = api.run_search(api.SearchRequest(workload="ncf", method="ga"))
    print(out.telemetry["hard_evals"], out.telemetry["cache_hit_rate"])
    obs.save_trace("trace.jsonl")          # or .json -> Chrome/Perfetto
    print(obs.REGISTRY.prometheus_text())
    obs.disable()
"""
from __future__ import annotations

from typing import Optional

from repro.obs import state as _state
from repro.obs.metrics import (REGISTRY, Counter, Gauge, Histogram,
                               MetricsRegistry, counter, gauge, histogram,
                               write_prometheus)
from repro.obs.recorder import (FlightRecorder, current_recorder, record,
                                observe, recording)
from repro.obs.trace import NULL_SPAN, Tracer, span
from repro.obs import instrument

__all__ = [
    "REGISTRY", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "counter", "gauge", "histogram", "write_prometheus",
    "FlightRecorder", "current_recorder", "record", "observe", "recording",
    "NULL_SPAN", "Tracer", "span", "instrument",
    "enable", "disable", "enabled", "tracer", "save_trace", "reset",
]


def enable(trace: bool = True, ring: int = 16384,
           jsonl_path: Optional[str] = None) -> None:
    """Turn telemetry on process-wide.

    ``trace=True`` installs a :class:`Tracer` (``ring`` spans of in-memory
    history; ``jsonl_path`` additionally streams every finished span to a
    JSONL file).  Metrics and flight recorders activate either way.
    Idempotent: re-enabling with ``trace=True`` keeps an already-installed
    tracer unless a new ``jsonl_path`` is requested.
    """
    if trace:
        t = _state.tracer
        if t is None or jsonl_path is not None:
            if t is not None:
                t.close()
            _state.tracer = Tracer(ring=ring, jsonl_path=jsonl_path)
    _state.enabled = True


def disable() -> None:
    """Turn telemetry off (the default state); the tracer's buffered spans
    stay readable until :func:`enable` installs a fresh one."""
    _state.enabled = False


def enabled() -> bool:
    return _state.enabled


def tracer() -> Optional[Tracer]:
    return _state.tracer


def save_trace(path: str) -> None:
    """Write the installed tracer's ring buffer: ``.jsonl`` for one span per
    line, any other extension for Chrome-trace JSON (chrome://tracing or
    https://ui.perfetto.dev)."""
    t = _state.tracer
    if t is None:
        raise RuntimeError("no tracer installed; call obs.enable() first")
    t.save(path)


def reset() -> None:
    """Test/bench helper: zero metrics, clear spans and compile tracking."""
    REGISTRY.reset()
    instrument.reset_seen_programs()
    if _state.tracer is not None:
        _state.tracer.clear()
