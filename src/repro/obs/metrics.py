"""Process-wide metrics registry: counters, gauges, histograms.

Zero-dependency Prometheus-style metrics shared by the serving stack and
the search engines.  A metric is created once (``REGISTRY.counter(...)`` is
get-or-create and idempotent) and updated from any thread; every update is
gated on :mod:`repro.obs.state` so a disabled process pays one bool check
per call site.

Two export formats:

  * :meth:`MetricsRegistry.prometheus_text` -- the Prometheus text
    exposition format (``# HELP`` / ``# TYPE`` comments, cumulative
    ``_bucket{le=...}`` histogram samples), scrapable or checkable with
    ``tools/check_telemetry.py``;
  * :meth:`MetricsRegistry.snapshot` -- a JSON-safe nested dict, the form
    benchmarks stamp into ``results/*.json``.

Histograms use *fixed* bucket edges chosen at creation so concurrent
observations never reshape the layout (thread-safe by construction) and
text exposition stays stable across runs.
"""
from __future__ import annotations

import bisect
import threading
from collections import OrderedDict
from typing import Dict, Optional, Sequence, Tuple

from repro.obs import state as _state

# Default edges span the latencies this repo actually sees: microsecond
# cache lookups up to multi-second fused dispatches / search chunks.
DEFAULT_TIME_BUCKETS = (1e-5, 1e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5,
                        1.0, 5.0, 30.0)
# Size-ish quantities: fuse widths, batch sizes, queue depths.
DEFAULT_SIZE_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0,
                        256.0, 1024.0, 4096.0)


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace(
        "\n", r"\n")


def _fmt_labels(names: Sequence[str], values: Sequence[str],
                extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{_escape(v)}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


class _Metric:
    """Shared plumbing: label handling, per-metric lock, registration."""

    kind = "untyped"

    def __init__(self, name: str, help: str,
                 label_names: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._lock = threading.Lock()
        self._values: "OrderedDict[Tuple[str, ...], object]" = OrderedDict()

    def _key(self, labels: Dict[str, str]) -> Tuple[str, ...]:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, "
                f"declared {sorted(self.label_names)}")
        return tuple(str(labels[n]) for n in self.label_names)

    def clear(self) -> None:
        with self._lock:
            self._values.clear()


class Counter(_Metric):
    """Monotonically increasing count (exposed with a ``_total`` name)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if not _state.enabled:
            return
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._values.get(self._key(labels), 0.0))

    def _samples(self):
        with self._lock:
            return [(f"{self.name}_total", self.label_names, k, (), v)
                    for k, v in self._values.items()]

    def _snap(self):
        with self._lock:
            return {",".join(k) or "": v for k, v in self._values.items()}


class Gauge(_Metric):
    """Point-in-time value that can go up and down."""

    kind = "gauge"

    def _samples(self):
        with self._lock:
            return [(self.name, self.label_names, k, (), v)
                    for k, v in self._values.items()]

    def set(self, value: float, **labels) -> None:
        if not _state.enabled:
            return
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        if not _state.enabled:
            return
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._values.get(self._key(labels), 0.0))

    _snap = Counter._snap


class Histogram(_Metric):
    """Fixed-bucket histogram: cumulative ``le`` buckets + sum + count.

    The bucket edges are frozen at creation; an implicit ``+Inf`` bucket
    catches the tail.  Per-label-set storage is ``[counts..., sum, count,
    max]`` -- ``max`` is not part of the Prometheus exposition but rides in
    :meth:`MetricsRegistry.snapshot` because flight-recorder style "worst
    observed" questions come up constantly in search profiling.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str,
                 label_names: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_TIME_BUCKETS):
        super().__init__(name, help, label_names)
        edges = tuple(sorted(float(b) for b in buckets))
        if not edges:
            raise ValueError(f"{name}: need at least one bucket edge")
        self.buckets = edges

    def observe(self, value: float, **labels) -> None:
        if not _state.enabled:
            return
        key = self._key(labels)
        value = float(value)
        i = bisect.bisect_left(self.buckets, value)
        with self._lock:
            row = self._values.get(key)
            if row is None:
                row = self._values[key] = (
                    [0] * (len(self.buckets) + 1) + [0.0, 0, value])
            row[i] += 1
            row[-3] += value
            row[-2] += 1
            row[-1] = max(row[-1], value)

    def stats(self, **labels) -> Dict[str, float]:
        """(sum, count, mean, max) for one label set -- test/report helper."""
        with self._lock:
            row = self._values.get(self._key(labels))
            if row is None:
                return {"sum": 0.0, "count": 0, "mean": 0.0, "max": 0.0}
            return {"sum": row[-3], "count": row[-2],
                    "mean": row[-3] / max(row[-2], 1), "max": row[-1]}

    def _samples(self):
        out = []
        with self._lock:
            for k, row in self._values.items():
                cum = 0
                for edge, n in zip(self.buckets, row[:-3]):
                    cum += n
                    out.append((f"{self.name}_bucket", self.label_names, k,
                                (("le", repr(float(edge))),), cum))
                out.append((f"{self.name}_bucket", self.label_names, k,
                            (("le", "+Inf"),), cum + row[-4]))
                out.append((f"{self.name}_sum", self.label_names, k, (),
                            row[-3]))
                out.append((f"{self.name}_count", self.label_names, k, (),
                            row[-2]))
        return out

    def _snap(self):
        with self._lock:
            return {
                ",".join(k) or "": {
                    "buckets": dict(zip([repr(float(b))
                                         for b in self.buckets] + ["+Inf"],
                                        row[:-3])),
                    "sum": row[-3], "count": row[-2], "max": row[-1],
                }
                for k, row in self._values.items()}


class MetricsRegistry:
    """Name -> metric map with get-or-create semantics and two exporters."""

    def __init__(self):
        self._metrics: "OrderedDict[str, _Metric]" = OrderedDict()
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name, help, labels, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls) or m.label_names != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{m.kind} with labels {m.label_names}")
                return m
            m = cls(name, help, labels, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS
                  ) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   buckets=buckets)

    def metrics(self):
        with self._lock:
            return list(self._metrics.values())

    def reset(self) -> None:
        """Zero every metric's values (definitions stay registered)."""
        for m in self.metrics():
            m.clear()

    def prometheus_text(self) -> str:
        """The Prometheus text exposition format, terminated by a newline."""
        lines = []
        for m in self.metrics():
            lines.append(f"# HELP {m.name} {_escape(m.help)}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for name, lnames, lvals, extra, v in m._samples():
                val = repr(float(v)) if isinstance(v, float) else str(v)
                lines.append(f"{name}{_fmt_labels(lnames, lvals, extra)} "
                             f"{val}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, dict]:
        """JSON-safe ``{name: {kind, labels, values}}`` dump."""
        return {m.name: {"kind": m.kind, "help": m.help,
                         "labels": list(m.label_names), "values": m._snap()}
                for m in self.metrics()}


# The process-wide default registry every instrumented module shares.
REGISTRY = MetricsRegistry()


def counter(name: str, help: str = "",
            labels: Sequence[str] = ()) -> Counter:
    return REGISTRY.counter(name, help, labels)


def gauge(name: str, help: str = "", labels: Sequence[str] = ()) -> Gauge:
    return REGISTRY.gauge(name, help, labels)


def histogram(name: str, help: str = "", labels: Sequence[str] = (),
              buckets: Sequence[float] = DEFAULT_TIME_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, help, labels, buckets)


def write_prometheus(path: str,
                     registry: Optional[MetricsRegistry] = None) -> None:
    """Write the exposition text (or a JSON snapshot for ``.json`` paths)."""
    import json
    import os

    reg = registry if registry is not None else REGISTRY
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        if path.endswith(".json"):
            json.dump(reg.snapshot(), f, indent=1)
        else:
            f.write(reg.prometheus_text())
