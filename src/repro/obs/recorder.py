"""Per-search flight recorder: one search's telemetry, attached to its outcome.

A :class:`FlightRecorder` rides along one search from submission to
``SearchOutcome``: the shared chunk loop counts hard evaluations and chunk
timings into it, the cost-eval batcher attributes queue-wait / dispatch /
device time and cache hits to it (the recorder is captured at submit time,
so a dispatch fused across N searches credits each rider its own share),
and the JIT-compile tracker notes first-compile events.  The final
:meth:`summary` dict lands in ``SearchOutcome.telemetry``.

Attribution across threads: the *search worker* thread installs its
recorder with :func:`recording` (a plain ``threading.local`` -- each
concurrent search in a ``SearchService`` worker pool sees only its own),
and hands it to the batcher inside the submitted item, so the dispatcher
threads write to the right recorder without any global coordination.
Recorders are lock-protected; everything they store is observational.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

from repro.obs import state as _state

_tls = threading.local()


class FlightRecorder:
    """Thread-safe accumulator of one search's counters and timings.

    ``add`` accumulates plain counts (hard evals, points, cache hits);
    ``observe`` accumulates (sum, count, max) timing/size series -- enough
    to report totals, means and worst cases without storing every sample.
    """

    __slots__ = ("engine", "_lock", "_counts", "_series")

    def __init__(self, engine: Optional[str] = None):
        self.engine = engine
        self._lock = threading.Lock()
        self._counts: Dict[str, float] = {}
        self._series: Dict[str, list] = {}   # key -> [sum, count, max]

    def add(self, key: str, n: float = 1.0) -> None:
        with self._lock:
            self._counts[key] = self._counts.get(key, 0.0) + n

    def observe(self, key: str, value: float) -> None:
        value = float(value)
        with self._lock:
            row = self._series.get(key)
            if row is None:
                self._series[key] = [value, 1, value]
            else:
                row[0] += value
                row[1] += 1
                row[2] = max(row[2], value)

    def count(self, key: str) -> float:
        with self._lock:
            return self._counts.get(key, 0.0)

    def summary(self) -> Dict[str, object]:
        """One JSON-safe dict: raw counts, per-series (sum, mean, max), and
        the derived ratios everyone asks for first (cache hit rate, dedup).
        """
        with self._lock:
            counts = dict(self._counts)
            series = {k: list(v) for k, v in self._series.items()}
        out: Dict[str, object] = {"engine": self.engine}
        for k, v in sorted(counts.items()):
            out[k] = int(v) if float(v).is_integer() else v
        for k, (s, n, mx) in sorted(series.items()):
            out[k] = {"sum": round(s, 6), "count": n,
                      "mean": round(s / max(n, 1), 6), "max": round(mx, 6)}
        points = counts.get("points", 0.0)
        if points:
            out["cache_hit_rate"] = round(
                counts.get("cached_points", 0.0) / points, 4)
            out["fresh_frac"] = round(
                counts.get("fresh_points", 0.0) / points, 4)
        return out


def current_recorder() -> Optional[FlightRecorder]:
    """This thread's active recorder, or None outside a recorded search."""
    return getattr(_tls, "recorder", None)


@contextlib.contextmanager
def recording(rec: Optional[FlightRecorder]):
    """Install ``rec`` as this thread's recorder for the duration."""
    prev = getattr(_tls, "recorder", None)
    _tls.recorder = rec
    try:
        yield rec
    finally:
        _tls.recorder = prev


def record(key: str, n: float = 1.0) -> None:
    """Count ``n`` into the current recorder (no-op when none/disabled)."""
    if not _state.enabled:
        return
    rec = getattr(_tls, "recorder", None)
    if rec is not None:
        rec.add(key, n)


def observe(key: str, value: float) -> None:
    """Observe a timing/size into the current recorder (no-op otherwise)."""
    if not _state.enabled:
        return
    rec = getattr(_tls, "recorder", None)
    if rec is not None:
        rec.observe(key, value)
