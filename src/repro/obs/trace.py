"""Structured tracing: nested spans over a ring buffer, JSONL + Chrome export.

A *span* is one named, timed region with attributes -- ``batcher.dispatch``,
``search.chunk``, ``xla.dispatch`` -- recorded with monotonic
``time.perf_counter_ns`` timestamps so durations are immune to wall-clock
jumps.  Spans nest per thread (a thread-local stack tracks depth and parent)
and land in:

  * an in-memory ring buffer (``collections.deque(maxlen=...)`` -- bounded,
    allocation-cheap, safe to leave on for long service runs);
  * optionally a JSONL trace file, one JSON object per finished span,
    appended under a lock (multi-thread safe);
  * on demand, a Chrome-trace JSON export loadable in ``chrome://tracing``
    or https://ui.perfetto.dev (``ph: "X"`` complete events).

Recording is observational only: spans never touch RNG state, search state
or any value the engines compute.  When tracing is disabled, ``span()``
returns one shared null context manager -- no allocation, no clock read.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

from repro.obs import state as _state

# Offset perf_counter timestamps to an epoch-ish origin once per process so
# trace files from one run share a common, comparable timebase.
_T0_NS = time.perf_counter_ns()
_EPOCH_US = time.time() * 1e6


class _NullSpan:
    """Shared do-nothing span: the disabled path and attr sink."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class _Span:
    """One live span; finished records are plain dicts in the ring."""

    __slots__ = ("tracer", "name", "attrs", "t0", "parent", "depth", "tid")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> "_Span":
        """Attach attributes discovered mid-span (e.g. fuse width)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        tls = self.tracer._tls
        stack = getattr(tls, "stack", None)
        if stack is None:
            stack = tls.stack = []
        self.parent = stack[-1].name if stack else None
        self.depth = len(stack)
        self.tid = threading.get_ident()
        stack.append(self)
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self.t0
        stack = self.tracer._tls.stack
        if stack and stack[-1] is self:
            stack.pop()
        self.tracer._record(self, dur)
        return False


class Tracer:
    """Span collector: ring buffer + optional JSONL sink + exporters."""

    def __init__(self, ring: int = 16384,
                 jsonl_path: Optional[str] = None):
        self._ring: "deque[dict]" = deque(maxlen=max(int(ring), 1))
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._jsonl_path = jsonl_path
        self._jsonl_file = None
        self.dropped = 0
        if jsonl_path:
            os.makedirs(os.path.dirname(os.path.abspath(jsonl_path)),
                        exist_ok=True)
            self._jsonl_file = open(jsonl_path, "w")

    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def _record(self, span: _Span, dur_ns: int) -> None:
        rec = {
            "name": span.name,
            "ts_us": round((span.t0 - _T0_NS) / 1e3 + _EPOCH_US, 3),
            "dur_us": round(dur_ns / 1e3, 3),
            "tid": span.tid,
            "depth": span.depth,
        }
        if span.parent is not None:
            rec["parent"] = span.parent
        if span.attrs:
            rec["attrs"] = {k: _jsonable(v) for k, v in span.attrs.items()}
        with self._lock:
            if len(self._ring) == self._ring.maxlen:
                self.dropped += 1
            self._ring.append(rec)
            if self._jsonl_file is not None:
                self._jsonl_file.write(json.dumps(rec) + "\n")
                self._jsonl_file.flush()

    def spans(self) -> List[dict]:
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self.dropped = 0

    # -- exporters ----------------------------------------------------------
    def chrome_trace(self) -> Dict:
        """Chrome trace-event JSON (complete events, microsecond units)."""
        pid = os.getpid()
        events = [{
            "name": rec["name"],
            "ph": "X",
            "ts": rec["ts_us"],
            "dur": rec["dur_us"],
            "pid": pid,
            "tid": rec["tid"],
            "args": rec.get("attrs", {}),
        } for rec in self.spans()]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        """Write the ring buffer: ``.jsonl`` -> one span per line; anything
        else -> Chrome trace JSON (open in chrome://tracing or Perfetto)."""
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            if path.endswith(".jsonl"):
                for rec in self.spans():
                    f.write(json.dumps(rec) + "\n")
            else:
                json.dump(self.chrome_trace(), f)

    def close(self) -> None:
        with self._lock:
            if self._jsonl_file is not None:
                self._jsonl_file.close()
                self._jsonl_file = None


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


def span(name: str, **attrs):
    """The module-level span entry point every call site uses.

    Disabled (no tracer or telemetry off) -> the shared :data:`NULL_SPAN`;
    enabled -> a real span on the installed tracer.  Always usable as
    ``with obs.span("x", k=v) as sp: sp.set(more=...)``.
    """
    tracer = _state.tracer
    if tracer is None or not _state.enabled:
        return NULL_SPAN
    return tracer.span(name, **attrs)


@contextlib.contextmanager
def timed(out: dict, key: str):
    """Tiny helper: time a block into ``out[key]`` (seconds) -- used where a
    duration is needed even without a tracer installed."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        out[key] = time.perf_counter() - t0
