"""Process-wide observability switch shared by every ``repro.obs`` module.

One mutable module holds the single source of truth for "is telemetry on"
so the hot-path check is a module-attribute load plus a bool test --
``if not state.enabled: return`` -- and flipping the switch affects every
instrumented call site at once.  Everything here is observational: enabling
or disabling telemetry can never change search results (asserted by the
byte-identity tests in tests/test_obs.py and the conformance suite).
"""
from __future__ import annotations

from typing import Optional

# The one switch.  False (the default) turns every obs primitive into a
# near-free no-op: metric updates return immediately, ``span`` yields a
# shared null context manager, and no recorder is installed.
enabled: bool = False

# The active Tracer (``repro.obs.trace.Tracer``) or None.  Spans are only
# recorded when BOTH ``enabled`` is True and a tracer is installed.
tracer: Optional[object] = None


def is_enabled() -> bool:
    return enabled
