"""Mamba2 blocks via SSD (state-space duality), arXiv:2405.21060.

The SSD recurrence per head h (scalar decay a_t = exp(dt_t * A_h)):

    S_t = a_t * S_{t-1} + dt_t * (B_t (x) x_t)        S: (P, S) per head
    y_t = C_t . S_t + D_h * x_t

Training/prefill uses the *chunked* algorithm: the sequence is split into
chunks of Q tokens; within a chunk the contribution is a masked
(attention-like) matmul -- MXU-friendly -- and chunk boundary states are
carried by a short ``lax.scan`` (T/Q steps).  This is exactly the paper's
"quadratic within / linear across" duality and is why the mamba archs keep
the long_500k shape (DESIGN.md SArch-applicability).

Decode is the O(1)-per-token recurrence on a persistent (H, P, S) state plus
a (width-1)-deep causal-conv tail.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import NO_SHARDING, cast, normal, rms_norm

CONV_WIDTH = 4


def dims(cfg) -> Tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim P, state S)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    return d_inner, d_inner // P, P, cfg.ssm_state


def init_mamba(key, cfg):
    d = cfg.d_model
    d_inner, H, P, S = dims(cfg)
    G = 1  # mamba2 default: single B/C group shared across heads
    conv_ch = d_inner + 2 * G * S
    proj_out = 2 * d_inner + 2 * G * S + H
    ks = jax.random.split(key, 6)
    return {
        "in_proj": normal(ks[0], (d, proj_out)),
        "conv_w": normal(ks[1], (CONV_WIDTH, conv_ch), scale=0.5),
        "conv_b": jnp.zeros((conv_ch,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D": jnp.ones((H,)),
        "dt_bias": jnp.full((H,), jnp.log(jnp.expm1(0.01))),
        "gate_norm": jnp.ones((d_inner,)),
        "out_proj": normal(ks[2], (d_inner, d)),
    }


def _causal_conv(x, w, b):
    """Depth-wise causal conv.  x: (B, T, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(W))
    return out + b[None, None, :]


def _split_proj(cfg, zxbcdt):
    d_inner, H, P, S = dims(cfg)
    G = 1
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, d_inner + d_inner + 2 * G * S], axis=-1)
    return z, xbc, dt


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """x: (B,T,H,P), dt: (B,T,H), A: (H,), Bm/Cm: (B,T,S).  -> (B,T,H,P)."""
    B_, T, H, P = x.shape
    S = Bm.shape[-1]
    Q = min(chunk, T)
    while T % Q:
        Q -= 1
    nc = T // Q
    xc = x.reshape(B_, nc, Q, H, P)
    dtc = dt.reshape(B_, nc, Q, H)
    Bc = Bm.reshape(B_, nc, Q, S)
    Cc = Cm.reshape(B_, nc, Q, S)

    a = dtc * A[None, None, None, :]                  # (B,nc,Q,H) log decay
    cum = jnp.cumsum(a, axis=2)

    # Intra-chunk (the "quadratic" branch): masked decay-weighted scores.
    CB = jnp.einsum("bnqs,bnks->bnqk", Cc, Bc)        # (B,nc,Q,Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    Wt = (CB[..., None] * decay
          * dtc[:, :, None, :, :])                    # (B,nc,t,s,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Wt = jnp.where(mask[None, None, :, :, None], Wt, 0.0)
    y_intra = jnp.einsum("bnqkh,bnkhp->bnqhp", Wt, xc)

    # Chunk-boundary states (the "linear" branch).
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)   # (B,nc,Q,H)
    Sc = jnp.einsum("bnqh,bnqs,bnqhp->bnhps", decay_to_end * dtc, Bc, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])           # (B,nc,H)

    def scan_fn(Sprev, inp):
        dec, Snew = inp
        return Sprev * dec[:, :, None, None] + Snew, Sprev

    S0 = jnp.zeros((B_, H, P, S), x.dtype)
    _, Sprevs = jax.lax.scan(
        scan_fn, S0,
        (chunk_decay.transpose(1, 0, 2), Sc.transpose(1, 0, 2, 3, 4)))
    Sprev = Sprevs.transpose(1, 0, 2, 3, 4)           # state entering chunk
    y_inter = jnp.einsum("bnqs,bnhps,bnqh->bnqhp", Cc, Sprev, jnp.exp(cum))
    return (y_intra + y_inter).reshape(B_, T, H, P)


def mamba_forward(p, cfg, x, *, pol=NO_SHARDING):
    """Full-sequence Mamba2 block.  x: (B, T, D) -> (B, T, D)."""
    B, T, D = x.shape
    d_inner, H, P, S = dims(cfg)
    zxbcdt = x @ cast(p["in_proj"], cfg.compute_dtype)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = jax.nn.silu(_causal_conv(xbc, cast(p["conv_w"], cfg.compute_dtype),
                                   cast(p["conv_b"], cfg.compute_dtype)))
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + S], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    # The SSD chunk scan is sequential in T: the sequence must be complete
    # per device (heads shard over 'model' instead, when divisible).
    xh = pol.ssm_x(xs.reshape(B, T, H, P))
    y = ssd_chunked(xh.astype(jnp.float32), dt, A,
                    Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                    cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return pol.resid(y @ cast(p["out_proj"], cfg.compute_dtype))


class MambaCache(NamedTuple):
    conv: jnp.ndarray   # (B, CONV_WIDTH-1, conv_ch) trailing conv inputs
    ssm: jnp.ndarray    # (B, H, P, S) recurrent state


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32) -> MambaCache:
    d_inner, H, P, S = dims(cfg)
    conv_ch = d_inner + 2 * S
    return MambaCache(
        conv=jnp.zeros((batch, CONV_WIDTH - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, H, P, S), dtype))


def mamba_step(p, cfg, x, cache: MambaCache, *, pol=NO_SHARDING):
    """One-token Mamba2 step.  x: (B, 1, D) -> (B, 1, D), new cache."""
    B = x.shape[0]
    d_inner, H, P, S = dims(cfg)
    zxbcdt = x[:, 0] @ cast(p["in_proj"], cfg.compute_dtype)  # (B, .)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    # Causal conv over (stored tail + current input).
    hist = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)
    w = cast(p["conv_w"], cfg.compute_dtype)
    conv_out = (hist * w[None]).sum(axis=1) + cast(p["conv_b"],
                                                   cfg.compute_dtype)
    xbc_c = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(xbc_c, [d_inner, d_inner + S], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None, :])                      # (B, H)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    dBx = jnp.einsum("bh,bs,bhp->bhps", dt, Bm.astype(jnp.float32), xh)
    ssm = cache.ssm * a[:, :, None, None] + dBx
    y = jnp.einsum("bs,bhps->bhp", Cm.astype(jnp.float32), ssm)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = (y @ cast(p["out_proj"], cfg.compute_dtype))[:, None, :]
    new_cache = MambaCache(conv=hist[:, 1:, :], ssm=ssm)
    return pol.resid(out), new_cache
