"""GShard-style grouped top-k mixture-of-experts FFN.

Dispatch strategy (see DESIGN.md S6): tokens are reshaped into ``n_groups``
groups of ``g`` tokens (one group per data shard on the production mesh);
routing, capacity and the dispatch/combine einsums are per-group.  This keeps
the dispatch-einsum FLOPs at ``n_groups * g * E * C * D`` with
``C = g*k/E*cf`` -- quadratic in the *group* size, not the global batch --
which is the GShard trade-off and a hillclimb lever in EXPERIMENTS.md SPerf.

Expert weights are stacked (E, D, F) and shard over the ``model`` axis (EP);
the dispatched activations (groups, E, C, D) shard groups->data, E->model,
so GSPMD lowers the group->expert exchange to an all-to-all.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import NO_SHARDING, cast, normal


def init_moe(key, cfg):
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    p = {"router": normal(ks[0], (d, E))}
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = normal(ks[1], (E, d, f))
        p["w_up"] = normal(ks[2], (E, d, f))
        p["w_down"] = normal(ks[3], (E, f, d))
    else:
        p["w_up"] = normal(ks[1], (E, d, f))
        p["w_down"] = normal(ks[2], (E, f, d))
    return p


def capacity(g: int, cfg) -> int:
    c = int(g * cfg.experts_per_token / cfg.num_experts
            * cfg.moe_capacity_factor)
    return max(c, cfg.experts_per_token)


def moe_ffn(p, cfg, x, *, n_groups: Optional[int] = None, pol=NO_SHARDING):
    """x: (B, T, D) -> (B, T, D).  Top-k routing with per-group capacity."""
    B, T, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    N = B * T
    # Default group size 512: the (g, E*C) dispatch one-hot and its einsum
    # scale as N*g*k*cf, so small groups keep dispatch overhead ~5-10% of
    # expert FLOPs (SPerf lever; see module docstring).
    n_groups = n_groups or max(1, N // 512)
    while N % n_groups:
        n_groups -= 1
    g = N // n_groups
    C = capacity(g, cfg)

    xf = x.reshape(n_groups, g, D)
    logits = (xf @ cast(p["router"], cfg.compute_dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)            # (n, g, E)
    top_p, top_e = jax.lax.top_k(probs, k)             # (n, g, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, choice) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)      # (n, g, k, E)
    flat = onehot.reshape(n_groups, g * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                   # (n, g*k, E)
    pos = (pos * flat).sum(-1).reshape(n_groups, g, k)      # (n, g, k)
    keep = pos < C
    weight = jnp.where(keep, top_p, 0.0)

    # dispatch: (n, g, k, E, C) one-hot -> folded to (n, g, E*C).  The E*C
    # dim is constrained onto the EP ('model') axis *before* the einsums so
    # GSPMD lowers group->expert movement as an all-to-all instead of
    # replicate+slice (the "involuntary full remat" path).
    disp = (jax.nn.one_hot(top_e * C + pos, E * C, dtype=x.dtype)
            * weight[..., None].astype(x.dtype)).sum(axis=2)  # (n, g, E*C)
    disp = pol.dispatch(disp)
    xe = jnp.einsum("ngc,ngd->ncd", disp, xf)                 # (n, E*C, D)
    xe = pol.experts_flat(xe)
    xe = pol.experts(xe.reshape(n_groups, E, C, D))

    if cfg.mlp_act == "swiglu":
        h = (jax.nn.silu(jnp.einsum("necd,edf->necf", xe,
                                    cast(p["w_gate"], cfg.compute_dtype)))
             * jnp.einsum("necd,edf->necf", xe,
                          cast(p["w_up"], cfg.compute_dtype)))
    else:
        h = jax.nn.gelu(jnp.einsum("necd,edf->necf", xe,
                                   cast(p["w_up"], cfg.compute_dtype)))
    ye = jnp.einsum("necf,efd->necd", h, cast(p["w_down"],
                                              cfg.compute_dtype))
    ye = pol.experts_flat(pol.experts(ye).reshape(n_groups, E * C, D))
    y = jnp.einsum("ngc,ncd->ngd", disp, ye)                  # combine
    return pol.resid(y.reshape(B, T, D))


def aux_load_balance_loss(p, cfg, x):
    """Switch-style load-balance auxiliary loss (fraction * probability)."""
    logits = (x @ cast(p["router"], cfg.compute_dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    E, k = cfg.num_experts, cfg.experts_per_token
    top_e = jax.lax.top_k(probs, k)[1]
    frac = jax.nn.one_hot(top_e, E).sum(axis=(-3, -2)) / (
        probs.shape[-2] * k)
    mean_p = probs.mean(axis=-2)
    return E * jnp.sum(frac.reshape(-1, E).mean(0)
                       * mean_p.reshape(-1, E).mean(0))
