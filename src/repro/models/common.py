"""Shared model primitives: norms, RoPE, GQA attention, MLP, embeddings.

Everything is a pure function over explicit parameter pytrees; layer stacks
carry a leading ``L`` dim and are driven by ``lax.scan`` (essential to keep
the HLO -- and hence multi-pod compile time -- independent of depth).

Sharding: model code never imports mesh machinery.  A :class:`ShardingPolicy`
carries `with_sharding_constraint` hooks for the residual stream / attention
internals / ffn internals; the default policy is a no-op so the same code
runs on CPU tests and under pjit on the production mesh
(repro/distributed/sharding.py builds the real policies).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Constraint hooks applied inside model code (no-ops by default)."""

    resid: Callable[[Array], Array] = lambda x: x      # (B, T, D)
    heads: Callable[[Array], Array] = lambda x: x      # (B, T, H, hd)
    kv_full: Callable[[Array], Array] = lambda x: x    # (B, S, Kv, hd)
    ffn: Callable[[Array], Array] = lambda x: x        # (B, T, F)
    experts: Callable[[Array], Array] = lambda x: x    # (..., E, C, D/F)
    dispatch: Callable[[Array], Array] = lambda x: x   # (n, g, E*C)
    experts_flat: Callable[[Array], Array] = lambda x: x  # (n, E*C, D/F)
    ssm_x: Callable[[Array], Array] = lambda x: x      # (B, T, H, P)
    logits: Callable[[Array], Array] = lambda x: x     # (B, T, V)
    cache: Callable[[Array], Array] = lambda x: x      # (B, T, Kv, hd)


NO_SHARDING = ShardingPolicy()


def cast(x, dtype: str):
    return x.astype(jnp.dtype(dtype))


def normal(key, shape, scale=0.02, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * scale


def rms_norm(x, gamma, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * gamma.astype(jnp.float32)
            ).astype(dt)


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding.  x: (..., T, H, hd); positions: (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention.
# ---------------------------------------------------------------------------
def init_attention(key, cfg, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    hd, H, Kv = cfg.hd(), cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 5)
    p = {
        "wq": normal(ks[0], (d, H * hd)),
        "wk": normal(ks[1], (d, Kv * hd)),
        "wv": normal(ks[2], (d, Kv * hd)),
        "wo": normal(ks[3], (H * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,))
        p["bk"] = jnp.zeros((Kv * hd,))
        p["bv"] = jnp.zeros((Kv * hd,))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p


def _project_qkv(p, cfg, x, positions, *, use_rope=True, pol=NO_SHARDING):
    B, T, _ = x.shape
    hd, H, Kv = cfg.hd(), cfg.num_heads, cfg.num_kv_heads
    q = x @ cast(p["wq"], cfg.compute_dtype)
    k = x @ cast(p["wk"], cfg.compute_dtype)
    v = x @ cast(p["wv"], cfg.compute_dtype)
    if cfg.qkv_bias:
        q = q + cast(p["bq"], cfg.compute_dtype)
        k = k + cast(p["bk"], cfg.compute_dtype)
        v = v + cast(p["bv"], cfg.compute_dtype)
    q = pol.heads(q.reshape(B, T, H, hd))
    k = k.reshape(B, T, Kv, hd)
    v = v.reshape(B, T, Kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k, scale):
    """q: (B,T,H,hd), k: (B,S,Kv,hd) -> (B,Kv,G,T,S)."""
    B, T, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, T, Kv, G, hd)
    return jnp.einsum("btkgd,bskd->bkgts", qg, k) * scale


# Sequences longer than this use the blockwise (flash-style) path: an
# online-softmax scan over KV chunks that never materializes (T, S) scores.
FLASH_THRESHOLD = 1024
Q_CHUNK = 512
KV_CHUNK = 1024


def blockwise_attention(q, k, v, *, causal=True, kv_chunk=KV_CHUNK):
    """Memory-bounded attention.  q: (B,T,H,hd); k/v: (B,S,Kv,hd).

    Single scan over KV chunks with the flash (m, l, acc) recurrence in f32;
    ALL query rows advance together.  This keeps the query/output tensors in
    whatever (batch, seq) sharding the caller established -- under SP the
    T dim stays on 'model' and every flash step is communication-free
    (a scan over q chunks would re-slice a sharded dim every step).  Live
    memory is one (B, Kv, G, T, ck) score tile.
    """
    B, T, H, hd = q.shape
    S = k.shape[1]
    Kv = k.shape[2]
    G = H // Kv
    ck = min(kv_chunk, S)
    pad = (-S) % ck
    if pad:  # ragged cache lengths (e.g. 1601 vision patches): mask the tail
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    nk = (S + pad) // ck
    scale = 1.0 / jnp.sqrt(hd)
    qg = q.reshape(B, T, Kv, G, hd).astype(jnp.float32)
    ks = k.reshape(B, nk, ck, Kv, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, nk, ck, Kv, hd).transpose(1, 0, 3, 2, 4)
    q_pos = jnp.arange(T)

    def kv_block(carry, ki_kc):
        m, l, acc = carry
        ki, kc, vc = ki_kc                   # (), (B,Kv,ck,hd) x2
        s = jnp.einsum("btkgd,bksd->bkgts", qg,
                       kc.astype(jnp.float32)) * scale  # (B,Kv,G,T,ck)
        k_pos = ki * ck + jnp.arange(ck)
        if causal:
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, -1e30)
        if pad:
            s = jnp.where(k_pos[None, :] < S, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = (acc * corr[..., None]
               + jnp.einsum("bkgts,bksd->bkgtd", p, vc.astype(jnp.float32)))
        return (m_new, l, acc), None

    init = (jnp.full((B, Kv, G, T), -1e30, jnp.float32),
            jnp.zeros((B, Kv, G, T), jnp.float32),
            jnp.zeros((B, Kv, G, T, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(kv_block, init, (jnp.arange(nk), ks, vs))
    out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,Kv,G,T,hd)
    return (out.transpose(0, 3, 1, 2, 4).reshape(B, T, H * hd)
            ).astype(q.dtype)


def attention(p, cfg, x, positions, *, causal=True, use_rope=True,
              pol=NO_SHARDING):
    """Full (training / prefill) attention.  x: (B, T, D)."""
    B, T, _ = x.shape
    hd, H, Kv = cfg.hd(), cfg.num_heads, cfg.num_kv_heads
    q, k, v = _project_qkv(p, cfg, x, positions, use_rope=use_rope, pol=pol)
    # K/V must be sequence-complete per device before the chunk scan --
    # one all-gather per layer instead of one per flash step.
    k, v = pol.kv_full(k), pol.kv_full(v)
    if T > FLASH_THRESHOLD:
        o = blockwise_attention(q, k, v, causal=causal)
    else:
        s = _gqa_scores(q, k, 1.0 / jnp.sqrt(hd)).astype(jnp.float32)
        if causal:
            mask = jnp.tril(jnp.ones((T, T), bool))
            s = jnp.where(mask, s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bkgts,bskd->btkgd", w, v).reshape(B, T, H * hd)
    return pol.resid(o @ cast(p["wo"], cfg.compute_dtype))


def cross_attention(p, cfg, x, kv_feats, *, pol=NO_SHARDING):
    """x: (B, T, D) queries over kv_feats: (B, S, D) (no RoPE, no mask)."""
    B, T, _ = x.shape
    S = kv_feats.shape[1]
    hd, H, Kv = cfg.hd(), cfg.num_heads, cfg.num_kv_heads
    q = (x @ cast(p["wq"], cfg.compute_dtype)).reshape(B, T, H, hd)
    k = (kv_feats @ cast(p["wk"], cfg.compute_dtype)).reshape(B, S, Kv, hd)
    v = (kv_feats @ cast(p["wv"], cfg.compute_dtype)).reshape(B, S, Kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = pol.heads(q)
    k, v = pol.kv_full(k), pol.kv_full(v)
    if T > FLASH_THRESHOLD:
        o = blockwise_attention(q, k, v, causal=False)
    else:
        s = _gqa_scores(q, k, 1.0 / jnp.sqrt(hd)).astype(jnp.float32)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bkgts,bskd->btkgd", w, v).reshape(B, T, H * hd)
    return pol.resid(o @ cast(p["wo"], cfg.compute_dtype))


def decode_attention_step(p, cfg, x, cache_k, cache_v, pos, *,
                          use_rope=True, pol=NO_SHARDING):
    """One-token attention against a KV cache.

    x: (B, 1, D); cache_k/v: (B, Tmax, Kv, hd); pos: () current index.
    Returns (out (B,1,D), new_k, new_v).
    """
    B = x.shape[0]
    hd, H, Kv = cfg.hd(), cfg.num_heads, cfg.num_kv_heads
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions, use_rope=use_rope, pol=pol)
    cache_k = pol.cache(jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos, axis=1))
    cache_v = pol.cache(jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos, axis=1))
    Tmax = cache_k.shape[1]
    s = _gqa_scores(q, cache_k.astype(q.dtype), 1.0 / jnp.sqrt(hd))
    s = s.astype(jnp.float32)
    valid = (jnp.arange(Tmax) <= pos)[None, None, None, None, :]
    s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgts,bskd->btkgd", w,
                   cache_v.astype(x.dtype)).reshape(B, 1, H * hd)
    return pol.resid(o @ cast(p["wo"], cfg.compute_dtype)), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP.
# ---------------------------------------------------------------------------
def init_mlp(key, cfg, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act == "swiglu":
        return {"w_gate": normal(ks[0], (d, f)),
                "w_up": normal(ks[1], (d, f)),
                "w_down": normal(ks[2], (f, d))}
    return {"w_up": normal(ks[0], (d, f)),
            "w_down": normal(ks[1], (f, d))}


def mlp(p, cfg, x, *, pol=NO_SHARDING):
    if cfg.mlp_act == "swiglu":
        h = (jax.nn.silu(x @ cast(p["w_gate"], cfg.compute_dtype))
             * (x @ cast(p["w_up"], cfg.compute_dtype)))
    else:
        h = jax.nn.gelu(x @ cast(p["w_up"], cfg.compute_dtype))
    h = pol.ffn(h)
    return pol.resid(h @ cast(p["w_down"], cfg.compute_dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding.
# ---------------------------------------------------------------------------
def init_embed(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {"tok": normal(k1, (cfg.vocab_size, cfg.d_model)),
         "norm_f": jnp.ones((cfg.d_model,))}
    if not cfg.tie_embeddings:
        p["unembed"] = normal(k2, (cfg.d_model, cfg.vocab_size))
    return p


def embed(p, cfg, tokens, *, pol=NO_SHARDING):
    out = jnp.take(cast(p["tok"], cfg.compute_dtype), tokens, axis=0)
    return pol.resid(out)


def unembed(p, cfg, x, *, pol=NO_SHARDING):
    x = rms_norm(x, p["norm_f"], cfg.norm_eps)
    w = (p["tok"].T if cfg.tie_embeddings else p["unembed"])
    return pol.logits(x @ cast(w, cfg.compute_dtype))
