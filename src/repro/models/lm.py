"""Family assembly: init / forward / decode / train & serve steps for all
10 assigned architectures.

Families (ArchConfig.family):
  dense   -- GQA transformer blocks (qwen3-32b, qwen1.5-0.5b, qwen2.5-3b,
             starcoder2-3b)
  moe     -- dense attention + grouped top-k MoE FFN (phi3.5-moe, qwen3-moe)
  ssm     -- Mamba2 SSD blocks (mamba2-130m)
  hybrid  -- Mamba2 groups + one *shared* attention block applied after each
             group (zamba2-1.2b; the real model also LoRA-specializes the
             shared block per site -- we share it verbatim, noted in
             DESIGN.md S5)
  audio   -- whisper-small: bidirectional encoder over precomputed frame
             embeddings (conv frontend stubbed per the brief) + causal
             decoder with cross-attention
  vlm     -- llama-3.2-vision: groups of self-attn layers + one
             cross-attention layer per group over precomputed patch
             embeddings (vision tower stubbed per the brief)

All stacks are ``lax.scan`` over stacked parameter pytrees with per-layer
``jax.checkpoint`` (remat), so HLO size and compile time are depth-
independent -- required for the 94-/100-layer multi-pod dry-runs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import common, moe, ssm
from repro.models.common import NO_SHARDING

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Blocks.
# ---------------------------------------------------------------------------
def _init_attn_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,)),
            "attn": common.init_attention(k1, cfg),
            "ln2": jnp.ones((cfg.d_model,)),
            "mlp": common.init_mlp(k2, cfg)}


def _init_moe_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,)),
            "attn": common.init_attention(k1, cfg),
            "ln2": jnp.ones((cfg.d_model,)),
            "moe": moe.init_moe(k2, cfg)}


def _init_mamba_block(key, cfg):
    return {"ln1": jnp.ones((cfg.d_model,)),
            "mamba": ssm.init_mamba(key, cfg)}


def _init_cross_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,)),
            "xattn": common.init_attention(k1, cfg),
            "ln2": jnp.ones((cfg.d_model,)),
            "mlp": common.init_mlp(k2, cfg)}


def _attn_block(p, cfg, x, positions, *, causal=True, pol=NO_SHARDING,
                moe_groups=None):
    h = common.attention(p["attn"], cfg,
                         common.rms_norm(x, p["ln1"], cfg.norm_eps),
                         positions, causal=causal, pol=pol)
    x = x + h
    z = common.rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        h = moe.moe_ffn(p["moe"], cfg, z, n_groups=moe_groups, pol=pol)
    else:
        h = common.mlp(p["mlp"], cfg, z, pol=pol)
    return x + h


def _mamba_block(p, cfg, x, *, pol=NO_SHARDING):
    return x + ssm.mamba_forward(
        p["mamba"], cfg, common.rms_norm(x, p["ln1"], cfg.norm_eps), pol=pol)


def _cross_block(p, cfg, x, feats, *, pol=NO_SHARDING):
    h = common.cross_attention(
        p["xattn"], cfg, common.rms_norm(x, p["ln1"], cfg.norm_eps), feats,
        pol=pol)
    x = x + h
    h = common.mlp(p["mlp"], cfg,
                   common.rms_norm(x, p["ln2"], cfg.norm_eps), pol=pol)
    return x + h


def _stack_init(key, n, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


UNROLL_STACKS = False  # set True to python-unroll layer stacks (flop-count
#                        validation against the analytic model; see
#                        tests/test_analytic.py -- XLA counts scan bodies once)


def _scan_stack(stacked, body, x, remat=True):
    """remat: True/'full' = recompute everything in bwd (min memory);
    'dots' = save matmul outputs with no batch dims (skips re-running the
    projections/MLP in the backward -- trades HBM for ~25% less recompute);
    False/'none' = no rematerialization (tests / tiny models)."""
    if remat in (True, "full"):
        f = jax.checkpoint(body)
    elif remat == "dots":
        f = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        f = body
    if UNROLL_STACKS:
        n = jax.tree.leaves(stacked)[0].shape[0]
        for i in range(n):
            x = f(jax.tree.map(lambda l: l[i], stacked), x)
        return x

    def step(carry, lp):
        return f(lp, carry), None

    x, _ = jax.lax.scan(step, x, stacked)
    return x


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------
def init_params(key, cfg) -> Dict[str, Any]:
    ke, kb, kx = jax.random.split(key, 3)
    params: Dict[str, Any] = {"embed": common.init_embed(ke, cfg)}
    fam = cfg.family
    if fam in ("dense", "moe"):
        fn = _init_moe_block if fam == "moe" else _init_attn_block
        params["blocks"] = _stack_init(kb, cfg.num_layers,
                                       functools.partial(fn, cfg=cfg))
    elif fam == "ssm":
        params["blocks"] = _stack_init(
            kb, cfg.num_layers, functools.partial(_init_mamba_block, cfg=cfg))
    elif fam == "hybrid":
        period = cfg.shared_attn_period
        n_groups, rem = divmod(cfg.num_layers, period)
        grp = jax.vmap(lambda k: _stack_init(
            k, period, functools.partial(_init_mamba_block, cfg=cfg)))
        params["groups"] = grp(jax.random.split(kb, n_groups))
        if rem:
            params["tail"] = _stack_init(
                jax.random.fold_in(kb, 1), rem,
                functools.partial(_init_mamba_block, cfg=cfg))
        params["shared_attn"] = _init_attn_block(kx, cfg)
    elif fam == "audio":
        params["encoder"] = _stack_init(
            kx, cfg.encoder_layers,
            functools.partial(_init_attn_block, cfg=cfg))
        params["enc_norm"] = jnp.ones((cfg.d_model,))
        dec = jax.random.split(kb, 2)
        params["blocks"] = _stack_init(
            dec[0], cfg.num_layers, functools.partial(_init_attn_block,
                                                      cfg=cfg))
        params["cross"] = _stack_init(
            dec[1], cfg.num_layers, functools.partial(_init_cross_block,
                                                      cfg=cfg))
    elif fam == "vlm":
        period = cfg.cross_attn_period
        n_cross = cfg.num_layers // period
        n_self = period - 1
        grp = jax.vmap(lambda k: _stack_init(
            k, n_self, functools.partial(_init_attn_block, cfg=cfg)))
        params["groups"] = grp(jax.random.split(kb, n_cross))
        params["cross"] = _stack_init(
            kx, n_cross, functools.partial(_init_cross_block, cfg=cfg))
    else:
        raise ValueError(f"unknown family {fam!r}")
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill).
# ---------------------------------------------------------------------------
def forward_hidden(params, cfg, tokens,
                   aux: Optional[Dict[str, Array]] = None,
                   *, pol=NO_SHARDING, remat=True, moe_groups=None) -> Array:
    """Causal LM trunk.  tokens: (B, T) -> final hidden states (B, T, D).

    aux carries modality-frontend stubs: {"frames": (B, S, D)} for audio,
    {"patches": (B, S, D)} for vlm.
    """
    B, T = tokens.shape
    x = common.embed(params["embed"], cfg, tokens, pol=pol)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    fam = cfg.family

    if fam in ("dense", "moe"):
        body = lambda lp, h: _attn_block(lp, cfg, h, positions, pol=pol,
                                         moe_groups=moe_groups)
        x = _scan_stack(params["blocks"], body, x, remat)
    elif fam == "ssm":
        body = lambda lp, h: _mamba_block(lp, cfg, h, pol=pol)
        x = _scan_stack(params["blocks"], body, x, remat)
    elif fam == "hybrid":
        mam = lambda lp, h: _mamba_block(lp, cfg, h, pol=pol)
        shared = params["shared_attn"]

        def group_body(gp, h):
            h = _scan_stack(gp, mam, h, remat)
            return _attn_block(shared, cfg, h, positions, pol=pol)

        x = _scan_stack(params["groups"], group_body, x, remat=False)
        if "tail" in params:
            x = _scan_stack(params["tail"], mam, x, remat)
    elif fam == "audio":
        feats = _encode_audio(params, cfg, aux["frames"], pol=pol,
                              remat=remat)

        def dec_body(lp, h):
            blk, xblk = lp
            h = _attn_block(blk, cfg, h, positions, pol=pol)
            return _cross_block(xblk, cfg, h, feats, pol=pol)

        x = _scan_stack((params["blocks"], params["cross"]), dec_body, x,
                        remat)
    elif fam == "vlm":
        feats = aux["patches"].astype(jnp.dtype(cfg.compute_dtype))
        slf = lambda lp, h: _attn_block(lp, cfg, h, positions, pol=pol)

        def group_body(lp, h):
            gp, xblk = lp
            h = _scan_stack(gp, slf, h, remat)
            return _cross_block(xblk, cfg, h, feats, pol=pol)

        x = _scan_stack((params["groups"], params["cross"]), group_body, x,
                        remat=False)
    return x


def forward(params, cfg, tokens, aux: Optional[Dict[str, Array]] = None,
            *, pol=NO_SHARDING, remat=True, moe_groups=None) -> Array:
    """Full-logits forward (small shapes / tests): (B, T) -> (B, T, V)."""
    x = forward_hidden(params, cfg, tokens, aux, pol=pol, remat=remat,
                       moe_groups=moe_groups)
    return common.unembed(params["embed"], cfg, x, pol=pol)


def prefill(params, cfg, tokens, aux: Optional[Dict[str, Array]] = None,
            *, pol=NO_SHARDING, remat=True, moe_groups=None) -> Array:
    """Prefill: process the whole prompt, emit logits for the LAST position
    only -- the full (B, T, V) tensor is never materialized (at 32k x 152k
    vocab it would be hundreds of GB)."""
    x = forward_hidden(params, cfg, tokens, aux, pol=pol, remat=remat,
                       moe_groups=moe_groups)
    return common.unembed(params["embed"], cfg, x[:, -1:, :], pol=pol)[:, 0]


def _encode_audio(params, cfg, frames, *, pol=NO_SHARDING, remat=True):
    """Whisper encoder over precomputed frame embeddings (frontend stub)."""
    B, S, _ = frames.shape
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    body = lambda lp, h: _attn_block(lp, cfg, h, positions, causal=False,
                                     pol=pol)
    x = _scan_stack(params["encoder"], body, x, remat)
    return common.rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decode (serve_step).
# ---------------------------------------------------------------------------
class Cache(NamedTuple):
    """Per-family decode state.

    attn_k/attn_v: (L_eq, B, Tmax, Kv, hd) for attention layers (L_eq is the
    number of attention *sites*: layers, or shared-block invocation sites for
    hybrid).  mamba: stacked ssm.MambaCache.  cross_k/v: precomputed
    encoder/vision cross KV (L_x, B, S, Kv, hd).  pos: () next index.
    """

    attn_k: Any = None
    attn_v: Any = None
    mamba: Any = None
    cross_k: Any = None
    cross_v: Any = None
    pos: Any = None


def _attn_cache_shape(cfg, sites, batch, max_len):
    return (sites, batch, max_len, cfg.num_kv_heads, cfg.hd())


def init_cache(cfg, batch: int, max_len: int, dtype=None) -> Cache:
    dt = jnp.dtype(dtype or cfg.compute_dtype)
    fam = cfg.family
    pos = jnp.zeros((), jnp.int32)
    # NB: attn_k / attn_v must be *distinct* arrays -- aliased leaves break
    # buffer donation in jitted decode loops (donate(a), donate(a)).
    if fam in ("dense", "moe", "audio"):
        sites = cfg.num_layers
        shp = _attn_cache_shape(cfg, sites, batch, max_len)
        return Cache(attn_k=jnp.zeros(shp, dt), attn_v=jnp.zeros(shp, dt),
                     pos=pos)
    if fam == "ssm":
        mk = jax.vmap(lambda _: ssm.init_mamba_cache(cfg, batch))(
            jnp.arange(cfg.num_layers))
        return Cache(mamba=mk, pos=pos)
    if fam == "hybrid":
        period = cfg.shared_attn_period
        n_groups, rem = divmod(cfg.num_layers, period)
        mk = jax.vmap(lambda _: jax.vmap(
            lambda __: ssm.init_mamba_cache(cfg, batch))(jnp.arange(period)))(
            jnp.arange(n_groups))
        tail = (jax.vmap(lambda _: ssm.init_mamba_cache(cfg, batch))(
            jnp.arange(rem)) if rem else None)
        shp = _attn_cache_shape(cfg, n_groups, batch, max_len)
        return Cache(attn_k=jnp.zeros(shp, dt), attn_v=jnp.zeros(shp, dt),
                     mamba={"groups": mk, "tail": tail}, pos=pos)
    if fam == "vlm":
        period = cfg.cross_attn_period
        n_cross = cfg.num_layers // period
        n_self = n_cross * (period - 1)
        shp = _attn_cache_shape(cfg, n_self, batch, max_len)
        return Cache(attn_k=jnp.zeros(shp, dt), attn_v=jnp.zeros(shp, dt),
                     pos=pos)
    raise ValueError(fam)


def precompute_cross_kv(params, cfg, feats) -> Dict[str, Array]:
    """Project encoder/vision features once into per-layer cross K/V."""
    def proj(xblk):
        B, S, _ = feats.shape
        Kv, hd = cfg.num_kv_heads, cfg.hd()
        k = (feats @ common.cast(xblk["xattn"]["wk"], cfg.compute_dtype)
             ).reshape(B, S, Kv, hd)
        v = (feats @ common.cast(xblk["xattn"]["wv"], cfg.compute_dtype)
             ).reshape(B, S, Kv, hd)
        if cfg.qk_norm:
            k = common.rms_norm(k, xblk["xattn"]["k_norm"], cfg.norm_eps)
        return k, v

    return jax.vmap(proj)(params["cross"])


def _cross_step_cached(xblk, cfg, x, k, v, *, pol=NO_SHARDING):
    B = x.shape[0]
    hd, H = cfg.hd(), cfg.num_heads
    q = (x @ common.cast(xblk["xattn"]["wq"], cfg.compute_dtype)
         ).reshape(B, 1, H, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, xblk["xattn"]["q_norm"], cfg.norm_eps)
    s = common._gqa_scores(q, k.astype(q.dtype), 1.0 / jnp.sqrt(hd))
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgts,bskd->btkgd", w,
                   v.astype(x.dtype)).reshape(B, 1, H * hd)
    return o @ common.cast(xblk["xattn"]["wo"], cfg.compute_dtype)


def decode_step(params, cfg, cache: Cache, token,
                *, pol=NO_SHARDING) -> tuple[Array, Cache]:
    """One decode step.  token: (B,) int32 -> (logits (B, V), new cache).

    For audio/vlm the cross K/V must be present in the cache
    (``precompute_cross_kv`` + Cache(cross_k=..., cross_v=...)).
    """
    B = token.shape[0]
    pos = cache.pos
    x = common.embed(params["embed"], cfg, token[:, None], pol=pol)
    fam = cfg.family

    def attn_site(p, h, ck, cv):
        hn = common.rms_norm(h, p["ln1"], cfg.norm_eps)
        out, ck, cv = common.decode_attention_step(p["attn"], cfg, hn, ck,
                                                   cv, pos, pol=pol)
        h = h + out
        z = common.rms_norm(h, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            h = h + moe.moe_ffn(p["moe"], cfg, z, n_groups=1, pol=pol)
        else:
            h = h + common.mlp(p["mlp"], cfg, z, pol=pol)
        return h, ck, cv

    if fam in ("dense", "moe"):
        def body(h, xs):
            lp, ck, cv = xs
            h, ck, cv = attn_site(lp, h, ck, cv)
            return h, (ck, cv)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["blocks"], cache.attn_k, cache.attn_v))
        cache = cache._replace(attn_k=ks, attn_v=vs, pos=pos + 1)
    elif fam == "ssm":
        def body(h, xs):
            lp, mc = xs
            hn = common.rms_norm(h, lp["ln1"], cfg.norm_eps)
            out, mc = ssm.mamba_step(lp["mamba"], cfg, hn, mc, pol=pol)
            return h + out, mc

        x, mcs = jax.lax.scan(body, x, (params["blocks"], cache.mamba))
        cache = cache._replace(mamba=mcs, pos=pos + 1)
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def mam_body(h, xs):
            lp, mc = xs
            hn = common.rms_norm(h, lp["ln1"], cfg.norm_eps)
            out, mc = ssm.mamba_step(lp["mamba"], cfg, hn, mc, pol=pol)
            return h + out, mc

        def group_body(h, xs):
            gp, gmc, ck, cv = xs
            h, gmc = jax.lax.scan(mam_body, h, (gp, gmc))
            h, ck, cv = attn_site(shared, h, ck, cv)
            return h, (gmc, ck, cv)

        x, (gmc, ks, vs) = jax.lax.scan(
            group_body, x,
            (params["groups"], cache.mamba["groups"], cache.attn_k,
             cache.attn_v))
        tail = cache.mamba["tail"]
        if "tail" in params:
            x, tail = jax.lax.scan(mam_body, x, (params["tail"], tail))
        cache = cache._replace(mamba={"groups": gmc, "tail": tail},
                               attn_k=ks, attn_v=vs, pos=pos + 1)
    elif fam == "audio":
        def body(h, xs):
            (lp, xblk, ck, cv, xk, xv) = xs
            h, ck, cv = attn_site(lp, h, ck, cv)
            hn = common.rms_norm(h, xblk["ln1"], cfg.norm_eps)
            h = h + _cross_step_cached(xblk, cfg, hn[:, 0], xk, xv, pol=pol)
            h = h + common.mlp(xblk["mlp"], cfg,
                               common.rms_norm(h, xblk["ln2"], cfg.norm_eps),
                               pol=pol)
            return h, (ck, cv)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["blocks"], params["cross"], cache.attn_k,
                      cache.attn_v, cache.cross_k, cache.cross_v))
        cache = cache._replace(attn_k=ks, attn_v=vs, pos=pos + 1)
    elif fam == "vlm":
        period = cfg.cross_attn_period
        n_cross = cfg.num_layers // period
        n_self = period - 1
        kss = cache.attn_k.reshape((n_cross, n_self) + cache.attn_k.shape[1:])
        vss = cache.attn_v.reshape((n_cross, n_self) + cache.attn_v.shape[1:])

        def self_body(h, xs):
            lp, ck, cv = xs
            h, ck, cv = attn_site(lp, h, ck, cv)
            return h, (ck, cv)

        def group_body(h, xs):
            gp, xblk, ck, cv, xk, xv = xs
            h, (ck, cv) = jax.lax.scan(self_body, h, (gp, ck, cv))
            hn = common.rms_norm(h, xblk["ln1"], cfg.norm_eps)
            h = h + _cross_step_cached(xblk, cfg, hn[:, 0], xk, xv, pol=pol)
            h = h + common.mlp(xblk["mlp"], cfg,
                               common.rms_norm(h, xblk["ln2"], cfg.norm_eps),
                               pol=pol)
            return h, (ck, cv)

        x, (ks, vs) = jax.lax.scan(
            group_body, x, (params["groups"], params["cross"], kss, vss,
                            cache.cross_k, cache.cross_v))
        cache = cache._replace(
            attn_k=ks.reshape(cache.attn_k.shape),
            attn_v=vs.reshape(cache.attn_v.shape), pos=pos + 1)
    else:
        raise ValueError(fam)

    logits = common.unembed(params["embed"], cfg, x, pol=pol)
    return logits[:, 0, :], cache


# ---------------------------------------------------------------------------
# Losses / steps.
# ---------------------------------------------------------------------------
CE_CHUNK = 512  # sequence positions per chunked-cross-entropy step


def lm_loss(params, cfg, tokens, labels, aux=None, *, pol=NO_SHARDING,
            moe_groups=None, remat=True):
    """Next-token CE with *chunked* unembedding: logits are produced and
    consumed CE_CHUNK positions at a time under a seq-chunk scan, so the
    (B, T, V) tensor never exists (train_4k x 152k vocab would be ~0.6 PB
    in f32 across the job).  Remat recomputes chunks in the backward."""
    x = forward_hidden(params, cfg, tokens, aux, pol=pol,
                       moe_groups=moe_groups, remat=remat)
    B, T, D = x.shape
    ck = min(CE_CHUNK, T)
    while T % ck:
        ck -= 1
    xc = x.reshape(B, T // ck, ck, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, T // ck, ck).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_nll(carry, xs):
        xchunk, lchunk = xs
        logits = common.unembed(params["embed"], cfg, xchunk,
                                pol=pol).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lchunk[..., None], axis=-1)[..., 0]
        return carry + nll.sum(), None

    total, _ = jax.lax.scan(chunk_nll, jnp.float32(0.0), (xc, lc))
    return total / (B * T)


def train_step(params, opt_state, batch, cfg, optimizer, *,
               pol=NO_SHARDING, moe_groups=None, remat=True):
    """One optimizer step.  batch: {"tokens": (B,T), "labels": (B,T), ...}."""
    aux = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    loss, grads = jax.value_and_grad(lm_loss)(
        params, cfg, batch["tokens"], batch["labels"], aux or None, pol=pol,
        moe_groups=moe_groups, remat=remat)
    params, opt_state = optimizer.update(grads, opt_state, params)
    return params, opt_state, loss


def train_step_accum(params, opt_state, batch, cfg, optimizer, *,
                     n_micro: int = 1, pol=NO_SHARDING, moe_groups=None):
    """One optimizer step with gradient accumulation over n_micro slices.

    The global batch is split along axis 0 and scanned; XLA schedules the
    gradient all-reduce of microbatch *i* to overlap the compute of *i+1*
    (the accumulation add is the reduction consumer inside the loop body).
    ``n_micro == 1`` reduces to :func:`train_step` exactly.
    """
    if n_micro == 1:
        return train_step(params, opt_state, batch, cfg, optimizer, pol=pol,
                          moe_groups=moe_groups)
    B = batch["tokens"].shape[0]
    assert B % n_micro == 0, (B, n_micro)
    micro = jax.tree.map(
        lambda x: x.reshape((n_micro, B // n_micro) + x.shape[1:]), batch)

    def one_micro(carry, mb):
        loss_acc, grad_acc = carry
        aux = {k: v for k, v in mb.items() if k not in ("tokens", "labels")}
        loss, grads = jax.value_and_grad(lm_loss)(
            params, cfg, mb["tokens"], mb["labels"], aux or None, pol=pol,
            moe_groups=moe_groups)
        return (loss_acc + loss,
                jax.tree.map(jnp.add, grad_acc, grads)), None

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(one_micro, (jnp.float32(0.0), zero),
                                    micro)
    grads = jax.tree.map(lambda g: g / n_micro, grads)
    params, opt_state = optimizer.update(grads, opt_state, params)
    return params, opt_state, loss / n_micro


def serve_step(params, cache: Cache, token, cfg, *, pol=NO_SHARDING):
    """One batched greedy decode step: (B,) token ids -> (B,) next ids."""
    logits, cache = decode_step(params, cfg, cache, token, pol=pol)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
