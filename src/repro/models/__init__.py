"""Model zoo: the 10 assigned architectures as config-driven JAX functions.

  common -- attention / MLP / norm / RoPE primitives + sharding hooks
  moe    -- GShard-style grouped top-k mixture-of-experts FFN
  ssm    -- Mamba2 SSD (chunked state-space duality) blocks
  lm     -- family assembly: dense | moe | ssm | hybrid | audio | vlm,
            init / forward / decode / train_step / serve_step
"""
from repro.models import lm

__all__ = ["lm"]
