"""starcoder2-3b [dense]: GQA, RoPE, GELU MLP (arXiv:2402.19173).
30L d_model=3072 24H (kv=2) d_ff=12288 vocab=49152."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2_3b", family="dense", num_layers=30, d_model=3072,
    num_heads=24, num_kv_heads=2, d_ff=12288, vocab_size=49152,
    mlp_act="gelu")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="starcoder2_smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
        mlp_act="gelu")
