"""whisper-small [audio]: enc-dec, conv frontend stubbed (arXiv:2212.04356).
12L decoder + 12L encoder, d_model=768 12H (kv=12) d_ff=3072 vocab=51865;
encoder consumes precomputed 1500-frame embeddings (input_specs stub)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_small", family="audio", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=51865,
    encoder_layers=12, encoder_seq=1500, mlp_act="gelu")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="whisper_smoke", family="audio", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        encoder_layers=2, encoder_seq=32, mlp_act="gelu")
