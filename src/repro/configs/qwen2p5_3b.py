"""qwen2.5-3b [dense]: GQA, QKV bias (hf:Qwen/Qwen2.5 family).
36L d_model=2048 16H (kv=2) d_ff=11008 vocab=151936."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2p5_3b", family="dense", num_layers=36, d_model=2048,
    num_heads=16, num_kv_heads=2, d_ff=11008, vocab_size=151936,
    qkv_bias=True, mlp_act="swiglu")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen2p5_smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
        qkv_bias=True, mlp_act="swiglu")
