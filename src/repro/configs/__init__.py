"""Architecture configs: one module per assigned architecture (+ helpers).

``get(name)`` accepts both canonical ids (qwen3_32b) and the brief's ids
(qwen3-32b).  Each module exposes CONFIG (exact published shape) and
smoke() (reduced same-family config for CPU tests).
"""
from repro.configs.base import (ALIASES, ARCH_IDS, SHAPES, ArchConfig,
                                InputShape, all_configs, canonical, get,
                                get_shape, get_smoke)

__all__ = ["ALIASES", "ARCH_IDS", "SHAPES", "ArchConfig", "InputShape",
           "all_configs", "canonical", "get", "get_shape", "get_smoke"]
