"""Architecture config schema + registry.

One file per assigned architecture lives in this package; each exposes
``CONFIG`` (the exact published shape) and ``smoke()`` (a reduced same-family
config for CPU tests).  ``repro.configs.get(name)`` looks either up.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # Attention details.
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0

    # MoE (d_ff above is the per-expert hidden dim when num_experts > 0).
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD).
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128

    # Hybrid (zamba2): one *shared* attention block invoked every
    # ``shared_attn_period`` SSM layers.
    shared_attn_period: int = 0

    # Encoder-decoder (whisper): ``num_layers`` is the decoder depth.
    encoder_layers: int = 0
    encoder_seq: int = 1500     # whisper-small: 30 s -> 1500 frames

    # VLM: one cross-attention layer every ``cross_attn_period`` layers
    # (counted within num_layers) attending to ``vision_seq`` patch embeds.
    cross_attn_period: int = 0
    vision_seq: int = 1601      # (448/14)^2 + cls for Llama-3.2-Vision

    mlp_act: str = "swiglu"     # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # Numerics.
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True iff decode cost/state is sub-linear in context (SSM/hybrid).

        Pure full-attention archs skip the long_500k shape (DESIGN.md
        SArch-applicability)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, hd = self.d_model, self.d_ff, self.hd()
        qkv = d * hd * (self.num_heads + 2 * self.num_kv_heads) + hd * \
            self.num_heads * d
        if self.mlp_act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.num_experts:
            mlp = self.num_experts * 3 * d * f + d * self.num_experts
        if self.family == "ssm":
            di = self.ssm_expand * d
            blk = d * (2 * di + 2 * self.ssm_state) + di * d
        elif self.family == "hybrid":
            di = self.ssm_expand * d
            blk = d * (2 * di + 2 * self.ssm_state) + di * d + mlp // 4
        else:
            blk = qkv + mlp
        n = self.num_layers * blk + 2 * self.vocab_size * d
        if self.encoder_layers:
            n += self.encoder_layers * (qkv + mlp)
        return int(n)


# Input shape grid (the brief's per-arch shape set).
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4096, 256, "train"),
    InputShape("prefill_32k", 32768, 32, "prefill"),
    InputShape("decode_32k", 32768, 128, "decode"),
    InputShape("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> InputShape:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


ARCH_IDS: List[str] = [
    "zamba2_1p2b",
    "phi3p5_moe_42b",
    "qwen3_moe_235b",
    "whisper_small",
    "qwen3_32b",
    "qwen1p5_0p5b",
    "starcoder2_3b",
    "qwen2p5_3b",
    "mamba2_130m",
    "llama3p2_vision_90b",
]

# CLI-friendly aliases (the brief's ids).
ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "phi3.5-moe-42b-a6.6b": "phi3p5_moe_42b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "whisper-small": "whisper_small",
    "qwen3-32b": "qwen3_32b",
    "qwen1.5-0.5b": "qwen1p5_0p5b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen2.5-3b": "qwen2p5_3b",
    "mamba2-130m": "mamba2_130m",
    "llama-3.2-vision-90b": "llama3p2_vision_90b",
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke()


def all_configs() -> List[ArchConfig]:
    return [get(a) for a in ARCH_IDS]
