"""qwen3-32b [dense]: qk_norm, GQA (hf:Qwen/Qwen3 family).
64L d_model=5120 64H (kv=8) d_ff=25600 vocab=151936, head_dim=128."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_32b", family="dense", num_layers=64, d_model=5120,
    num_heads=64, num_kv_heads=8, d_ff=25600, vocab_size=151936,
    head_dim=128, qk_norm=True, mlp_act="swiglu")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen3_smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        qk_norm=True, mlp_act="swiglu")
