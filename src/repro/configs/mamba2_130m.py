"""mamba2-130m [ssm]: SSD, attention-free (arXiv:2405.21060).
24L d_model=768 ssm_state=128 vocab=50280; d_inner=1536, head_dim=64 -> 24
SSD heads."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2_130m", family="ssm", num_layers=24, d_model=768,
    num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, tie_embeddings=True)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mamba2_smoke", family="ssm", num_layers=3, d_model=64,
        num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=256,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=8,
        tie_embeddings=True)
