"""zamba2-1.2b [hybrid]: 38 Mamba2 layers + shared attention block
(arXiv:2411.15242).  38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64; the shared transformer block fires after every 6th SSM layer."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2_1p2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, shared_attn_period=6,
    mlp_act="swiglu")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="zamba2_smoke", family="hybrid", num_layers=5, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, shared_attn_period=2,
        ssm_chunk=8, mlp_act="swiglu")
