"""llama-3.2-vision-90b [vlm]: cross-attn image layers
(hf:meta-llama/Llama-3.2-Vision family).  100L d_model=8192 64H (kv=8)
d_ff=28672 vocab=128256; every 5th layer cross-attends to precomputed patch
embeddings (vision tower stubbed per the brief)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3p2_vision_90b", family="vlm", num_layers=100, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=28672, vocab_size=128256,
    head_dim=128, cross_attn_period=5, vision_seq=1601, mlp_act="swiglu")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llama_vision_smoke", family="vlm", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        cross_attn_period=2, vision_seq=16, mlp_act="swiglu")
