"""phi3.5-moe-42b-a6.6b [moe]: 16 experts top-2
(hf:microsoft/Phi-3.5-MoE-instruct).  32L d_model=4096 32H (kv=8)
d_ff=6400/expert vocab=32064."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3p5_moe_42b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=6400, vocab_size=32064,
    num_experts=16, experts_per_token=2, mlp_act="swiglu")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="phi3p5_moe_smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=256,
        num_experts=4, experts_per_token=2, mlp_act="swiglu")
