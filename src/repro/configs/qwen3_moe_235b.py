"""qwen3-moe-235b-a22b [moe]: 128 experts top-8 (hf:Qwen/Qwen3-30B-A3B
family).  94L d_model=4096 64H (kv=4) d_ff=1536/expert vocab=151936,
head_dim=128, qk_norm."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_moe_235b", family="moe", num_layers=94, d_model=4096,
    num_heads=64, num_kv_heads=4, d_ff=1536, vocab_size=151936,
    head_dim=128, qk_norm=True, num_experts=128, experts_per_token=8,
    mlp_act="swiglu")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen3_moe_smoke", family="moe", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=32, vocab_size=256, head_dim=16,
        qk_norm=True, num_experts=8, experts_per_token=2, mlp_act="swiglu")
