"""Genetic algorithms: the stage-2 local fine-tuner (SIII-G) and the
general-GA baseline (SIV-A3).

Both operate on genomes of 2N genes -- per-layer (PE, Buf) -- plus an
optional dataflow gene for MIX.  The baseline GA works in the coarse L-level
space; the local fine-tuner works in the *raw* integer space around the
stage-1 solution with the paper's conservative operators:

  * local mutation   -- a gene moves at most +-step from its current value
                        (SIII-G "for a gene representing PE=64 ... mutate to
                        value in the range [60, 68] when the step is 4")
  * local crossover  -- *within* one genome: swap the (PE, Buf) pairs of two
                        layers, preserving the learnt budget split

Fitness = whole-model objective, +inf when the platform constraint is
violated.  Fully vectorized: one generation = one batched cost-model call.

Both GAs are **chunked, resumable engines** with the same lifecycle as
``reinforce.run_search``/``rl_baselines.run_ac_search``: the generation scan
runs in fixed-size chunks, ``on_chunk(state, chunk_hist, gens_done)`` fires
between chunks (the unified API streams progress and observes cancellation
there), and the returned :class:`GAState` feeds back in via ``state=`` to
continue a run bit-identically.  Each engine splits one generation into a
*fitness* half and an *evolve* half so a host-side ``eval_fn`` (the search
service's cross-request :class:`~repro.serving.batcher.CostEvalBatcher`) can
own the fitness evaluation; the fitness values are bit-identical whichever
path computes them, so batched outcomes equal in-graph ones byte for byte.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunk as chunk_lib
from repro.core import env as env_lib
from repro.costmodel import dataflows as dfl


@dataclasses.dataclass(frozen=True)
class GAConfig:
    population: int = 100
    generations: int = 50
    mutation_rate: float = 0.05
    crossover_rate: float = 0.05
    seed: int = 0
    # None = auto: the Pallas batched cost kernel on TPU, the jnp oracle
    # elsewhere (interpret mode would dominate the generation on CPU).
    use_kernel: Optional[bool] = None


@dataclasses.dataclass(frozen=True)
class LocalGAConfig:
    population: int = 20
    generations: int = 2000
    mutation_rate: float = 0.05
    crossover_rate: float = 0.2
    mutation_step: int = 4       # raw-space +-step (PE); kt uses step 1
    seed: int = 0


class GAState(NamedTuple):
    """Scan carry of either GA: everything a resumed run needs."""

    pop: jnp.ndarray             # (P, N, genes) int32
    best_val: jnp.ndarray        # () f32 best feasible objective so far
    best_genome: jnp.ndarray     # (N, genes) int32
    key: jnp.ndarray
    generation: jnp.ndarray      # () int32 generations completed


class GAEngine(NamedTuple):
    """Building blocks of one GA run.

    ``gen_step(state, _) == evolve(state, fitness(state.pop))`` -- the scan
    body of the in-graph path.  The split exists so a host-side ``eval_fn``
    can own the fitness half (search-service batching) while ``evolve``
    stays the one compiled selection/breeding program either way.
    """

    init_carry: Callable         # seed -> GAState
    gen_step: Callable           # (GAState, _) -> (GAState, best_val)
    decode: Callable             # genome levels -> (pe, kt, df) raw
    fitness: Callable            # pop -> (P,) objective-or-inf
    evolve: Callable             # (GAState, fit) -> (GAState, best_val)


class GAResult(NamedTuple):
    best_value: jnp.ndarray      # () objective; inf if nothing feasible
    best_pe: jnp.ndarray         # (N,) raw PE counts
    best_kt: jnp.ndarray         # (N,) raw tile counts
    best_df: jnp.ndarray         # (N,) dataflow ids
    history: jnp.ndarray         # (generations,) best-so-far trace
    evals: int


def _fitness(env, ecfg, pe, kt, df, use_kernel: bool = False):
    if use_kernel and getattr(pe, "ndim", 0) == 2:
        # Population-sized batches are exactly the Pallas kernel's shape:
        # (B, N) design points against the (N, NUM_FIELDS) workload.
        from repro.kernels import ops
        lat, en, area, pw = ops.batched_cost(env.layers, pe, kt, df)
        perf, _, feas = env_lib.aggregate_costs(lat, en, area, pw, ecfg,
                                                env.budget)
        return jnp.where(feas, perf, jnp.inf)
    perf, cons, feas = env_lib.genome_cost(env, ecfg, pe, kt, df)
    return jnp.where(feas, perf, jnp.inf)


# ---------------------------------------------------------------------------
# Baseline GA (coarse level space).
# ---------------------------------------------------------------------------
def make_ga_engine(env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
                   cfg: GAConfig) -> GAEngine:
    """The baseline GA's :class:`GAEngine` for one environment.

    ``init_carry(seed)`` builds the scan carry for one independent GA run;
    ``gen_step`` is seed-free, so the fanout device backend can shard_map one
    compiled generation scan across devices whose carries differ only in
    their seed.  ``run_ga_search`` below is the chunked single-run driver.
    """
    N = env.num_layers
    P = cfg.population
    L = ecfg.levels
    n_df = 3 if ecfg.mix else 1
    genes = 3 if ecfg.mix else 2
    use_kernel = (cfg.use_kernel if cfg.use_kernel is not None
                  else jax.default_backend() == "tpu")

    def decode(genome):
        pe = env.pe_table[genome[..., 0]]
        kt = env.kt_table[genome[..., 1]]
        df = (genome[..., 2] if ecfg.mix
              else jnp.asarray(ecfg.dataflow, jnp.int32))
        return pe, kt, df

    def fitness(pop):
        pe, kt, df = decode(pop)
        return _fitness(env, ecfg, pe, kt, df, use_kernel)   # (P,)

    def evolve(state: GAState, fit):
        pop, best_val, best_genome, key, gen = state
        order = jnp.argsort(fit)
        pop = pop[order]
        fit = fit[order]
        better = fit[0] < best_val
        best_val = jnp.where(better, fit[0], best_val)
        best_genome = jnp.where(better, pop[0], best_genome)
        # Elitist half survives; children from random parent pairs.
        half = P // 2
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        pa = jax.random.randint(k1, (P - half,), 0, half)
        pb = jax.random.randint(k2, (P - half,), 0, half)
        cx_mask = (jax.random.uniform(k3, (P - half, N, pop.shape[-1]))
                   < cfg.crossover_rate)
        children = jnp.where(cx_mask, pop[pb], pop[pa])
        mut_mask = (jax.random.uniform(k4, children.shape)
                    < cfg.mutation_rate)
        key, k5 = jax.random.split(key)
        rand = jax.random.randint(k5, children.shape, 0, L)
        if ecfg.mix:
            rand = rand.at[..., 2].set(
                jax.random.randint(jax.random.fold_in(k5, 1),
                                   children.shape[:-1], 0, n_df))
        children = jnp.where(mut_mask, rand, children)
        pop = jnp.concatenate([pop[:half], children], axis=0)
        return GAState(pop, best_val, best_genome, key, gen + 1), best_val

    def gen_step(carry: GAState, _):
        return evolve(carry, fitness(carry.pop))

    def init_carry(seed) -> GAState:
        key = jax.random.PRNGKey(seed)
        key, k0 = jax.random.split(key)
        pop = jax.random.randint(k0, (P, N, genes), 0, L)
        if ecfg.mix:
            pop = pop.at[..., 2].set(
                jax.random.randint(jax.random.fold_in(k0, 7), (P, N), 0, 3))
        return GAState(pop, jnp.float32(jnp.inf),
                       jnp.zeros((N, genes), jnp.int32), key,
                       jnp.zeros((), jnp.int32))

    return GAEngine(init_carry, gen_step, decode, fitness, evolve)


def run_chunked_engine(env, ecfg, engine: GAEngine, state,
                       generations: int, chunk: Optional[int], on_chunk,
                       eval_fn, mix_df: bool, raw_genome: bool = False,
                       fixed_df=None, engine_name: str = "ga"):
    """Shared chunk driver for every population engine.  Returns
    (state, (gens,) history).

    Drives both GAs here and the NSGA-II engine in ``core/nsga2.py``: any
    engine whose state leads with a ``pop`` field of candidates awaiting
    evaluation and whose ``evolve(state, fit)`` consumes their fitness
    (scalar (P,) or multi-objective (P, 4)) gets chunking, resume,
    cancellation and eval_fn injection from this one loop (via
    :func:`repro.core.chunk.drive`, which also tags each chunk's telemetry
    with ``engine_name`` -- one hard eval per population member per
    generation).

    ``eval_fn=None`` scans ``gen_step`` in jitted chunks (fitness stays in
    the XLA program); with ``eval_fn(pe, kt, df) -> (P,) fitness`` each
    generation decodes on the host, evaluates through the injected function
    (the service's cross-request batcher) and applies the same compiled
    ``evolve`` step.  Both paths produce byte-identical states/histories:
    the decode is the same table gather, the fitness values are bit-equal
    (asserted in tests/test_search_service.py), and every other op is the
    identical jnp program.
    """
    pop_size = int(state.pop.shape[0])
    if eval_fn is None:
        @functools.partial(jax.jit, static_argnames=("n",))
        def scan_chunk(state, n):
            return jax.lax.scan(engine.gen_step, state, None, length=n)

        def run_chunk(state, n):
            state, h = scan_chunk(state, n)
            return state, np.asarray(h)

        state, hist = chunk_lib.drive(
            state, generations, chunk, run_chunk, on_chunk,
            engine=engine_name, evals_per_step=pop_size)
        return state, chunk_lib.concat_hist(hist)

    evolve = jax.jit(engine.evolve)
    pe_table = np.asarray(env.pe_table, np.float32)
    kt_table = np.asarray(env.kt_table, np.float32)

    def run_chunk(state, n):
        h = np.empty((n,), np.float32)
        for g in range(n):
            pop = np.asarray(state.pop)
            if raw_genome:
                pe = pop[..., 0].astype(np.float32)
                kt = pop[..., 1].astype(np.float32)
            else:
                pe = pe_table[pop[..., 0]]
                kt = kt_table[pop[..., 1]]
            if fixed_df is not None:
                df = fixed_df
            elif mix_df:
                df = pop[..., 2].astype(np.float32)
            else:
                df = np.float32(ecfg.dataflow)
            fit = np.asarray(eval_fn(pe, kt, df), np.float32)
            state, bv = evolve(state, jnp.asarray(fit))
            h[g] = np.float32(bv)
        return state, h

    state, hist = chunk_lib.drive(
        state, generations, chunk, run_chunk, on_chunk,
        engine=engine_name, evals_per_step=pop_size)
    return state, chunk_lib.concat_hist(hist)


def run_ga_search(workload, ecfg: env_lib.EnvConfig,
                  cfg: GAConfig = GAConfig(),
                  state: Optional[GAState] = None,
                  chunk: Optional[int] = None,
                  on_chunk=None,
                  eval_fn=None,
                  env: Optional[env_lib.EnvArrays] = None):
    """Chunked, resumable baseline GA.  Returns (GAState, (gens,) history).

    Runs ``cfg.generations`` *more* generations from ``state`` (fresh run
    when None), in chunks of ``chunk`` generations (default: one chunk).
    ``on_chunk(state, chunk_hist, gens_done)`` fires between chunks -- the
    unified API streams progress and observes cancellation there, exactly
    like ``reinforce.run_search``.  ``eval_fn(pe, kt, df) -> (P,) fitness``
    moves the per-generation fitness evaluation to the host (the search
    service injects its cross-request batcher); results are byte-identical
    either way.  Chunk boundaries never change the result.
    """
    if env is None:
        env = env_lib.make_env(workload, ecfg)
    engine = make_ga_engine(env, ecfg, cfg)
    if state is None:
        state = engine.init_carry(cfg.seed)
    return run_chunked_engine(env, ecfg, engine, state, cfg.generations,
                              chunk, on_chunk, eval_fn, mix_df=ecfg.mix,
                              engine_name="ga")


def ga_solution(env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
                state: GAState):
    """Decode a baseline-GA state's best genome to raw (pe, kt, df)."""
    pe = env.pe_table[state.best_genome[..., 0]]
    kt = env.kt_table[state.best_genome[..., 1]]
    df = (state.best_genome[..., 2] if ecfg.mix
          else jnp.asarray(ecfg.dataflow, jnp.int32))
    return pe, kt, jnp.broadcast_to(df, (env.num_layers,))


def baseline_ga(workload, ecfg: env_lib.EnvConfig,
                cfg: GAConfig = GAConfig()) -> GAResult:
    env = env_lib.make_env(workload, ecfg)
    state, hist = run_ga_search(workload, ecfg, cfg, env=env)
    pe, kt, df = ga_solution(env, ecfg, state)
    return GAResult(state.best_val, pe, kt, df, hist,
                    cfg.population * cfg.generations)


# ---------------------------------------------------------------------------
# Stage-2 local GA (fine-grained raw space, seeded by the RL solution).
# ---------------------------------------------------------------------------
def make_local_ga_engine(env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
                         init_pe, init_kt, init_df,
                         cfg: LocalGAConfig) -> GAEngine:
    """The fine-tuner's :class:`GAEngine`: raw-space genomes, fixed df."""
    N = env.num_layers
    P = cfg.population

    init_genome = jnp.stack(
        [jnp.asarray(init_pe, jnp.int32), jnp.asarray(init_kt, jnp.int32)],
        axis=-1)                                         # (N, 2)
    df = jnp.asarray(init_df, jnp.int32)                 # (N,) fixed in stage 2

    def mutate(genome, key):
        k1, k2 = jax.random.split(key)
        mask = jax.random.uniform(k1, genome.shape) < cfg.mutation_rate
        step = jnp.stack([
            jax.random.randint(k2, genome.shape[:-1],
                               -cfg.mutation_step, cfg.mutation_step + 1),
            jax.random.randint(jax.random.fold_in(k2, 1), genome.shape[:-1],
                               -1, 2)], axis=-1)
        out = jnp.where(mask, genome + step, genome)
        lo = jnp.array([dfl.PE_MIN, dfl.KT_MIN])
        hi = jnp.array([dfl.PE_MAX, dfl.KT_MAX])
        return jnp.clip(out, lo, hi)

    def self_crossover(genome, key):
        """Swap the (PE, Buf) pairs of two random layers (SIII-G)."""
        k1, k2, k3 = jax.random.split(key, 3)
        i = jax.random.randint(k1, (), 0, N)
        j = jax.random.randint(k2, (), 0, N)
        do = jax.random.uniform(k3) < cfg.crossover_rate
        gi, gj = genome[i], genome[j]
        swapped = genome.at[i].set(gj).at[j].set(gi)
        return jnp.where(do, swapped, genome)

    def decode(genome):
        return (genome[..., 0].astype(jnp.float32),
                genome[..., 1].astype(jnp.float32), df)

    def fitness(pop):
        pe, kt, _ = decode(pop)
        return _fitness(env, ecfg, pe, kt, df)

    def evolve(state: GAState, fit):
        pop, best_val, best_genome, key, gen = state
        order = jnp.argsort(fit)
        pop, fit = pop[order], fit[order]
        better = fit[0] < best_val
        best_val = jnp.where(better, fit[0], best_val)
        best_genome = jnp.where(better, pop[0], best_genome)
        half = P // 2
        key, k1, k2, k3 = jax.random.split(key, 4)
        parents = pop[jax.random.randint(k1, (P - half,), 0, half)]
        children = jax.vmap(self_crossover)(
            parents, jax.random.split(k2, P - half))
        children = jax.vmap(mutate)(children, jax.random.split(k3, P - half))
        pop = jnp.concatenate([pop[:half], children], axis=0)
        return GAState(pop, best_val, best_genome, key, gen + 1), best_val

    def gen_step(carry: GAState, _):
        return evolve(carry, fitness(carry.pop))

    def init_carry(seed) -> GAState:
        pop = jnp.broadcast_to(init_genome, (P, N, 2)).astype(jnp.int32)
        return GAState(pop, jnp.float32(jnp.inf), init_genome,
                       jax.random.PRNGKey(seed), jnp.zeros((), jnp.int32))

    return GAEngine(init_carry, gen_step, decode, fitness, evolve)


def run_local_ga(workload, ecfg: env_lib.EnvConfig,
                 init_pe, init_kt, init_df,
                 cfg: LocalGAConfig = LocalGAConfig(),
                 state: Optional[GAState] = None,
                 chunk: Optional[int] = None,
                 on_chunk=None,
                 eval_fn=None,
                 env: Optional[env_lib.EnvArrays] = None):
    """Chunked, resumable stage-2 fine-tune; same contract as run_ga_search.

    The dataflow assignment is frozen at ``init_df`` (stage 2 fine-tunes
    only the budget split), so ``eval_fn`` always receives that fixed array.
    """
    if env is None:
        env = env_lib.make_env(workload, ecfg)
    engine = make_local_ga_engine(env, ecfg, init_pe, init_kt, init_df, cfg)
    if state is None:
        state = engine.init_carry(cfg.seed)
    fixed_df = np.asarray(init_df, np.float32) if eval_fn is not None else None
    return run_chunked_engine(env, ecfg, engine, state, cfg.generations,
                              chunk, on_chunk, eval_fn, mix_df=False,
                              raw_genome=True, fixed_df=fixed_df,
                              engine_name="local_ga")


def local_ga(workload, ecfg: env_lib.EnvConfig,
             init_pe, init_kt, init_df,
             cfg: LocalGAConfig = LocalGAConfig()) -> GAResult:
    state, hist = run_local_ga(workload, ecfg, init_pe, init_kt, init_df, cfg)
    df = jnp.asarray(init_df, jnp.int32)
    return GAResult(state.best_val,
                    state.best_genome[..., 0].astype(jnp.float32),
                    state.best_genome[..., 1].astype(jnp.float32),
                    df, hist, cfg.population * cfg.generations)
