"""Genetic algorithms: the stage-2 local fine-tuner (SIII-G) and the
general-GA baseline (SIV-A3).

Both operate on genomes of 2N genes -- per-layer (PE, Buf) -- plus an
optional dataflow gene for MIX.  The baseline GA works in the coarse L-level
space; the local fine-tuner works in the *raw* integer space around the
stage-1 solution with the paper's conservative operators:

  * local mutation   -- a gene moves at most +-step from its current value
                        (SIII-G "for a gene representing PE=64 ... mutate to
                        value in the range [60, 68] when the step is 4")
  * local crossover  -- *within* one genome: swap the (PE, Buf) pairs of two
                        layers, preserving the learnt budget split

Fitness = whole-model objective, +inf when the platform constraint is
violated.  Fully vectorized: one generation = one batched cost-model call.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import env as env_lib
from repro.costmodel import dataflows as dfl


@dataclasses.dataclass(frozen=True)
class GAConfig:
    population: int = 100
    generations: int = 50
    mutation_rate: float = 0.05
    crossover_rate: float = 0.05
    seed: int = 0
    # None = auto: the Pallas batched cost kernel on TPU, the jnp oracle
    # elsewhere (interpret mode would dominate the generation on CPU).
    use_kernel: Optional[bool] = None


@dataclasses.dataclass(frozen=True)
class LocalGAConfig:
    population: int = 20
    generations: int = 2000
    mutation_rate: float = 0.05
    crossover_rate: float = 0.2
    mutation_step: int = 4       # raw-space +-step (PE); kt uses step 1
    seed: int = 0


class GAResult(NamedTuple):
    best_value: jnp.ndarray      # () objective; inf if nothing feasible
    best_pe: jnp.ndarray         # (N,) raw PE counts
    best_kt: jnp.ndarray         # (N,) raw tile counts
    best_df: jnp.ndarray         # (N,) dataflow ids
    history: jnp.ndarray         # (generations,) best-so-far trace
    evals: int


def _fitness(env, ecfg, pe, kt, df, use_kernel: bool = False):
    if use_kernel and getattr(pe, "ndim", 0) == 2:
        # Population-sized batches are exactly the Pallas kernel's shape:
        # (B, N) design points against the (N, NUM_FIELDS) workload.
        from repro.kernels import ops
        lat, en, area, pw = ops.batched_cost(env.layers, pe, kt, df)
        perf, _, feas = env_lib.aggregate_costs(lat, en, area, pw, ecfg,
                                                env.budget)
        return jnp.where(feas, perf, jnp.inf)
    perf, cons, feas = env_lib.genome_cost(env, ecfg, pe, kt, df)
    return jnp.where(feas, perf, jnp.inf)


# ---------------------------------------------------------------------------
# Baseline GA (coarse level space).
# ---------------------------------------------------------------------------
def make_ga_engine(env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
                   cfg: GAConfig):
    """(init_carry, gen_step, decode) building blocks of the baseline GA.

    ``init_carry(seed)`` builds the scan carry for one independent GA run;
    ``gen_step`` is seed-free, so the fanout device backend can shard_map one
    compiled generation scan across devices whose carries differ only in
    their seed.  ``baseline_ga`` below is the single-run composition.
    """
    N = env.num_layers
    P = cfg.population
    L = ecfg.levels
    n_df = 3 if ecfg.mix else 1
    genes = 3 if ecfg.mix else 2
    use_kernel = (cfg.use_kernel if cfg.use_kernel is not None
                  else jax.default_backend() == "tpu")

    def decode(genome):
        pe = env.pe_table[genome[..., 0]]
        kt = env.kt_table[genome[..., 1]]
        df = (genome[..., 2] if ecfg.mix
              else jnp.asarray(ecfg.dataflow, jnp.int32))
        return pe, kt, df

    def gen_step(carry, _):
        pop, best_val, best_genome, key = carry
        pe, kt, df = decode(pop)
        fit = _fitness(env, ecfg, pe, kt, df, use_kernel)   # (P,)
        order = jnp.argsort(fit)
        pop = pop[order]
        fit = fit[order]
        better = fit[0] < best_val
        best_val = jnp.where(better, fit[0], best_val)
        best_genome = jnp.where(better, pop[0], best_genome)
        # Elitist half survives; children from random parent pairs.
        half = P // 2
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        pa = jax.random.randint(k1, (P - half,), 0, half)
        pb = jax.random.randint(k2, (P - half,), 0, half)
        cx_mask = (jax.random.uniform(k3, (P - half, N, pop.shape[-1]))
                   < cfg.crossover_rate)
        children = jnp.where(cx_mask, pop[pb], pop[pa])
        mut_mask = (jax.random.uniform(k4, children.shape)
                    < cfg.mutation_rate)
        key, k5 = jax.random.split(key)
        rand = jax.random.randint(k5, children.shape, 0, L)
        if ecfg.mix:
            rand = rand.at[..., 2].set(
                jax.random.randint(jax.random.fold_in(k5, 1),
                                   children.shape[:-1], 0, n_df))
        children = jnp.where(mut_mask, rand, children)
        pop = jnp.concatenate([pop[:half], children], axis=0)
        return (pop, best_val, best_genome, key), best_val

    def init_carry(seed):
        key = jax.random.PRNGKey(seed)
        key, k0 = jax.random.split(key)
        pop = jax.random.randint(k0, (P, N, genes), 0, L)
        if ecfg.mix:
            pop = pop.at[..., 2].set(
                jax.random.randint(jax.random.fold_in(k0, 7), (P, N), 0, 3))
        return (pop, jnp.float32(jnp.inf),
                jnp.zeros((N, genes), jnp.int32), key)

    return init_carry, gen_step, decode


def baseline_ga(workload, ecfg: env_lib.EnvConfig,
                cfg: GAConfig = GAConfig()) -> GAResult:
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    init_carry, gen_step, decode = make_ga_engine(env, ecfg, cfg)
    (pop, best_val, best_genome, _), hist = jax.lax.scan(
        gen_step, init_carry(cfg.seed), None, length=cfg.generations)
    pe, kt, df = decode(best_genome)
    df = jnp.broadcast_to(df, (N,))
    return GAResult(best_val, pe, kt, df, hist,
                    cfg.population * cfg.generations)


# ---------------------------------------------------------------------------
# Stage-2 local GA (fine-grained raw space, seeded by the RL solution).
# ---------------------------------------------------------------------------
def local_ga(workload, ecfg: env_lib.EnvConfig,
             init_pe, init_kt, init_df,
             cfg: LocalGAConfig = LocalGAConfig()) -> GAResult:
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    P = cfg.population
    key = jax.random.PRNGKey(cfg.seed)

    init_genome = jnp.stack(
        [jnp.asarray(init_pe, jnp.int32), jnp.asarray(init_kt, jnp.int32)],
        axis=-1)                                         # (N, 2)
    df = jnp.asarray(init_df, jnp.int32)                 # (N,) fixed in stage 2

    def mutate(genome, key):
        k1, k2 = jax.random.split(key)
        mask = jax.random.uniform(k1, genome.shape) < cfg.mutation_rate
        step = jnp.stack([
            jax.random.randint(k2, genome.shape[:-1],
                               -cfg.mutation_step, cfg.mutation_step + 1),
            jax.random.randint(jax.random.fold_in(k2, 1), genome.shape[:-1],
                               -1, 2)], axis=-1)
        out = jnp.where(mask, genome + step, genome)
        lo = jnp.array([dfl.PE_MIN, dfl.KT_MIN])
        hi = jnp.array([dfl.PE_MAX, dfl.KT_MAX])
        return jnp.clip(out, lo, hi)

    def self_crossover(genome, key):
        """Swap the (PE, Buf) pairs of two random layers (SIII-G)."""
        k1, k2, k3 = jax.random.split(key, 3)
        i = jax.random.randint(k1, (), 0, N)
        j = jax.random.randint(k2, (), 0, N)
        do = jax.random.uniform(k3) < cfg.crossover_rate
        gi, gj = genome[i], genome[j]
        swapped = genome.at[i].set(gj).at[j].set(gi)
        return jnp.where(do, swapped, genome)

    def gen_step(carry, _):
        pop, best_val, best_genome, key = carry
        pe = pop[..., 0].astype(jnp.float32)
        kt = pop[..., 1].astype(jnp.float32)
        fit = _fitness(env, ecfg, pe, kt, df)
        order = jnp.argsort(fit)
        pop, fit = pop[order], fit[order]
        better = fit[0] < best_val
        best_val = jnp.where(better, fit[0], best_val)
        best_genome = jnp.where(better, pop[0], best_genome)
        half = P // 2
        key, k1, k2, k3 = jax.random.split(key, 4)
        parents = pop[jax.random.randint(k1, (P - half,), 0, half)]
        children = jax.vmap(self_crossover)(
            parents, jax.random.split(k2, P - half))
        children = jax.vmap(mutate)(children, jax.random.split(k3, P - half))
        pop = jnp.concatenate([pop[:half], children], axis=0)
        return (pop, best_val, best_genome, key), best_val

    pop = jnp.broadcast_to(init_genome, (P, N, 2)).astype(jnp.int32)
    init = (pop, jnp.inf, init_genome, key)
    run = functools.partial(jax.lax.scan, gen_step, length=cfg.generations)
    (_, best_val, best_genome, _), hist = jax.jit(
        lambda init: run(init, None))(init)
    return GAResult(best_val,
                    best_genome[..., 0].astype(jnp.float32),
                    best_genome[..., 1].astype(jnp.float32),
                    df, hist, cfg.population * cfg.generations)
