"""The interactive environment (SIII-F): workload + constraints + objective.

The Env wraps the analytical cost model.  Everything is a device array so a
whole episode -- and in fact the whole multi-thousand-epoch search -- stays
inside one XLA program (DESIGN.md S3 "Env-in-the-graph").

Observation (Eq. 1): O_t = (K,C,Y,X,R,S,T, A^PE_{t-1}, A^Buf_{t-1}, t),
every dimension normalized to [-1, 1].  The static 7-dim layer part is
precomputed here; the dynamic 3 dims (previous actions + time) are appended
by the rollout.  The MIX agent appends the previous dataflow choice as an
11th dimension.

Platform constraints (Table II): budget = frac * C_max, where C_max is the
constraint consumption of the whole model under the uniform maximum action
pair (p_12th, b_12th) -- measured exactly as the paper measures it.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from repro.costmodel import dataflows as dfl
from repro.costmodel import maestro
from repro.costmodel.layers import NUM_FIELDS, layers_to_array

PLATFORM_FRACTIONS = {
    "unlimited": float("inf"),
    "cloud": 0.50,
    "iot": 0.10,
    "iotx": 0.05,
}

# "blend" is the scalarization objective for frontier sweeps: the whole-
# model value is total_lat**w * total_en**(1-w) (w = EnvConfig.blend_weight),
# i.e. a weighted sum in log space, so any single-objective engine can walk
# the latency/energy trade-off one weight at a time.  It is whole-model only:
# the per-layer RL reward path (``layer_cost``) rejects it.
OBJECTIVES = ("latency", "energy", "blend")
CONSTRAINTS = ("area", "power")


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    """Static (trace-time) environment configuration."""

    objective: str = "latency"
    constraint: str = "area"
    platform: str = "iot"
    scenario: str = "LP"
    dataflow: int = dfl.DLA    # ignored when mix=True
    mix: bool = False
    levels: int = 12
    blend_weight: float = 0.5  # only read when objective == "blend"

    def __post_init__(self):
        assert self.objective in OBJECTIVES
        assert self.constraint in CONSTRAINTS
        assert self.platform in PLATFORM_FRACTIONS
        assert self.scenario in ("LP", "LS")
        assert 0.0 <= self.blend_weight <= 1.0

    @property
    def obs_dim(self) -> int:
        return 11 if self.mix else 10


class EnvArrays(NamedTuple):
    """Device-array environment state (jit-traceable)."""

    layers: jnp.ndarray      # (N, NUM_FIELDS) f32
    static_obs: jnp.ndarray  # (N, 7) normalized layer observation
    pe_table: jnp.ndarray    # (L,) f32
    kt_table: jnp.ndarray    # (L,) f32
    budget: jnp.ndarray      # () f32 (inf for unlimited)

    @property
    def num_layers(self) -> int:
        return self.layers.shape[0]


def _normalize_obs(arr: np.ndarray) -> np.ndarray:
    """Per-model max-normalization of (K,C,Y,X,R,S,type) into [-1, 1]."""
    obs = arr[:, :7].astype(np.float64)
    maxes = np.maximum(obs.max(axis=0), 1.0)
    return (2.0 * obs / maxes - 1.0).astype(np.float32)


def max_constraint(layers_arr, cfg: EnvConfig) -> float:
    """C_max: whole-model consumption at the uniform max action (Table II)."""
    N = layers_arr.shape[0]
    pe_max = float(dfl.pe_levels(cfg.levels)[-1])
    kt_max = float(dfl.kt_levels(cfg.levels)[-1])
    df = cfg.dataflow if not cfg.mix else dfl.DLA
    out = maestro.model_cost(
        jnp.asarray(layers_arr, jnp.float32),
        jnp.full((N,), pe_max), jnp.full((N,), kt_max), df, cfg.scenario)
    val = out.area if cfg.constraint == "area" else out.power
    return float(val)


def make_env(workload, cfg: EnvConfig) -> EnvArrays:
    """Build the Env from a workload (list of LayerSpec or (N,8) array)."""
    if isinstance(workload, (list, tuple)):
        arr = layers_to_array(workload)
    else:
        arr = np.asarray(workload)
    assert arr.ndim == 2 and arr.shape[1] == NUM_FIELDS
    frac = PLATFORM_FRACTIONS[cfg.platform]
    budget = (np.float32(np.inf) if np.isinf(frac)
              else np.float32(frac * max_constraint(arr, cfg)))
    return EnvArrays(
        layers=jnp.asarray(arr, jnp.float32),
        static_obs=jnp.asarray(_normalize_obs(arr)),
        pe_table=jnp.asarray(dfl.pe_levels(cfg.levels), jnp.float32),
        kt_table=jnp.asarray(dfl.kt_levels(cfg.levels), jnp.float32),
        budget=jnp.asarray(budget),
    )


def layer_cost(env: EnvArrays, cfg: EnvConfig, t, pe, kt, df):
    """Per-layer (objective value, constraint consumption) at step t."""
    if cfg.objective == "blend":
        raise ValueError(
            "objective='blend' is a whole-model scalarization; the per-layer"
            " RL reward path cannot decompose it per step -- use a"
            " population/sampling method (random/grid/sa/ga/bo/relaxed) or"
            " the native multi-objective engine (nsga2) instead")
    out = maestro.evaluate(env.layers[t], pe, kt, df)
    perf = out.latency if cfg.objective == "latency" else out.energy
    cons = out.area if cfg.constraint == "area" else out.power
    return perf, cons


def select_objective(total_lat, total_en, cfg: EnvConfig):
    """Whole-model objective from the aggregated (latency, energy) pair."""
    if cfg.objective == "latency":
        return total_lat
    if cfg.objective == "energy":
        return total_en
    w = jnp.float32(cfg.blend_weight)
    return total_lat ** w * total_en ** (jnp.float32(1.0) - w)


def aggregate_costs_multi(lat, en, area, pw, cfg: EnvConfig, budget):
    """Per-layer costs (..., N) -> whole-model
    (total_lat, total_en, total_area, total_pw, feasible).

    THE one definition of the aggregation semantics -- objectives summed
    over layers, constraints summed (LP: one partition per layer) or maxed
    (LS: one shared design), feasible iff the configured constraint metric
    fits the platform budget -- shared by :func:`genome_cost`, the GA's
    Pallas-kernel fitness path, the NSGA-II engine and the serving batcher,
    so none of them can drift apart.  ``aggregate_costs`` below is the
    scalar-objective view of this same definition.
    """
    total_lat = jnp.sum(lat, axis=-1)
    total_en = jnp.sum(en, axis=-1)
    if cfg.scenario == "LP":
        total_area = jnp.sum(area, axis=-1)
        total_pw = jnp.sum(pw, axis=-1)
    else:
        total_area = jnp.max(area, axis=-1)
        total_pw = jnp.max(pw, axis=-1)
    total_cons = total_area if cfg.constraint == "area" else total_pw
    return total_lat, total_en, total_area, total_pw, total_cons <= budget


def aggregate_costs(lat, en, area, pw, cfg: EnvConfig, budget):
    """Per-layer costs (..., N) -> whole-model (objective, constraint,
    feasible): the single-objective view of :func:`aggregate_costs_multi`
    (bit-identical to the pre-frontier definition -- the same jnp
    reductions over the same arrays; XLA prunes the unselected metric)."""
    tl, te, ta, tp, feas = aggregate_costs_multi(lat, en, area, pw, cfg,
                                                 budget)
    total_perf = select_objective(tl, te, cfg)
    total_cons = ta if cfg.constraint == "area" else tp
    return total_perf, total_cons, feas


def genome_cost(env: EnvArrays, cfg: EnvConfig, pe, kt, df):
    """Whole-model (objective, constraint, feasible) for per-layer arrays.

    pe/kt: (..., N) raw values;  df: scalar or (..., N).
    LP: constraint = sum over layers; LS: constraint = max over layers.
    """
    out = maestro.evaluate(env.layers, pe, kt, df)
    return aggregate_costs(out.latency, out.energy, out.area, out.power,
                           cfg, env.budget)


def genome_costs_multi(env: EnvArrays, cfg: EnvConfig, pe, kt, df):
    """Whole-model (total_lat, total_en, total_area, total_pw, feasible)
    for per-layer arrays -- the multi-objective sibling of
    :func:`genome_cost` (same model eval, same reductions)."""
    out = maestro.evaluate(env.layers, pe, kt, df)
    return aggregate_costs_multi(out.latency, out.energy, out.area,
                                 out.power, cfg, env.budget)


def feasibility_mask(env: EnvArrays, cfg: EnvConfig, pe, kt, df):
    """First-class feasibility of per-layer assignments: (...,) bool True
    where the aggregated platform constraint (Table II) fits the budget.

    This is the mask every optimizer's reported ``best`` must satisfy
    (enforced registry-wide by tests/test_optimizer_conformance.py):
    infeasible candidates are never reported as best, they surface only as
    the paper's "NAN" (best_value = +inf, feasible=False).
    """
    return genome_costs_multi(env, cfg, pe, kt, df)[4]


def action_tables(cfg: EnvConfig) -> Sequence[np.ndarray]:
    return dfl.pe_levels(cfg.levels), dfl.kt_levels(cfg.levels)
