"""NSGA-II: constrained multi-objective search over (latency, energy).

ConfuciuX optimizes latency *or* energy under a hard area/power budget
(Table II); this engine searches the latency-energy *trade-off curve* in
one run instead of one scalarized point per run.  Same genome space as the
baseline GA -- per-layer (PE, Buf) level indices plus the dataflow gene for
MIX -- with NSGA-II's selection machinery (Deb et al. 2002):

  * **constrained dominance**: any lower-violation point dominates a
    higher-violation one; at equal violation (in particular 0 == feasible
    vs feasible) Pareto dominance on (total latency, total energy) decides.
    Budgets are first-class feasibility masks
    (:func:`repro.core.env.aggregate_costs_multi`), not reward penalties.
  * **non-dominated sorting** via a vectorized (M, M) dominance matrix and
    front peeling inside ``lax.fori_loop`` -- the whole generation is one
    XLA program, like every other engine here.
  * **crowding distance** computed with same-front masks (no data-dependent
    sort), boundary points at +inf, used for survival truncation and binary
    tournaments.
  * a fixed-capacity **Pareto archive** rides in the scan carry: every
    evaluated feasible point competes for one of ``archive`` slots
    (non-dominated filter + objective-space dedup + one-shot crowding
    truncation), so the frontier is available at every chunk boundary
    without host round-trips.  While the archive is below capacity its
    hypervolume is monotone non-decreasing in evals (no point is ever
    dropped except by a dominating one); at capacity, crowding truncation
    may trade boundary-interior points and the guarantee becomes
    approximate -- size ``archive`` generously.

The engine fills the :class:`repro.core.ga.GAEngine` contract with a
(P, 4) multi-cost fitness, so :func:`repro.core.ga.run_chunked_engine`
drives it unchanged: chunked, resumable, cancellable, and ``eval_fn``-
injectable (the search service routes whole populations through the
cross-request :class:`~repro.serving.batcher.CostEvalBatcher`; outcomes
are byte-identical to the in-graph path).

The pure-numpy Pareto helpers (``non_dominated_mask``, ``pareto_insert``,
``hypervolume_2d``) are the reference semantics the property tests in
tests/test_pareto_properties.py pin the engine against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as env_lib
from repro.core import ga as ga_lib
from repro.costmodel import maestro

_BIG = jnp.float32(1e30)   # finite stand-in for +inf crowding in sort keys


# ---------------------------------------------------------------------------
# Pure Pareto helpers (numpy reference semantics; minimization throughout).
# ---------------------------------------------------------------------------
def pareto_dominates(a, b) -> bool:
    """True iff point ``a`` Pareto-dominates ``b`` (<= everywhere, < once)."""
    a = np.asarray(a, float)
    b = np.asarray(b, float)
    return bool(np.all(a <= b) and np.any(a < b))


def non_dominated_mask(costs) -> np.ndarray:
    """(M, k) cost points -> (M,) bool mask of the non-dominated subset."""
    c = np.asarray(costs, float)
    if c.size == 0:
        return np.zeros((0,), bool)
    le = np.all(c[:, None, :] <= c[None, :, :], axis=-1)
    lt = np.any(c[:, None, :] < c[None, :, :], axis=-1)
    dom = le & lt                 # dom[i, j]: i dominates j
    return ~dom.any(axis=0)


def pareto_insert(front, point):
    """Insert ``point`` into a non-dominated ``front`` (list of points).

    Returns the new front: unchanged (same points) when ``point`` is
    dominated by -- or equal to -- a member; otherwise ``point`` joins and
    every member it dominates leaves.  A dominated insertion therefore
    never grows the front (property-tested).
    """
    pt = np.asarray(point, float)
    front = [np.asarray(p, float) for p in front]
    for p in front:
        if np.array_equal(p, pt) or pareto_dominates(p, pt):
            return front
    return [p for p in front if not pareto_dominates(pt, p)] + [pt]


def hypervolume_2d(points, ref) -> float:
    """Dominated hypervolume of 2-D minimization points w.r.t. ``ref``.

    Points not strictly dominating the reference point contribute nothing.
    Monotone under set union: adding points never decreases it.
    """
    ref = np.asarray(ref, float)
    pts = np.asarray(points, float).reshape(-1, 2)
    pts = pts[np.all(np.isfinite(pts), axis=1)]
    pts = pts[np.all(pts < ref, axis=1)]
    if len(pts) == 0:
        return 0.0
    pts = pts[non_dominated_mask(pts)]
    order = np.argsort(pts[:, 0], kind="stable")
    pts = pts[order]                      # x ascending => y descending
    hv = 0.0
    for i, (x, y) in enumerate(pts):
        x_next = pts[i + 1, 0] if i + 1 < len(pts) else ref[0]
        hv += (x_next - x) * (ref[1] - y)
    return float(hv)


# ---------------------------------------------------------------------------
# Jitted selection machinery (shapes are static; everything scans).
# ---------------------------------------------------------------------------
def _violation(costs, cons_col: int, budget):
    """(M, 4) aggregated costs -> (M,) constraint violation (0 = feasible)."""
    cons = costs[:, cons_col]
    return jnp.where(cons <= budget, jnp.float32(0.0), cons - budget)


def _constrained_dominance(costs, viol):
    """(M, 4) costs + (M,) violation -> (M, M) bool [i, j]: i dominates j.

    Deb's constrained dominance: strictly smaller violation dominates;
    equal violation (both feasible included) falls back to Pareto dominance
    on the (latency, energy) objective pair.
    """
    obj = costs[:, :2]
    le = jnp.all(obj[:, None, :] <= obj[None, :, :], axis=-1)
    lt = jnp.any(obj[:, None, :] < obj[None, :, :], axis=-1)
    pdom = le & lt
    v_lt = viol[:, None] < viol[None, :]
    v_eq = viol[:, None] == viol[None, :]
    return v_lt | (v_eq & pdom)


def _front_ranks(dom):
    """(M, M) dominance matrix -> (M,) front index (0 = non-dominated)."""
    M = dom.shape[0]
    big = jnp.int32(M + 1)
    n_dom = jnp.sum(dom, axis=0).astype(jnp.int32)

    def body(r, carry):
        rank, rem = carry
        front = (rem == 0) & (rank == big)
        rank = jnp.where(front, jnp.int32(r), rank)
        freed = jnp.sum(jnp.where(front[:, None], dom, False),
                        axis=0).astype(jnp.int32)
        rem = jnp.where(front, big, rem - freed)
        return rank, rem

    rank, _ = jax.lax.fori_loop(
        0, M, body, (jnp.full((M,), M + 1, jnp.int32), n_dom))
    return rank


def _crowding(obj, rank):
    """(M, 2) objectives + (M,) front ranks -> (M,) crowding distance.

    Mask-based (no data-dependent sort): a point's gap along one objective
    is (nearest strictly-larger value) - (nearest strictly-smaller value)
    within its front, normalized by the front's span; front boundary points
    get +inf.  Deterministic under ties by construction.
    """
    same = rank[:, None] == rank[None, :]
    d = jnp.zeros(obj.shape[0], jnp.float32)
    for k in range(obj.shape[1]):
        v = obj[:, k]
        vmax = jnp.max(jnp.where(same, v[None, :], -jnp.inf), axis=1)
        vmin = jnp.min(jnp.where(same, v[None, :], jnp.inf), axis=1)
        span = jnp.maximum(vmax - vmin, jnp.float32(1e-12))
        gt = same & (v[None, :] > v[:, None])
        lt = same & (v[None, :] < v[:, None])
        upper = jnp.min(jnp.where(gt, v[None, :], jnp.inf), axis=1)
        lower = jnp.max(jnp.where(lt, v[None, :], -jnp.inf), axis=1)
        interior = jnp.isfinite(upper) & jnp.isfinite(lower)
        gap = jnp.where(interior, (upper - lower) / span, jnp.inf)
        d = d + gap
    return d


def _select_best(rank, crowd, n):
    """Indices of the n best by (rank asc, crowding desc, index asc)."""
    crowd_f = jnp.where(jnp.isfinite(crowd), crowd, _BIG)
    return jnp.lexsort((-crowd_f, rank))[:n]


def _tournament(key, rank, crowd, n, pool_size):
    """(n,) winner indices of binary tournaments on (rank, crowding)."""
    k1, k2 = jax.random.split(key)
    i = jax.random.randint(k1, (n,), 0, pool_size)
    j = jax.random.randint(k2, (n,), 0, pool_size)
    crowd_f = jnp.where(jnp.isfinite(crowd), crowd, _BIG)
    ci, cj = crowd_f[i], crowd_f[j]
    ri, rj = rank[i], rank[j]
    i_wins = (ri < rj) | ((ri == rj) & (ci > cj)) | \
        ((ri == rj) & (ci == cj) & (i <= j))
    return jnp.where(i_wins, i, j)


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NSGA2Config:
    population: int = 64
    generations: int = 50
    mutation_rate: float = 0.05
    crossover_rate: float = 0.5   # per-gene uniform-crossover swap prob
    archive: int = 128            # Pareto-archive capacity (frontier slots)
    seed: int = 0
    # None = auto: the Pallas batched cost kernel on TPU, the jnp oracle
    # elsewhere (same policy as GAConfig).
    use_kernel: Optional[bool] = None


class NSGA2State(NamedTuple):
    """Scan carry: everything a resumed run needs.

    ``pop`` leads (like :class:`~repro.core.ga.GAState`) so the shared
    chunk driver's host-eval loop decodes the right field: it holds the
    *candidates awaiting evaluation*; ``parents``/``parent_costs`` hold the
    current survivors (cost sentinel +inf before the first generation --
    sentinels lose every constrained-dominance comparison against any
    evaluated point, so the first survival keeps exactly the first
    evaluated population).
    """

    pop: jnp.ndarray            # (P, N, genes) int32 candidates to evaluate
    parents: jnp.ndarray        # (P, N, genes) int32 current survivors
    parent_costs: jnp.ndarray   # (P, 4) f32 (lat, en, area, pw) aggregated
    best_val: jnp.ndarray       # () f32 best feasible primary objective
    best_genome: jnp.ndarray    # (N, genes) int32
    arch_genomes: jnp.ndarray   # (A, N, genes) int32 Pareto archive
    arch_costs: jnp.ndarray     # (A, 4) f32; +inf latency = empty slot
    key: jnp.ndarray
    generation: jnp.ndarray     # () int32 generations completed


def _multi_costs(env, ecfg, pe, kt, df, use_kernel: bool = False):
    """(..., N) raw assignment -> (..., 4) aggregated whole-model costs.

    The oracle path evaluates through FLAT per-point rows (layer fields
    materialized per point) rather than broadcasting the (N, F) layer table
    against (..., N) assignments: with the broadcast shape XLA hoists
    layer-only subexpressions and reassociates the f32 products, drifting
    an ulp from the serving batcher's flat per-point evaluation.  The flat
    shape is bit-stable across batch sizes, which is what keeps serial
    nsga2 byte-identical to service-batched nsga2 (asserted by
    benchmarks/bench_frontier.py and tests/test_nsga2.py).
    """
    if use_kernel and getattr(pe, "ndim", 0) == 2:
        from repro.kernels import ops
        lat, en, area, pw = ops.batched_cost(env.layers, pe, kt, df)
    else:
        F = env.layers.shape[-1]
        df = jnp.broadcast_to(jnp.asarray(df, jnp.float32), pe.shape)
        flat = jnp.broadcast_to(env.layers, pe.shape + (F,)).reshape(-1, F)
        out = maestro.evaluate(flat, pe.reshape(-1), kt.reshape(-1),
                               df.reshape(-1))
        lat, en, area, pw = jax.lax.optimization_barrier(
            tuple(a.reshape(pe.shape) for a in
                  (out.latency, out.energy, out.area, out.power)))
    tl, te, ta, tp, _ = env_lib.aggregate_costs_multi(
        lat, en, area, pw, ecfg, env.budget)
    return jnp.stack([tl, te, ta, tp], axis=-1)


def make_nsga2_engine(env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
                      cfg: NSGA2Config) -> ga_lib.GAEngine:
    """NSGA-II as a :class:`~repro.core.ga.GAEngine`: same contract, (P, 4)
    fitness.  ``run_chunked_engine`` drives it exactly like the GAs."""
    N = env.num_layers
    P = cfg.population
    A = cfg.archive
    L = ecfg.levels
    n_df = 3 if ecfg.mix else 1
    genes = 3 if ecfg.mix else 2
    cons_col = 2 if ecfg.constraint == "area" else 3
    use_kernel = (cfg.use_kernel if cfg.use_kernel is not None
                  else jax.default_backend() == "tpu")

    def decode(genome):
        pe = env.pe_table[genome[..., 0]]
        kt = env.kt_table[genome[..., 1]]
        df = (genome[..., 2] if ecfg.mix
              else jnp.asarray(ecfg.dataflow, jnp.int32))
        return pe, kt, df

    def fitness(pop):
        pe, kt, df = decode(pop)
        return _multi_costs(env, ecfg, pe, kt, df, use_kernel)   # (P, 4)

    def _update_archive(arch_genomes, arch_costs, pop, fit):
        """Archive ∪ newly evaluated pop -> non-dominated feasible top-A."""
        pool_g = jnp.concatenate([arch_genomes, pop], axis=0)    # (A+P,...)
        pool_c = jnp.concatenate([arch_costs, fit], axis=0)      # (A+P, 4)
        viol = _violation(pool_c, cons_col, env.budget)
        valid = (viol == 0) & jnp.isfinite(pool_c[:, 0])
        obj = jnp.where(valid[:, None], pool_c[:, :2], jnp.inf)
        le = jnp.all(obj[:, None, :] <= obj[None, :, :], axis=-1)
        lt = jnp.any(obj[:, None, :] < obj[None, :, :], axis=-1)
        dominated = jnp.any(le & lt & valid[:, None], axis=0)
        # Dedup identical objective pairs (keep the lowest index).
        idx = jnp.arange(obj.shape[0])
        eq = jnp.all(obj[:, None, :] == obj[None, :, :], axis=-1)
        dup = jnp.any(eq & (idx[None, :] < idx[:, None]), axis=1)
        keep = valid & ~dominated & ~dup
        # One-shot crowding truncation to A slots (rank 0 = the keepers).
        crowd = _crowding(obj, jnp.where(keep, 0, 1).astype(jnp.int32))
        crowd_f = jnp.where(jnp.isfinite(crowd), crowd, _BIG)
        score = jnp.where(keep, -crowd_f, jnp.inf)
        sel = jnp.argsort(score)[:A]
        kept = keep[sel]
        new_g = jnp.where(kept[:, None, None], pool_g[sel], 0)
        new_c = jnp.where(kept[:, None], pool_c[sel], jnp.inf)
        return new_g.astype(jnp.int32), new_c

    def evolve(state: NSGA2State, fit):
        (pop, parents, parent_costs, best_val, best_genome,
         arch_genomes, arch_costs, key, gen) = state
        # 1. Environmental selection over parents ∪ evaluated children.
        cand = jnp.concatenate([parents, pop], axis=0)           # (2P,...)
        costs = jnp.concatenate([parent_costs, fit], axis=0)     # (2P, 4)
        viol = _violation(costs, cons_col, env.budget)
        rank = _front_ranks(_constrained_dominance(costs, viol))
        crowd = _crowding(costs[:, :2], rank)
        sel = _select_best(rank, crowd, P)
        parents = cand[sel]
        parent_costs = costs[sel]
        # 2. Scalar best-so-far (the unified history/best_value contract:
        #    the env's primary objective over feasible points only).
        child_viol = _violation(fit, cons_col, env.budget)
        child_obj = env_lib.select_objective(fit[:, 0], fit[:, 1], ecfg)
        child_val = jnp.where(child_viol == 0, child_obj, jnp.inf)
        i_best = jnp.argmin(child_val)
        better = child_val[i_best] < best_val
        best_val = jnp.where(better, child_val[i_best], best_val)
        best_genome = jnp.where(better, pop[i_best], best_genome)
        # 3. Pareto archive update from the newly evaluated points.
        arch_genomes, arch_costs = _update_archive(
            arch_genomes, arch_costs, pop, fit)
        # 4. Breed the next candidate population by binary tournament on
        #    the survivors' (rank, crowding), uniform crossover, mutation.
        key, k1, k2, k3, k4, k5 = jax.random.split(key, 6)
        rank_p, crowd_p = rank[sel], crowd[sel]
        pa = _tournament(k1, rank_p, crowd_p, P, P)
        pb = _tournament(k2, rank_p, crowd_p, P, P)
        cx = jax.random.uniform(k3, (P, N, genes)) < cfg.crossover_rate
        children = jnp.where(cx, parents[pb], parents[pa])
        mut = jax.random.uniform(k4, children.shape) < cfg.mutation_rate
        rand = jax.random.randint(k5, children.shape, 0, L)
        if ecfg.mix:
            rand = rand.at[..., 2].set(
                jax.random.randint(jax.random.fold_in(k5, 1),
                                   children.shape[:-1], 0, n_df))
        children = jnp.where(mut, rand, children)
        return NSGA2State(children, parents, parent_costs, best_val,
                          best_genome, arch_genomes, arch_costs, key,
                          gen + 1), best_val

    def gen_step(carry: NSGA2State, _):
        # The barrier pins each generation's arithmetic: XLA unrolls short
        # scans and would otherwise fuse across iterations, so a chunk=1
        # run could drift an ulp from a one-shot run of the same seed.
        state, best = evolve(carry, fitness(carry.pop))
        return jax.lax.optimization_barrier(state), best

    def init_carry(seed) -> NSGA2State:
        key = jax.random.PRNGKey(seed)
        key, k0 = jax.random.split(key)
        pop = jax.random.randint(k0, (P, N, genes), 0, L)
        if ecfg.mix:
            pop = pop.at[..., 2].set(
                jax.random.randint(jax.random.fold_in(k0, 7), (P, N), 0,
                                   n_df))
        return NSGA2State(
            pop=pop,
            parents=jnp.zeros((P, N, genes), jnp.int32),
            parent_costs=jnp.full((P, 4), jnp.inf, jnp.float32),
            best_val=jnp.float32(jnp.inf),
            best_genome=jnp.zeros((N, genes), jnp.int32),
            arch_genomes=jnp.zeros((A, N, genes), jnp.int32),
            arch_costs=jnp.full((A, 4), jnp.inf, jnp.float32),
            key=key,
            generation=jnp.zeros((), jnp.int32))

    return ga_lib.GAEngine(init_carry, gen_step, decode, fitness, evolve)


def run_nsga2_search(workload, ecfg: env_lib.EnvConfig,
                     cfg: NSGA2Config = NSGA2Config(),
                     state: Optional[NSGA2State] = None,
                     chunk: Optional[int] = None,
                     on_chunk=None,
                     eval_fn=None,
                     env: Optional[env_lib.EnvArrays] = None):
    """Chunked, resumable NSGA-II.  Returns (NSGA2State, (gens,) history).

    Same lifecycle as :func:`repro.core.ga.run_ga_search`: runs
    ``cfg.generations`` *more* generations from ``state`` (fresh when
    None) in ``chunk``-sized pieces, firing ``on_chunk(state, hist,
    gens_done)`` between them; ``eval_fn(pe, kt, df) -> (P, 4) aggregated
    costs`` moves fitness evaluation to the host (the search service
    injects its cross-request batcher).  Chunk boundaries and the eval
    path never change the result -- byte-identical states/histories.
    """
    if env is None:
        env = env_lib.make_env(workload, ecfg)
    engine = make_nsga2_engine(env, ecfg, cfg)
    if state is None:
        state = engine.init_carry(cfg.seed)
    return ga_lib.run_chunked_engine(env, ecfg, engine, state,
                                     cfg.generations, chunk, on_chunk,
                                     eval_fn, mix_df=ecfg.mix,
                                     engine_name="nsga2")


def frontier_points(state: NSGA2State) -> np.ndarray:
    """The archive's live frontier as an (F, 4) float array sorted by
    latency (the per-chunk snapshot the outcome's frontier trace records)."""
    costs = np.asarray(state.arch_costs, np.float64)
    costs = costs[np.isfinite(costs[:, 0])]
    return costs[np.argsort(costs[:, 0], kind="stable")]


def nsga2_frontier(env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
                   state: NSGA2State) -> Dict[str, np.ndarray]:
    """Decode the final archive: the non-dominated feasible designs.

    Returns arrays sorted by latency -- ``lat``/``en``/``area``/``pw`` of
    shape (F,) plus the raw per-layer assignments ``pe``/``kt``/``df`` of
    shape (F, N) that realize each point.
    """
    costs = np.asarray(state.arch_costs, np.float64)
    genomes = np.asarray(state.arch_genomes)
    valid = np.isfinite(costs[:, 0])
    costs, genomes = costs[valid], genomes[valid]
    order = np.argsort(costs[:, 0], kind="stable")
    costs, genomes = costs[order], genomes[order]
    pe = np.asarray(env.pe_table, np.float32)[genomes[..., 0]]
    kt = np.asarray(env.kt_table, np.float32)[genomes[..., 1]]
    if ecfg.mix:
        df = genomes[..., 2].astype(np.int32)
    else:
        df = np.full(genomes.shape[:2], ecfg.dataflow, np.int32)
    return {"lat": costs[:, 0], "en": costs[:, 1], "area": costs[:, 2],
            "pw": costs[:, 3], "pe": pe, "kt": kt, "df": df}


def nsga2_solution(env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
                   state: NSGA2State):
    """Decode the best-primary-objective genome to raw (pe, kt, df)."""
    return ga_lib.ga_solution(env, ecfg, state)
