"""Classic optimization baselines (SII-E / SIV-A3): grid, random, simulated
annealing, Bayesian optimization.

All report the best *feasible* whole-model objective after a fixed sample
budget Eps (the paper uses Eps = 5000 "epochs"; one epoch = one whole-model
evaluation for these methods), or +inf ("NAN" in the paper's tables) if no
feasible point was found -- exactly how Table IV reports failures.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as env_lib


class BaselineResult(NamedTuple):
    best_value: float
    best_pe: np.ndarray
    best_kt: np.ndarray
    history: np.ndarray      # best-so-far per evaluation (Eps,)
    evals: int


def _decode_and_eval(env, ecfg, genome):
    """genome: (..., N, 2) int levels -> (objective-or-inf)."""
    pe = env.pe_table[genome[..., 0]]
    kt = env.kt_table[genome[..., 1]]
    perf, cons, feas = env_lib.genome_cost(env, ecfg, pe, kt, ecfg.dataflow)
    return jnp.where(feas, perf, jnp.inf), pe, kt


def _eval_batch_fn(env, ecfg, eval_fn):
    """The genome-batch evaluator the host-loop baselines iterate on.

    ``eval_fn(genomes (b, N, 2) int levels) -> (fit (b,), pe (b, N),
    kt (b, N))`` overrides the built-in jitted evaluator -- the search
    service injects its cross-request batcher here; results must be
    bit-identical to the default path (see repro.serving.batcher).
    """
    if eval_fn is not None:
        return eval_fn
    return jax.jit(lambda g: _decode_and_eval(env, ecfg, g))


# ---------------------------------------------------------------------------
def random_search(workload, ecfg: env_lib.EnvConfig, eps: int = 5000,
                  seed: int = 0, batch: int = 512,
                  eval_fn=None) -> BaselineResult:
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    key = jax.random.PRNGKey(seed)
    best, best_pe, best_kt = np.inf, None, None
    hist = []
    eval_b = _eval_batch_fn(env, ecfg, eval_fn)
    done = 0
    while done < eps:
        n = min(batch, eps - done)
        key, k = jax.random.split(key)
        genomes = jax.random.randint(k, (n, N, 2), 0, ecfg.levels)
        fit, pe, kt = eval_b(genomes)
        fit = np.asarray(fit)
        # Seed the trace with the best *before* this batch so no sample is
        # credited ahead of being drawn (keeps convergence plots honest).
        hist.append(np.minimum(np.minimum.accumulate(fit), best))
        i = int(fit.argmin())
        if fit[i] < best:
            best, best_pe, best_kt = float(fit[i]), np.asarray(pe[i]), \
                np.asarray(kt[i])
        done += n
    return BaselineResult(best, best_pe, best_kt, np.concatenate(hist), eps)


# ---------------------------------------------------------------------------
def grid_search(workload, ecfg: env_lib.EnvConfig, eps: int = 5000,
                stride: int = 1, batch: int = 512,
                eval_fn=None) -> BaselineResult:
    """Lexicographic sweep with stride over the per-layer level space.

    For an N-layer model the space is L^(2N); Eps samples only scratch the
    first couple of genes (everything else pinned at level 0), which is why
    grid search performs so poorly in Table IV -- reproduced faithfully.
    """
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    base = int(np.ceil(ecfg.levels / stride))
    eval_b = _eval_batch_fn(env, ecfg, eval_fn)
    best, best_pe, best_kt = np.inf, None, None
    hist = []
    done = 0
    while done < eps:
        n = min(batch, eps - done)
        idx = np.arange(done, done + n, dtype=np.int64)
        digits = np.zeros((n, 2 * N), dtype=np.int32)
        rem = idx.copy()
        for d in range(2 * N):          # last gene varies fastest
            digits[:, 2 * N - 1 - d] = (rem % base) * stride
            rem //= base
            if not rem.any():
                break
        genomes = np.minimum(digits.reshape(n, N, 2), ecfg.levels - 1)
        fit, pe, kt = eval_b(jnp.asarray(genomes))
        fit = np.asarray(fit)
        hist.append(np.minimum(np.minimum.accumulate(fit), best))
        i = int(fit.argmin())
        if fit[i] < best:
            best, best_pe, best_kt = float(fit[i]), np.asarray(pe[i]), \
                np.asarray(kt[i])
        done += n
    return BaselineResult(best, best_pe, best_kt, np.concatenate(hist), eps)


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SAConfig:
    temperature: float = 10.0   # the paper's setting
    step: int = 1
    decay: float = 0.999
    seed: int = 0


def simulated_annealing(workload, ecfg: env_lib.EnvConfig, eps: int = 5000,
                        cfg: SAConfig = SAConfig()) -> BaselineResult:
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    L = ecfg.levels

    def eval_one(genome):
        fit, pe, kt = _decode_and_eval(env, ecfg, genome[None])
        return fit[0]

    def step_fn(carry, _):
        genome, cur_fit, best_fit, best_genome, T, key = carry
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        i = jax.random.randint(k1, (), 0, N)
        j = jax.random.randint(k2, (), 0, 2)
        delta = jnp.where(jax.random.uniform(k3) < 0.5, -cfg.step, cfg.step)
        cand = genome.at[i, j].set(jnp.clip(genome[i, j] + delta, 0, L - 1))
        cand_fit = eval_one(cand)
        # Metropolis on finite fitness; +inf candidates only accepted if the
        # current point is also infeasible (pure exploration).
        d = cand_fit - cur_fit
        accept_prob = jnp.where(d <= 0, 1.0, jnp.exp(-jnp.minimum(
            d / jnp.maximum(cur_fit, 1.0) * 100.0 / T, 50.0)))
        accept_prob = jnp.where(jnp.isnan(accept_prob),
                                jnp.where(jnp.isinf(cur_fit), 1.0, 0.0),
                                accept_prob)
        take = jax.random.uniform(k4) < accept_prob
        genome = jnp.where(take, cand, genome)
        cur_fit = jnp.where(take, cand_fit, cur_fit)
        better = cand_fit < best_fit
        best_fit = jnp.where(better, cand_fit, best_fit)
        best_genome = jnp.where(better, cand, best_genome)
        return (genome, cur_fit, best_fit, best_genome, T * cfg.decay,
                key), best_fit

    key = jax.random.PRNGKey(cfg.seed)
    key, k0 = jax.random.split(key)
    genome = jax.random.randint(k0, (N, 2), 0, L)
    cur = eval_one(genome)
    init = (genome, cur, cur, genome, jnp.float32(cfg.temperature), key)
    (g, _, best_fit, best_genome, _, _), hist = jax.jit(
        lambda c: jax.lax.scan(step_fn, c, None, length=eps))(init)
    pe = np.asarray(env.pe_table)[np.asarray(best_genome[:, 0])]
    kt = np.asarray(env.kt_table)[np.asarray(best_genome[:, 1])]
    return BaselineResult(float(best_fit), pe, kt, np.asarray(hist), eps)


# ---------------------------------------------------------------------------
def bayes_opt(workload, ecfg: env_lib.EnvConfig, eps: int = 5000,
              seed: int = 0, n_candidates: int = 64, gamma: float = 0.15,
              init_random: int = 64, batch: int = 16,
              eval_fn=None) -> BaselineResult:
    """Tree-Parzen-Estimator Bayesian optimization (surrogate + acquisition).

    The paper uses a GP-based BO [54]; a GP over a 2N-dim discrete space with
    5000 observations is O(n^3)-infeasible here, so we use the standard TPE
    formulation (per-dimension categorical good/bad densities, expected-
    improvement-equivalent l/g acquisition).  Same interface and failure
    mode: under IoTx the surrogate never observes a feasible point and the
    result is NAN, as in Table IV.
    """
    rng = np.random.default_rng(seed)
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    L = ecfg.levels
    eval_b = _eval_batch_fn(env, ecfg, eval_fn)

    X = rng.integers(0, L, size=(init_random, N, 2)).astype(np.int32)
    fit, pe_all, kt_all = eval_b(jnp.asarray(X))
    y = np.asarray(fit, dtype=np.float64)
    hist = list(np.minimum.accumulate(np.where(np.isinf(y), np.inf, y)))

    while len(y) < eps:
        finite = np.isfinite(y)
        # Rank: feasible by value, infeasible last.
        order = np.argsort(np.where(finite, y, np.inf))
        n_good = max(4, int(gamma * len(y)))
        good = X[order[:n_good]]
        # Per-dimension categorical densities with Laplace smoothing.
        counts = np.ones((N, 2, L))
        for g in good:
            for d in range(2):
                counts[np.arange(N), d, g[:, d]] += 1.0
        pg = counts / counts.sum(-1, keepdims=True)
        counts_all = np.ones((N, 2, L))
        for g in X[order[n_good:]][: 4 * n_good]:
            for d in range(2):
                counts_all[np.arange(N), d, g[:, d]] += 1.0
        pb = counts_all / counts_all.sum(-1, keepdims=True)

        # Sample candidates from l(x), score by l/g, evaluate the best few.
        cand = np.zeros((n_candidates, N, 2), dtype=np.int32)
        for d in range(2):
            cum = pg[:, d].cumsum(-1)
            u = rng.random((n_candidates, N, 1))
            cand[:, :, d] = (u > cum[None]).sum(-1)
        li = np.take_along_axis(
            pg[None], cand.transpose(0, 1, 2)[..., None], axis=-1)
        gi = np.take_along_axis(
            pb[None], cand.transpose(0, 1, 2)[..., None], axis=-1)
        score = np.log(li + 1e-12).sum((1, 2, 3)) - np.log(
            gi + 1e-12).sum((1, 2, 3))
        pick = cand[np.argsort(-score)[:batch]]
        fit, _, _ = eval_b(jnp.asarray(pick))
        fit = np.asarray(fit, dtype=np.float64)
        X = np.concatenate([X, pick], axis=0)
        y = np.concatenate([y, fit])
        prev_best = hist[-1] if hist else np.inf
        hist.extend(np.minimum(
            np.minimum.accumulate(fit), prev_best).tolist())

    i = int(np.argmin(np.where(np.isfinite(y), y, np.inf)))
    best = float(y[i]) if np.isfinite(y[i]) else float("inf")
    pe = np.asarray(env.pe_table)[X[i, :, 0]]
    kt = np.asarray(env.kt_table)[X[i, :, 1]]
    return BaselineResult(best, pe, kt, np.asarray(hist[:eps]), eps)
