"""Classic optimization baselines (SII-E / SIV-A3): grid, random, simulated
annealing, Bayesian optimization.

All report the best *feasible* whole-model objective after a fixed sample
budget Eps (the paper uses Eps = 5000 "epochs"; one epoch = one whole-model
evaluation for these methods), or +inf ("NAN" in the paper's tables) if no
feasible point was found -- exactly how Table IV reports failures.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunk as chunk_lib
from repro.core import env as env_lib
from repro.obs import instrument as obs_instrument


class BaselineResult(NamedTuple):
    best_value: float
    best_pe: np.ndarray
    best_kt: np.ndarray
    history: np.ndarray      # best-so-far per evaluation (Eps,)
    evals: int


def _decode_and_eval(env, ecfg, genome):
    """genome: (..., N, 2) int levels -> (objective-or-inf)."""
    pe = env.pe_table[genome[..., 0]]
    kt = env.kt_table[genome[..., 1]]
    perf, cons, feas = env_lib.genome_cost(env, ecfg, pe, kt, ecfg.dataflow)
    return jnp.where(feas, perf, jnp.inf), pe, kt


def _eval_batch_fn(env, ecfg, eval_fn):
    """The genome-batch evaluator the host-loop baselines iterate on.

    ``eval_fn(genomes (b, N, 2) int levels) -> (fit (b,), pe (b, N),
    kt (b, N))`` overrides the built-in jitted evaluator -- the search
    service injects its cross-request batcher here; results must be
    bit-identical to the default path (see repro.serving.batcher).
    """
    if eval_fn is not None:
        return eval_fn
    return jax.jit(lambda g: _decode_and_eval(env, ecfg, g))


# ---------------------------------------------------------------------------
def random_search(workload, ecfg: env_lib.EnvConfig, eps: int = 5000,
                  seed: int = 0, batch: int = 512,
                  eval_fn=None) -> BaselineResult:
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    key = jax.random.PRNGKey(seed)
    best, best_pe, best_kt = np.inf, None, None
    hist = []
    eval_b = _eval_batch_fn(env, ecfg, eval_fn)
    done = 0
    while done < eps:
        n = min(batch, eps - done)
        key, k = jax.random.split(key)
        genomes = jax.random.randint(k, (n, N, 2), 0, ecfg.levels)
        fit, pe, kt = eval_b(genomes)
        obs_instrument.hard_evals("random", n)
        fit = np.asarray(fit)
        # Seed the trace with the best *before* this batch so no sample is
        # credited ahead of being drawn (keeps convergence plots honest).
        hist.append(np.minimum(np.minimum.accumulate(fit), best))
        i = int(fit.argmin())
        if fit[i] < best:
            best, best_pe, best_kt = float(fit[i]), np.asarray(pe[i]), \
                np.asarray(kt[i])
        done += n
    return BaselineResult(best, best_pe, best_kt, np.concatenate(hist), eps)


# ---------------------------------------------------------------------------
def grid_search(workload, ecfg: env_lib.EnvConfig, eps: int = 5000,
                stride: int = 1, batch: int = 512,
                eval_fn=None) -> BaselineResult:
    """Lexicographic sweep with stride over the per-layer level space.

    For an N-layer model the space is L^(2N); Eps samples only scratch the
    first couple of genes (everything else pinned at level 0), which is why
    grid search performs so poorly in Table IV -- reproduced faithfully.
    """
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    base = int(np.ceil(ecfg.levels / stride))
    eval_b = _eval_batch_fn(env, ecfg, eval_fn)
    best, best_pe, best_kt = np.inf, None, None
    hist = []
    done = 0
    while done < eps:
        n = min(batch, eps - done)
        idx = np.arange(done, done + n, dtype=np.int64)
        digits = np.zeros((n, 2 * N), dtype=np.int32)
        rem = idx.copy()
        for d in range(2 * N):          # last gene varies fastest
            digits[:, 2 * N - 1 - d] = (rem % base) * stride
            rem //= base
            if not rem.any():
                break
        genomes = np.minimum(digits.reshape(n, N, 2), ecfg.levels - 1)
        fit, pe, kt = eval_b(jnp.asarray(genomes))
        obs_instrument.hard_evals("grid", n)
        fit = np.asarray(fit)
        hist.append(np.minimum(np.minimum.accumulate(fit), best))
        i = int(fit.argmin())
        if fit[i] < best:
            best, best_pe, best_kt = float(fit[i]), np.asarray(pe[i]), \
                np.asarray(kt[i])
        done += n
    return BaselineResult(best, best_pe, best_kt, np.concatenate(hist), eps)


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SAConfig:
    temperature: float = 10.0   # the paper's setting
    step: int = 1
    decay: float = 0.999
    seed: int = 0


class SAState(NamedTuple):
    """Annealing carry: everything a resumed run needs."""

    genome: jnp.ndarray       # (N, 2) int32 levels
    cur_fit: jnp.ndarray      # () f32 current point's objective-or-inf
    best_fit: jnp.ndarray     # () f32 best seen
    best_genome: jnp.ndarray  # (N, 2) int32
    temp: jnp.ndarray         # () f32 annealing temperature
    key: jnp.ndarray
    step: jnp.ndarray         # () int32 annealing steps completed


class SAEngine(NamedTuple):
    """One annealing step split at the cost evaluation.

    ``step_fn(state, _)`` is the in-graph scan body; it composes
    ``propose`` -> evaluate-candidate -> ``accept``.  The split lets a
    host-side ``eval_fn`` (the search service's cross-request batcher) own
    the candidate evaluation while ``propose``/``accept`` stay the same
    compiled programs, so batched runs are byte-identical to in-graph ones.
    """

    init_genome: Callable     # seed -> (genome, key)
    propose: Callable         # SAState -> (cand, accept_key, next_key)
    accept: Callable          # (SAState, cand, cand_fit, k4, key) ->
    #                           (SAState, best_fit)
    step_fn: Callable         # (SAState, _) -> (SAState, best_fit)
    eval_one: Callable        # (N, 2) genome -> () fitness


def make_sa_engine(env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
                   cfg: SAConfig) -> SAEngine:
    N = env.num_layers
    L = ecfg.levels

    def eval_one(genome):
        fit, pe, kt = _decode_and_eval(env, ecfg, genome[None])
        return fit[0]

    def propose(state: SAState):
        key, k1, k2, k3, k4 = jax.random.split(state.key, 5)
        i = jax.random.randint(k1, (), 0, N)
        j = jax.random.randint(k2, (), 0, 2)
        delta = jnp.where(jax.random.uniform(k3) < 0.5, -cfg.step, cfg.step)
        cand = state.genome.at[i, j].set(
            jnp.clip(state.genome[i, j] + delta, 0, L - 1))
        return cand, k4, key

    def accept(state: SAState, cand, cand_fit, k4, key):
        # Metropolis on finite fitness; +inf candidates only accepted if the
        # current point is also infeasible (pure exploration).
        d = cand_fit - state.cur_fit
        accept_prob = jnp.where(d <= 0, 1.0, jnp.exp(-jnp.minimum(
            d / jnp.maximum(state.cur_fit, 1.0) * 100.0 / state.temp, 50.0)))
        accept_prob = jnp.where(jnp.isnan(accept_prob),
                                jnp.where(jnp.isinf(state.cur_fit), 1.0, 0.0),
                                accept_prob)
        take = jax.random.uniform(k4) < accept_prob
        genome = jnp.where(take, cand, state.genome)
        cur_fit = jnp.where(take, cand_fit, state.cur_fit)
        better = cand_fit < state.best_fit
        best_fit = jnp.where(better, cand_fit, state.best_fit)
        best_genome = jnp.where(better, cand, state.best_genome)
        return SAState(genome, cur_fit, best_fit, best_genome,
                       state.temp * cfg.decay, key,
                       state.step + 1), best_fit

    def step_fn(carry: SAState, _):
        cand, k4, key = propose(carry)
        return accept(carry, cand, eval_one(cand), k4, key)

    def init_genome(seed):
        key = jax.random.PRNGKey(seed)
        key, k0 = jax.random.split(key)
        return jax.random.randint(k0, (N, 2), 0, L), key

    return SAEngine(init_genome, propose, accept, step_fn, eval_one)


def run_sa_search(workload, ecfg: env_lib.EnvConfig, eps: int = 5000,
                  cfg: SAConfig = SAConfig(),
                  state: Optional[SAState] = None,
                  chunk: Optional[int] = None,
                  on_chunk=None,
                  eval_fn=None,
                  env: Optional[env_lib.EnvArrays] = None):
    """Chunked, resumable simulated annealing.  Returns (SAState, history).

    Runs ``eps`` *more* annealing steps from ``state`` (fresh run when
    None) in chunks of ``chunk`` steps (default: one chunk).
    ``on_chunk(state, chunk_hist, steps_done)`` fires between chunks -- the
    unified API streams progress and observes cancellation there, exactly
    like ``reinforce.run_search``.  ``eval_fn(pe, kt, df) -> (1,) fitness``
    moves candidate evaluation to the host (the search service injects its
    cross-request batcher); results are byte-identical either way, and
    chunk boundaries never change the result.
    """
    if env is None:
        env = env_lib.make_env(workload, ecfg)
    engine = make_sa_engine(env, ecfg, cfg)
    pe_table = np.asarray(env.pe_table, np.float32)
    kt_table = np.asarray(env.kt_table, np.float32)

    def host_eval(genome_np):
        pe = pe_table[genome_np[:, 0]][None]
        kt = kt_table[genome_np[:, 1]][None]
        fit = np.asarray(eval_fn(pe, kt, np.float32(ecfg.dataflow)),
                         np.float32)
        return jnp.float32(fit[0])

    if state is None:
        genome, key = engine.init_genome(cfg.seed)
        cur = (host_eval(np.asarray(genome)) if eval_fn is not None
               else jax.jit(engine.eval_one)(genome))
        state = SAState(genome, cur, cur, genome,
                        jnp.float32(cfg.temperature), key,
                        jnp.zeros((), jnp.int32))

    if eval_fn is None:
        @functools.partial(jax.jit, static_argnames=("n",))
        def scan_chunk(state, n):
            return jax.lax.scan(engine.step_fn, state, None, length=n)

        def run_chunk(state, n):
            state, h = scan_chunk(state, n)
            return state, np.asarray(h)
    else:
        propose = jax.jit(engine.propose)
        accept = jax.jit(engine.accept)

        def run_chunk(state, n):
            h = np.empty((n,), np.float32)
            for s in range(n):
                cand, k4, key = propose(state)
                cand_fit = host_eval(np.asarray(cand))
                state, bf = accept(state, cand, cand_fit, k4, key)
                h[s] = np.float32(bf)
            return state, h

    state, hist = chunk_lib.drive(state, eps, chunk, run_chunk, on_chunk,
                                  engine="sa")
    return state, chunk_lib.concat_hist(hist)


def sa_solution(env: env_lib.EnvArrays, state: SAState):
    """Decode an SA state's best genome to raw (pe, kt) arrays."""
    pe = np.asarray(env.pe_table)[np.asarray(state.best_genome[:, 0])]
    kt = np.asarray(env.kt_table)[np.asarray(state.best_genome[:, 1])]
    return pe, kt


def simulated_annealing(workload, ecfg: env_lib.EnvConfig, eps: int = 5000,
                        cfg: SAConfig = SAConfig(),
                        eval_fn=None) -> BaselineResult:
    env = env_lib.make_env(workload, ecfg)
    state, hist = run_sa_search(workload, ecfg, eps, cfg, eval_fn=eval_fn,
                                env=env)
    pe, kt = sa_solution(env, state)
    return BaselineResult(float(state.best_fit), pe, kt, hist, eps)


# ---------------------------------------------------------------------------
def bayes_opt(workload, ecfg: env_lib.EnvConfig, eps: int = 5000,
              seed: int = 0, n_candidates: int = 64, gamma: float = 0.15,
              init_random: int = 64, batch: int = 16,
              eval_fn=None) -> BaselineResult:
    """Tree-Parzen-Estimator Bayesian optimization (surrogate + acquisition).

    The paper uses a GP-based BO [54]; a GP over a 2N-dim discrete space with
    5000 observations is O(n^3)-infeasible here, so we use the standard TPE
    formulation (per-dimension categorical good/bad densities, expected-
    improvement-equivalent l/g acquisition).  Same interface and failure
    mode: under IoTx the surrogate never observes a feasible point and the
    result is NAN, as in Table IV.
    """
    rng = np.random.default_rng(seed)
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    L = ecfg.levels
    eval_b = _eval_batch_fn(env, ecfg, eval_fn)

    X = rng.integers(0, L, size=(min(init_random, eps), N, 2)).astype(np.int32)
    fit, pe_all, kt_all = eval_b(jnp.asarray(X))
    obs_instrument.hard_evals("bo", len(X))
    y = np.asarray(fit, dtype=np.float64)
    hist = list(np.minimum.accumulate(np.where(np.isinf(y), np.inf, y)))

    while len(y) < eps:
        finite = np.isfinite(y)
        # Rank: feasible by value, infeasible last.
        order = np.argsort(np.where(finite, y, np.inf))
        n_good = max(4, int(gamma * len(y)))
        good = X[order[:n_good]]
        # Per-dimension categorical densities with Laplace smoothing.
        counts = np.ones((N, 2, L))
        for g in good:
            for d in range(2):
                counts[np.arange(N), d, g[:, d]] += 1.0
        pg = counts / counts.sum(-1, keepdims=True)
        counts_all = np.ones((N, 2, L))
        for g in X[order[n_good:]][: 4 * n_good]:
            for d in range(2):
                counts_all[np.arange(N), d, g[:, d]] += 1.0
        pb = counts_all / counts_all.sum(-1, keepdims=True)

        # Sample candidates from l(x), score by l/g, evaluate the best few.
        cand = np.zeros((n_candidates, N, 2), dtype=np.int32)
        for d in range(2):
            cum = pg[:, d].cumsum(-1)
            u = rng.random((n_candidates, N, 1))
            cand[:, :, d] = (u > cum[None]).sum(-1)
        li = np.take_along_axis(
            pg[None], cand.transpose(0, 1, 2)[..., None], axis=-1)
        gi = np.take_along_axis(
            pb[None], cand.transpose(0, 1, 2)[..., None], axis=-1)
        score = np.log(li + 1e-12).sum((1, 2, 3)) - np.log(
            gi + 1e-12).sum((1, 2, 3))
        # Clamp the final batch to the remaining budget: the best must be
        # found within eps samples (the conformance suite asserts the trace
        # ends at best_value; an over-budget improvement would be invisible
        # in the eps-length history yet reported as the result).
        pick = cand[np.argsort(-score)[:min(batch, eps - len(y))]]
        fit, _, _ = eval_b(jnp.asarray(pick))
        obs_instrument.hard_evals("bo", len(pick))
        fit = np.asarray(fit, dtype=np.float64)
        X = np.concatenate([X, pick], axis=0)
        y = np.concatenate([y, fit])
        prev_best = hist[-1] if hist else np.inf
        hist.extend(np.minimum(
            np.minimum.accumulate(fit), prev_best).tolist())

    i = int(np.argmin(np.where(np.isfinite(y), y, np.inf)))
    best = float(y[i]) if np.isfinite(y[i]) else float("inf")
    pe = np.asarray(env.pe_table)[X[i, :, 0]]
    kt = np.asarray(env.kt_table)[X[i, :, 1]]
    return BaselineResult(best, pe, kt, np.asarray(hist[:eps]), eps)
