"""The shared chunk loop every chunked engine drives through.

Every resumable engine in this repo -- reinforce, a2c/ppo2, both GAs,
NSGA-II, SA and the relaxed one-shot engine -- runs the same host loop:
split ``total`` steps into ``chunk``-sized pieces, run one piece, append
its history, fire ``on_chunk(state, h, done)`` (the unified API's streaming
+ cancellation point), repeat.  :func:`drive` owns that loop in ONE place,
which is also where per-chunk telemetry lives: one engine-tagged
``search.chunk`` span per chunk, one hard-eval counter tick per evaluation,
and per-chunk wall-clock into the current flight recorder.

The contract is byte-stability: ``drive`` sequences ``run_chunk`` and
``on_chunk`` exactly as the engines' hand-rolled loops did (same chunk
normalization, same ``min(chunk, total - done)`` splits, same callback
arguments), and the telemetry is observational only -- instrumented and
un-instrumented runs return identical bytes (asserted registry-wide in
tests/test_optimizer_conformance.py).
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.obs import instrument as obs_instrument
from repro.obs import state as obs_state
from repro.obs import trace as obs_trace


def drive(state, total: int, chunk: Optional[int],
          run_chunk: Callable,
          on_chunk: Optional[Callable] = None,
          *,
          engine: str,
          evals_per_step: int = 1,
          start: int = 0) -> Tuple[object, List]:
    """Run ``total - start`` more steps of ``run_chunk`` in chunks.

    run_chunk(state, n) -> (state, h): one piece of ``n`` steps; ``h`` is
        that piece's history (numpy array or pytree -- ``drive`` never
        inspects it).
    on_chunk(state, h, done): fires after every piece with ``done`` counted
        from 0 (``start`` offsets it for engines whose loop has a prologue,
        e.g. the relaxed engine's rounding-variant tail).
    engine / evals_per_step: telemetry tags -- each chunk of ``n`` steps
        accounts ``n * evals_per_step`` hard evaluations (GA generations
        evaluate a population per step, RL epochs E episodes, SA one).

    Returns ``(state, [h, ...])``; callers concatenate with
    :func:`concat_hist` (or their own dict-aware merge).
    """
    chunk = (total - start) if not chunk else max(int(chunk), 1)
    hist: List = []
    done = start
    while done < total:
        n = min(chunk, total - done)
        if obs_state.enabled:
            t0 = time.perf_counter()
            with obs_trace.span("search.chunk", engine=engine, start=done,
                                steps=n, evals=n * evals_per_step):
                state, h = run_chunk(state, n)
            obs_instrument.chunk_metrics(engine, n, n * evals_per_step,
                                         time.perf_counter() - t0)
        else:
            state, h = run_chunk(state, n)
        hist.append(h)
        done += n
        if on_chunk is not None:
            on_chunk(state, h, done)
    return state, hist


def concat_hist(hist: List) -> np.ndarray:
    """Concatenate per-chunk history arrays ((0,) f32 when no chunks ran)."""
    return (np.concatenate(hist) if hist else np.empty((0,), np.float32))


def concat_hist_dict(hist: List) -> dict:
    """Concatenate per-chunk history dicts key-wise (RL-family metrics)."""
    if not hist:
        return {}
    return {k: np.concatenate([h[k] for h in hist]) for k in hist[0]}
