"""Policy networks for the ConfuciuX agent (SIII-A2, Table IX).

The paper's policy is an RNN with one LSTM(128) hidden layer -- the recurrent
state is what lets the agent track the remaining platform budget across
layers.  An MLP variant exists for the Table IX ablation.

Heads: one L-way categorical per action (PE level, Buffer level) plus an
optional 3-way dataflow head for the MIX co-automation agent (SIV-D).

Pure JAX; the LSTM step can route through the fused Pallas kernel
(kernels/lstm_cell.py) on TPU.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

HIDDEN = 128  # the paper's LSTM size


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    obs_dim: int = 10
    hidden: int = HIDDEN
    levels: int = 12          # L action levels
    mix: bool = False         # add the 3-way dataflow head
    kind: str = "rnn"         # "rnn" (paper) | "mlp" (Table IX ablation)
    use_kernel: Optional[bool] = None  # None -> pallas kernel iff on TPU

    @property
    def n_heads(self) -> int:
        return 3 if self.mix else 2


class LSTMState(NamedTuple):
    h: jnp.ndarray
    c: jnp.ndarray


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, jnp.float32) * scale


def init_params(key, cfg: PolicyConfig):
    ks = jax.random.split(key, 8)
    H, I, L = cfg.hidden, cfg.obs_dim, cfg.levels
    params = {
        "head_pe": {"w": _glorot(ks[2], (H, L)), "b": jnp.zeros((L,))},
        "head_kt": {"w": _glorot(ks[3], (H, L)), "b": jnp.zeros((L,))},
    }
    if cfg.mix:
        params["head_df"] = {"w": _glorot(ks[4], (H, 3)),
                             "b": jnp.zeros((3,))}
    if cfg.kind == "rnn":
        params["lstm"] = {
            "wx": _glorot(ks[0], (I, 4 * H)),
            "wh": _glorot(ks[1], (H, 4 * H)),
            # forget-gate bias 1.0 (standard LSTM initialization)
            "b": jnp.zeros((4 * H,)).at[H:2 * H].set(1.0),
        }
    elif cfg.kind == "mlp":
        params["mlp"] = {
            "w1": _glorot(ks[5], (I, H)), "b1": jnp.zeros((H,)),
            "w2": _glorot(ks[6], (H, H)), "b2": jnp.zeros((H,)),
        }
    else:
        raise ValueError(f"unknown policy kind {cfg.kind!r}")
    return params


def init_state(cfg: PolicyConfig, batch: Tuple[int, ...] = ()) -> LSTMState:
    shape = (*batch, cfg.hidden)
    return LSTMState(jnp.zeros(shape), jnp.zeros(shape))


def step(params, cfg: PolicyConfig, obs, state: LSTMState):
    """One policy step.  obs: (..., obs_dim).  Returns (logits_tuple, state').

    The MLP variant ignores (and passes through) the recurrent state -- it
    sees only the current observation, which is exactly why the paper finds
    it weaker under tight budgets (it cannot remember consumed constraint).
    """
    if cfg.kind == "rnn":
        lp = params["lstm"]
        squeeze = obs.ndim == 1
        x = obs[None, :] if squeeze else obs
        h = state.h[None, :] if squeeze else state.h
        c = state.c[None, :] if squeeze else state.c
        use_kernel = (cfg.use_kernel if cfg.use_kernel is not None
                      else jax.default_backend() == "tpu")
        h2, c2 = kops.lstm_step(x, h, c, lp["wx"], lp["wh"], lp["b"],
                                use_kernel=use_kernel)
        if squeeze:
            h2, c2 = h2[0], c2[0]
        feat, new_state = h2, LSTMState(h2, c2)
    else:
        mp = params["mlp"]
        z = jnp.tanh(obs @ mp["w1"] + mp["b1"])
        feat = jnp.tanh(z @ mp["w2"] + mp["b2"])
        new_state = state

    logits = [feat @ params["head_pe"]["w"] + params["head_pe"]["b"],
              feat @ params["head_kt"]["w"] + params["head_kt"]["b"]]
    if cfg.mix:
        logits.append(feat @ params["head_df"]["w"] + params["head_df"]["b"])
    return tuple(logits), new_state


def sample_action(key, logits):
    """Sample one categorical action; returns (action, log_prob, entropy)."""
    logp = jax.nn.log_softmax(logits)
    a = jax.random.categorical(key, logits)
    lp = jnp.take_along_axis(logp, a[..., None], axis=-1)[..., 0]
    ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)
    return a, lp, ent
