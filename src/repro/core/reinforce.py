"""ConfuciuX stage 1: REINFORCE global search (SIII-A..F).

Faithful elements (paper section in brackets):
  * LSTM(128) policy, one (PE, Buf) action pair per layer [III-A2, III-C]
  * observation Eq. (1), normalized to [-1, 1]                       [III-B]
  * reward  R = P_t - P_min  with the *global* running minimum P_min
    tracked across all time-steps and epochs (P = -objective, so rewards
    are always >= 0 while feasible)                                  [III-E]
  * violation penalty = -(accumulated episode reward), episode ends  [III-E]
  * discount d = 0.9; per-episode reward standardization             [III-E]
  * episode terminates after 2N actions (N steps of action pairs) or on
    constraint violation                                             [III-A]
  * MIX: optional third per-layer action choosing the dataflow style [IV-D]

Beyond-paper (ablatable, see EXPERIMENTS.md SPerf): the environment is inside
the XLA program, episodes are batched with vmap (episodes_per_epoch = 1
reproduces the paper's setting), and whole epoch-chunks run under lax.scan.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import chunk as chunk_lib
from repro.core import env as env_lib
from repro.core import policy as policy_lib
from repro.costmodel import maestro
from repro.training import optim


@dataclasses.dataclass(frozen=True)
class ReinforceConfig:
    epochs: int = 5000
    episodes_per_epoch: int = 1   # 1 == the paper's setting
    lr: float = 3e-3
    discount: float = 0.9         # the paper's d
    entropy_coef: float = 0.0     # 0.0 == faithful; >0 helps tiny workloads
    seed: int = 0


class SearchState(NamedTuple):
    params: dict
    opt_state: optim.OptState
    pmin: jnp.ndarray        # () running min of P_t across steps & epochs
    best_value: jnp.ndarray  # () best feasible objective so far
    best_pe_lvl: jnp.ndarray  # (N,) int32
    best_kt_lvl: jnp.ndarray  # (N,) int32
    best_df: jnp.ndarray      # (N,) int32
    key: jnp.ndarray
    epoch: jnp.ndarray


class RolloutOut(NamedTuple):
    rewards: jnp.ndarray   # (N,)
    logps: jnp.ndarray     # (N,)
    entropy: jnp.ndarray   # (N,)
    mask: jnp.ndarray      # (N,) 1.0 while alive at step entry
    perf: jnp.ndarray      # (N,) raw objective per layer (positive)
    actions: jnp.ndarray   # (N, 3) int32 (pe_lvl, kt_lvl, df)
    feasible: jnp.ndarray  # () bool -- never violated
    model_value: jnp.ndarray  # () sum of per-layer objective
    pmin: jnp.ndarray      # () updated running min


def make_rollout(ecfg: env_lib.EnvConfig, pcfg: policy_lib.PolicyConfig,
                 env: env_lib.EnvArrays, discount: float):
    """Build rollout(params, pmin, key) -> RolloutOut for a fixed env."""
    N = env.num_layers
    t_norm = 2.0 * jnp.arange(N, dtype=jnp.float32) / max(N - 1, 1) - 1.0
    Lm1 = max(pcfg.levels - 1, 1)

    def _make_step_fn(params):
      def step_fn(carry, xs):
        (pstate, prev_pe, prev_kt, prev_df, budget_left, alive, acc_r,
         pmin_run, key) = carry
        sobs, layer_t, tn = xs
        dyn = [prev_pe, prev_kt] + ([prev_df] if ecfg.mix else []) + [tn]
        obs = jnp.concatenate([sobs, jnp.stack(dyn)])
        logits, pstate2 = policy_lib.step(params, pcfg, obs, pstate)
        key, k1, k2, k3 = jax.random.split(key, 4)
        a_pe, lp_pe, ent_pe = policy_lib.sample_action(k1, logits[0])
        a_kt, lp_kt, ent_kt = policy_lib.sample_action(k2, logits[1])
        if ecfg.mix:
            a_df, lp_df, ent_df = policy_lib.sample_action(k3, logits[2])
        else:
            a_df = jnp.asarray(ecfg.dataflow, jnp.int32)
            lp_df = jnp.zeros(())
            ent_df = jnp.zeros(())
        pe = env.pe_table[a_pe]
        kt = env.kt_table[a_kt]
        out = maestro.evaluate(layer_t, pe, kt, a_df)
        perf_pos = (out.latency if ecfg.objective == "latency"
                    else out.energy)
        cons = out.area if ecfg.constraint == "area" else out.power
        P_t = -perf_pos  # higher is better
        if ecfg.scenario == "LP":
            budget_left2 = budget_left - cons
            viol = alive & (budget_left2 < 0)
        else:  # LS: the single design must fit the budget at every layer
            budget_left2 = budget_left
            viol = alive & (cons > env.budget)
        pmin2 = jnp.where(alive, jnp.minimum(pmin_run, P_t), pmin_run)
        r_ok = P_t - pmin2                       # >= 0 by construction
        r = jnp.where(viol, -acc_r, r_ok) * alive
        acc_r2 = acc_r + jnp.where(alive & ~viol, r, 0.0)
        mask = alive.astype(jnp.float32)
        alive2 = alive & ~viol
        carry2 = (pstate2,
                  2.0 * a_pe / Lm1 - 1.0, 2.0 * a_kt / Lm1 - 1.0,
                  a_df.astype(jnp.float32) - 1.0,
                  budget_left2, alive2, acc_r2, pmin2, key)
        outs = (r, lp_pe + lp_kt + lp_df, ent_pe + ent_kt + ent_df,
                mask, perf_pos,
                jnp.stack([a_pe, a_kt, a_df]).astype(jnp.int32))
        return carry2, outs

      return step_fn

    def rollout(params, pmin, key) -> RolloutOut:
        init = (policy_lib.init_state(pcfg),
                jnp.float32(-1.0), jnp.float32(-1.0), jnp.float32(-1.0),
                env.budget, jnp.asarray(True), jnp.float32(0.0),
                pmin, key)
        carry, outs = jax.lax.scan(
            _make_step_fn(params), init, (env.static_obs, env.layers, t_norm))
        (_, _, _, _, _, alive_end, _, pmin_out, _) = carry
        r, logps, ents, mask, perf, actions = outs
        return RolloutOut(
            rewards=r, logps=logps, entropy=ents, mask=mask, perf=perf,
            actions=actions, feasible=alive_end,
            model_value=jnp.sum(perf * mask), pmin=pmin_out)

    return rollout


def _discounted_returns(rewards, discount):
    def f(g, r_t):
        g2 = r_t + discount * g
        return g2, g2

    _, G = jax.lax.scan(f, jnp.float32(0.0), rewards[::-1])
    return G[::-1]


def make_epoch_fn(ecfg: env_lib.EnvConfig, pcfg: policy_lib.PolicyConfig,
                  rcfg: ReinforceConfig, env: env_lib.EnvArrays,
                  opt: optim.Adam):
    """Build the jitted epoch update: E episodes -> policy-gradient step."""
    rollout = make_rollout(ecfg, pcfg, env, rcfg.discount)
    E = rcfg.episodes_per_epoch

    def loss_fn(params, pmin, keys):
        rolls = jax.vmap(lambda k: rollout(params, pmin, k))(keys)
        G = jax.vmap(lambda r: _discounted_returns(r, rcfg.discount))(
            rolls.rewards * rolls.mask)
        n_valid = jnp.maximum(rolls.mask.sum(axis=1), 1.0)
        mean = (G * rolls.mask).sum(axis=1) / n_valid
        var = (jnp.square(G - mean[:, None]) * rolls.mask).sum(axis=1) / n_valid
        G_std = (G - mean[:, None]) / (jnp.sqrt(var)[:, None] + 1e-8)
        pg = -(rolls.logps * jax.lax.stop_gradient(G_std)
               * rolls.mask).sum(axis=1)
        ent = (rolls.entropy * rolls.mask).sum(axis=1)
        loss = jnp.mean(pg) - rcfg.entropy_coef * jnp.mean(ent)
        return loss, rolls

    def epoch_fn(state: SearchState, _):
        key, sub = jax.random.split(state.key)
        keys = jax.random.split(sub, E)
        (loss, rolls), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, state.pmin, keys)
        params, opt_state = opt.update(grads, state.opt_state, state.params)
        # Track the best feasible whole-model solution seen so far.
        values = jnp.where(rolls.feasible, rolls.model_value, jnp.inf)
        i = jnp.argmin(values)
        better = values[i] < state.best_value
        best_value = jnp.where(better, values[i], state.best_value)
        pick = lambda new, old: jnp.where(better, new, old)
        new_state = SearchState(
            params=params, opt_state=opt_state,
            pmin=jnp.min(rolls.pmin),
            best_value=best_value,
            best_pe_lvl=pick(rolls.actions[i, :, 0], state.best_pe_lvl),
            best_kt_lvl=pick(rolls.actions[i, :, 1], state.best_kt_lvl),
            best_df=pick(rolls.actions[i, :, 2], state.best_df),
            key=key, epoch=state.epoch + 1)
        metrics = {
            "loss": loss,
            "best_value": best_value,
            "mean_value": jnp.mean(rolls.model_value),
            "feasible_frac": jnp.mean(rolls.feasible.astype(jnp.float32)),
            "mean_return": jnp.mean((rolls.rewards * rolls.mask).sum(axis=1)),
        }
        return new_state, metrics

    return epoch_fn


def init_search(env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
                pcfg: policy_lib.PolicyConfig, rcfg: ReinforceConfig,
                opt: optim.Adam) -> SearchState:
    key = jax.random.PRNGKey(rcfg.seed)
    key, pkey = jax.random.split(key)
    params = policy_lib.init_params(pkey, pcfg)
    N = env.num_layers
    return SearchState(
        params=params, opt_state=opt.init(params),
        pmin=jnp.asarray(jnp.inf, jnp.float32),
        best_value=jnp.asarray(jnp.inf, jnp.float32),
        best_pe_lvl=jnp.zeros((N,), jnp.int32),
        best_kt_lvl=jnp.zeros((N,), jnp.int32),
        best_df=jnp.full((N,), ecfg.dataflow, jnp.int32),
        key=key, epoch=jnp.zeros((), jnp.int32))


def run_search(workload, ecfg: env_lib.EnvConfig,
               rcfg: ReinforceConfig = ReinforceConfig(),
               pcfg: policy_lib.PolicyConfig | None = None,
               state: SearchState | None = None,
               chunk: int = 500,
               on_chunk=None):
    """Full stage-1 search.  Returns (state, history dict of (epochs,) arrays).

    Runs in jitted lax.scan chunks so long searches can checkpoint between
    chunks.  ``on_chunk(state, chunk_history, epochs_done)`` fires after each
    chunk (the unified API streams progress through it); the compiled epoch
    function is reused across chunks either way.
    """
    env = env_lib.make_env(workload, ecfg)
    if pcfg is None:
        pcfg = policy_lib.PolicyConfig(obs_dim=ecfg.obs_dim, mix=ecfg.mix,
                                       levels=ecfg.levels)
    opt = optim.Adam(lr=rcfg.lr)
    if state is None:
        state = init_search(env, ecfg, pcfg, rcfg, opt)
    epoch_fn = make_epoch_fn(ecfg, pcfg, rcfg, env, opt)

    @functools.partial(jax.jit, static_argnames=("n",))
    def scan_chunk(state, n):
        return jax.lax.scan(epoch_fn, state, None, length=n)

    def run_chunk(state, n):
        state, metrics = scan_chunk(state, n)
        return state, jax.tree.map(jax.device_get, metrics)

    state, history = chunk_lib.drive(
        state, rcfg.epochs, chunk, run_chunk, on_chunk,
        engine="reinforce", evals_per_step=rcfg.episodes_per_epoch)
    return state, chunk_lib.concat_hist_dict(history)


def solution_arrays(state: SearchState, env: env_lib.EnvArrays):
    """Decode the best solution's raw (pe, kt, df) arrays."""
    pe = env.pe_table[state.best_pe_lvl]
    kt = env.kt_table[state.best_kt_lvl]
    return pe, kt, state.best_df
