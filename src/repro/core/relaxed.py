"""One-shot relaxed search: gradient descent through the soft cost model.

The discrete per-layer assignment space (PE count, per-PE tile ``kt``,
dataflow style) is relaxed to a continuous one -- ``(pe, kt)`` become boxed
reals via a sigmoid reparameterization and the dataflow choice becomes a
softmax simplex, in the style of Gumbel-softmax supernet searches.  The
engine then *descends the cost model itself*: ``jax.grad`` of the soft
MAESTRO twin (:func:`repro.costmodel.maestro.soft_model_cost`) flows through
every layer's variables jointly, so one gradient run replaces thousands of
black-box episodes.

Anatomy of a run (``eps`` counts whole-model *hard* evaluations, same
accounting as every other engine):

  * ``restarts`` parallel replicas descend the soft landscape with Adam;
    the soft objective is ``log(objective)`` plus a softplus penalty on
    relative constraint-budget violation (differentiable twin of the hard
    infeasible -> +inf rule).
  * The temperature ``tau`` anneals geometrically each round, sharpening
    the soft surrogates toward the exact hard semantics as descent
    converges (coarse landscape first, exact landscape last).
  * Every round (= ``steps_per_eval`` gradient steps) the replica with the
    best soft loss is rounded to integers and scored by the *hard* model --
    that is the engine's per-sample history, and those hard probes keep the
    reported best honest (the soft model guides, the hard model judges).
  * The final ``topk`` budget is spent re-scoring rounding variants
    (floor/ceil combinations) of the best replica's continuous point: the
    nearest integer point is not always the best one in a staircase
    landscape.

The engine honors the shared chunked/resumable contract of
:func:`repro.core.baselines.run_sa_search`: ``state`` resumes, ``chunk`` +
``on_chunk`` stream progress between chunks (the search service's
cancellation point), and an injected ``eval_fn(pe, kt, df) -> (b,) fitness``
routes the hard probes through the cross-request batcher, byte-identical to
the in-graph path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunk as chunk_lib
from repro.core import env as env_lib
from repro.costmodel import dataflows as dfl
from repro.costmodel import maestro


@dataclasses.dataclass(frozen=True)
class RelaxedConfig:
    """Knobs of the one-shot relaxed engine."""

    lr: float = 0.05               # Adam step size on the relaxed params
    steps_per_eval: int = 25       # gradient steps bought per hard probe
    restarts: int = 4              # parallel replicas (vmapped descent)
    tau_start: float = 1.0         # initial surrogate temperature
    tau_min: float = 0.05          # annealing floor (high-fidelity regime)
    tau_decay: float = 0.92        # geometric decay per round
    penalty: float = 10.0          # constraint-violation penalty weight
    topk: int = 4                  # final rounding-variant re-scores (<= 4)
    init_scale: float = 0.5        # stddev of the logit init (replica 0 = 0)
    seed: int = 0


class RelaxedState(NamedTuple):
    """Descent carry: everything a resumed run needs.

    ``params``/``m``/``v`` are ``(theta_pe, theta_kt, theta_df)`` pytrees of
    shape ``(R, N)`` / ``(R, N)`` / ``(R, N, 3)`` -- Adam moments included so
    a resume continues the *same* trajectory, not a re-warmed one.
    """

    params: tuple
    m: tuple
    v: tuple
    tau: jnp.ndarray          # () f32 current surrogate temperature
    gstep: jnp.ndarray        # () int32 gradient steps completed
    best_fit: jnp.ndarray     # () f32 best hard fitness seen (inf = none)
    best_pe: jnp.ndarray      # (N,) f32 rounded assignment of the best
    best_kt: jnp.ndarray      # (N,) f32
    best_df: jnp.ndarray      # (N,) f32
    evals: jnp.ndarray        # () int32 hard evaluations consumed


# Rounding variants tried in the final re-scoring pass, in order: the
# round-to-nearest point is probed every round already, so the variants are
# the floor/ceil corners of the continuous point's cell.
_VARIANTS = ((jnp.floor, jnp.floor), (jnp.ceil, jnp.ceil),
             (jnp.floor, jnp.ceil), (jnp.ceil, jnp.floor))


def _decode(params, mix: bool, dataflow: int):
    """Relaxed params -> continuous (pe, kt, df_weights), shapes (R, N[, 3]).

    Sigmoid box constraints keep ``(pe, kt)`` inside the fine search bounds
    (the same 1..160 x 1..16 space the second-stage GA explores); the
    dataflow simplex is a plain softmax, pinned to the env's one-hot when
    the search is not dataflow-mixing.
    """
    th_pe, th_kt, th_df = params
    pe = dfl.PE_MIN + (dfl.PE_MAX - dfl.PE_MIN) * jax.nn.sigmoid(th_pe)
    kt = dfl.KT_MIN + (dfl.KT_MAX - dfl.KT_MIN) * jax.nn.sigmoid(th_kt)
    if mix:
        df_w = jax.nn.softmax(th_df, axis=-1)
    else:
        df_w = jnp.broadcast_to(
            jax.nn.one_hot(dataflow, dfl.NUM_DATAFLOWS), th_df.shape)
    return pe, kt, df_w


def _soft_loss(params, tau, env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
               cfg: RelaxedConfig):
    """Per-replica soft objective: log-objective + budget penalty, (R,)."""
    pe, kt, df_w = _decode(params, ecfg.mix, ecfg.dataflow)
    mc = maestro.soft_model_cost(env.layers, pe, kt, df_w, tau, ecfg.scenario)
    obj = mc.latency if ecfg.objective == "latency" else mc.energy
    cons = mc.area if ecfg.constraint == "area" else mc.power
    loss = jnp.log(obj + 1.0)
    # Penalty on *relative* violation: scale-free across workloads and
    # platforms, zero-gated for the unlimited platform (budget = inf).
    rel = cons / env.budget - 1.0
    pen = cfg.penalty * 0.05 * jax.nn.softplus(rel / 0.05)
    return loss + jnp.where(jnp.isfinite(env.budget), pen, 0.0)


def _round_candidate(pe, kt, df_w, mix: bool, dataflow: int,
                     round_pe=jnp.round, round_kt=jnp.round):
    """Continuous point -> integer (pe, kt, df) inside the search bounds."""
    pe_i = jnp.clip(round_pe(pe), dfl.PE_MIN, dfl.PE_MAX)
    kt_i = jnp.clip(round_kt(kt), dfl.KT_MIN, dfl.KT_MAX)
    if mix:
        df = jnp.argmax(df_w, axis=-1).astype(jnp.float32)
    else:
        df = jnp.full(pe_i.shape, float(dataflow), jnp.float32)
    return pe_i, kt_i, df


def _init_state(env: env_lib.EnvArrays, cfg: RelaxedConfig) -> RelaxedState:
    N = env.num_layers
    R = max(int(cfg.restarts), 1)
    key = jax.random.PRNGKey(cfg.seed)
    k1, k2, k3 = jax.random.split(key, 3)
    th_pe = cfg.init_scale * jax.random.normal(k1, (R, N))
    th_kt = cfg.init_scale * jax.random.normal(k2, (R, N))
    th_df = cfg.init_scale * jax.random.normal(k3, (R, N, dfl.NUM_DATAFLOWS))
    # Replica 0 starts at the exact box center: a deterministic mid-range
    # point that is feasible on most platforms and anchors the ensemble.
    params = tuple(t.at[0].set(0.0) for t in (th_pe, th_kt, th_df))
    zeros = tuple(jnp.zeros_like(t) for t in params)
    return RelaxedState(
        params=params, m=zeros, v=zeros,
        tau=jnp.float32(cfg.tau_start),
        gstep=jnp.zeros((), jnp.int32),
        best_fit=jnp.float32(jnp.inf),
        best_pe=jnp.full((N,), jnp.nan, jnp.float32),
        best_kt=jnp.full((N,), jnp.nan, jnp.float32),
        best_df=jnp.full((N,), jnp.nan, jnp.float32),
        evals=jnp.zeros((), jnp.int32))


def make_round_fn(env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
                  cfg: RelaxedConfig):
    """Compiled one-round descent: ``steps_per_eval`` Adam steps + anneal.

    Returns ``round_fn(state) -> (state, pe_i, kt_i, df)`` where the integer
    arrays are the rounded candidate of the replica with the best soft loss
    (hard scoring stays outside, so the search service's ``eval_fn`` can own
    it).  One compiled program serves every round: ``tau`` is a traced input.
    """
    b1, b2, eps_adam = 0.9, 0.999, 1e-8
    lr = cfg.lr

    def total_loss(params, tau):
        return jnp.sum(_soft_loss(params, tau, env, ecfg, cfg))

    grad_fn = jax.grad(total_loss)

    def adam_step(carry, _):
        params, m, v, t, tau = carry
        g = grad_fn(params, tau)
        t = t + 1
        m = jax.tree_util.tree_map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree_util.tree_map(
            lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        tf = t.astype(jnp.float32)
        scale = jnp.sqrt(1.0 - b2 ** tf) / (1.0 - b1 ** tf)
        params = jax.tree_util.tree_map(
            lambda p, mi, vi: p - lr * scale * mi / (jnp.sqrt(vi) + eps_adam),
            params, m, v)
        return (params, m, v, t, tau), None

    @jax.jit
    def round_fn(state: RelaxedState):
        carry = (state.params, state.m, state.v, state.gstep, state.tau)
        (params, m, v, t, _), _ = jax.lax.scan(
            adam_step, carry, None, length=cfg.steps_per_eval)
        tau = jnp.maximum(state.tau * cfg.tau_decay, cfg.tau_min)
        losses = _soft_loss(params, tau, env, ecfg, cfg)
        r = jnp.argmin(losses)
        pe, kt, df_w = _decode(params, ecfg.mix, ecfg.dataflow)
        pe_i, kt_i, df = _round_candidate(pe[r], kt[r], df_w[r],
                                          ecfg.mix, ecfg.dataflow)
        return state._replace(params=params, m=m, v=v, tau=tau,
                              gstep=t), pe_i, kt_i, df

    @jax.jit
    def best_continuous(state: RelaxedState):
        losses = _soft_loss(state.params, state.tau, env, ecfg, cfg)
        r = jnp.argmin(losses)
        pe, kt, df_w = _decode(state.params, ecfg.mix, ecfg.dataflow)
        return pe[r], kt[r], df_w[r]

    return round_fn, best_continuous


def run_relaxed_search(workload, ecfg: env_lib.EnvConfig, eps: int = 100,
                       cfg: RelaxedConfig = RelaxedConfig(),
                       state: Optional[RelaxedState] = None,
                       chunk: Optional[int] = None,
                       on_chunk=None,
                       eval_fn=None,
                       env: Optional[env_lib.EnvArrays] = None):
    """Chunked, resumable one-shot relaxed search.  Returns (state, history).

    Spends ``eps`` *more* hard evaluations from ``state`` (fresh descent when
    None): ``eps - topk`` descent rounds, then ``topk`` rounding-variant
    re-scores of the best replica.  ``on_chunk(state, chunk_hist,
    evals_done)`` fires between chunks -- the unified API streams progress
    and observes cancellation there, exactly like ``run_sa_search``.
    ``eval_fn(pe, kt, df) -> (1,) fitness`` moves hard probes to the host
    (the search service injects its cross-request batcher); results are
    byte-identical either way, and chunk boundaries never change them.
    """
    if env is None:
        env = env_lib.make_env(workload, ecfg)
    round_fn, best_continuous = make_round_fn(env, ecfg, cfg)

    @jax.jit
    def hard_fit(pe, kt, df):
        perf, cons, feas = env_lib.genome_cost(env, ecfg, pe, kt, df)
        return jnp.where(feas, perf, jnp.inf)

    def score(pe, kt, df):
        if eval_fn is None:
            return float(hard_fit(pe, kt, df))
        pe = np.asarray(pe, np.float32)[None]
        kt = np.asarray(kt, np.float32)[None]
        df = (np.float32(ecfg.dataflow) if not ecfg.mix
              else np.asarray(df, np.float32)[None])
        return float(np.asarray(eval_fn(pe, kt, df), np.float32)[0])

    def absorb(state, fit, pe, kt, df):
        if fit < float(state.best_fit):
            state = state._replace(
                best_fit=jnp.float32(fit),
                best_pe=jnp.asarray(pe, jnp.float32),
                best_kt=jnp.asarray(kt, jnp.float32),
                best_df=jnp.asarray(df, jnp.float32))
        return state._replace(evals=state.evals + 1)

    if state is None:
        state = _init_state(env, cfg)

    n_var = min(max(int(cfg.topk), 0), len(_VARIANTS), eps - 1)
    rounds = eps - n_var

    def run_round_chunk(state, n):
        h = np.empty((n,), np.float32)
        for s in range(n):
            state, pe_i, kt_i, df = round_fn(state)
            state = absorb(state, score(pe_i, kt_i, df), pe_i, kt_i, df)
            h[s] = np.float32(state.best_fit)
        return state, h

    state, hist = chunk_lib.drive(
        state, rounds, chunk, run_round_chunk, on_chunk, engine="relaxed")
    if n_var:
        # Final budget: hard-score the floor/ceil rounding variants of the
        # best replica's continuous point (staircase landscapes often hide
        # the optimum one cell off round-to-nearest).  One drive() chunk
        # offset past the descent rounds so on_chunk sees the same `done`
        # values as the old hand-rolled loop.
        pe_c, kt_c, df_w = best_continuous(state)

        def run_variant_chunk(state, n):
            h = np.empty((n,), np.float32)
            for i in range(n):
                rp, rk = _VARIANTS[i]
                pe_i, kt_i, df = _round_candidate(
                    pe_c, kt_c, df_w, ecfg.mix, ecfg.dataflow, rp, rk)
                state = absorb(state, score(pe_i, kt_i, df), pe_i, kt_i, df)
                h[i] = np.float32(state.best_fit)
            return state, h

        state, vhist = chunk_lib.drive(
            state, rounds + n_var, n_var, run_variant_chunk, on_chunk,
            engine="relaxed", start=rounds)
        hist.extend(vhist)
    return state, chunk_lib.concat_hist(hist)


def relaxed_solution(state: RelaxedState):
    """Best rounded assignment seen: raw (pe, kt, df) arrays (NaN = none)."""
    return (np.asarray(state.best_pe), np.asarray(state.best_kt),
            np.asarray(state.best_df))
