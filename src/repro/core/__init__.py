"""ConfuciuX core: the paper's contribution as a composable JAX module.

  env          -- the interactive environment (cost model + constraints)
  policy       -- LSTM/MLP policy networks
  reinforce    -- stage-1 REINFORCE global search
  ga           -- stage-2 local GA fine-tuner + baseline GA
  baselines    -- grid / random / simulated annealing / Bayesian opt
  rl_baselines -- A2C / PPO2 actor-critic baselines
  search       -- two-stage orchestration + LS per-layer study

These are the engines.  The canonical user-facing entry point is the
unified optimizer API in :mod:`repro.api` -- one registry
(``get_optimizer("reinforce"|"ga"|"sa"|...)``) and one
``SearchRequest``/``SearchOutcome`` schema for every method; the functions
here remain callable directly as thin legacy entry points.
"""
from repro.core.env import EnvConfig, make_env
from repro.core.reinforce import ReinforceConfig, run_search
from repro.core.search import SearchResult, confuciux_search

__all__ = [
    "EnvConfig",
    "make_env",
    "ReinforceConfig",
    "run_search",
    "SearchResult",
    "confuciux_search",
]
