"""Actor-critic RL baselines: A2C [47] and PPO2 [66] (discrete variants).

The paper compares REINFORCE against A2C, ACKTR, PPO2, DDPG, SAC and TD3 and
finds the discrete on-policy methods (A2C/PPO2) the strongest baselines
(Table V; the continuous off-policy ones cost more time/memory and do worse).
We implement A2C and PPO2 -- the two baselines the paper's tables actually
feature -- on the *same* environment, observation, reward shaping and LSTM
trunk as the REINFORCE agent, plus a linear value head (the critic).

The standalone critic-fit experiment (Fig. 6: a critic cannot regress the
discrete/irregular HW-performance landscape) lives in
benchmarks/bench_fig6_critic.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import chunk as chunk_lib
from repro.core import env as env_lib
from repro.core import policy as policy_lib
from repro.core import reinforce
from repro.costmodel import maestro
from repro.training import optim


@dataclasses.dataclass(frozen=True)
class ACConfig:
    algo: str = "a2c"            # "a2c" | "ppo2"
    epochs: int = 5000
    episodes_per_epoch: int = 4
    lr: float = 1e-3
    discount: float = 0.9
    gae_lambda: float = 0.95
    clip_eps: float = 0.2        # PPO clip
    ppo_updates: int = 4         # PPO inner epochs
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    seed: int = 0


class ACRollout(NamedTuple):
    obs: jnp.ndarray       # (N, obs_dim)
    actions: jnp.ndarray   # (N, 3)
    rewards: jnp.ndarray   # (N,)
    mask: jnp.ndarray      # (N,)
    logps: jnp.ndarray     # (N,)
    values: jnp.ndarray    # (N,)
    perf: jnp.ndarray      # (N,)
    feasible: jnp.ndarray
    model_value: jnp.ndarray
    pmin: jnp.ndarray


def init_ac_params(key, pcfg: policy_lib.PolicyConfig):
    k1, k2 = jax.random.split(key)
    params = policy_lib.init_params(k1, pcfg)
    params["head_v"] = {
        "w": jax.random.normal(k2, (pcfg.hidden, 1)) * 0.01,
        "b": jnp.zeros((1,)),
    }
    return params


def _value(params, feat):
    return (feat @ params["head_v"]["w"] + params["head_v"]["b"])[..., 0]


def make_ac_rollout(ecfg: env_lib.EnvConfig, pcfg: policy_lib.PolicyConfig,
                    env: env_lib.EnvArrays):
    """Rollout that also records observations and value estimates."""
    N = env.num_layers
    t_norm = 2.0 * jnp.arange(N, dtype=jnp.float32) / max(N - 1, 1) - 1.0
    Lm1 = max(pcfg.levels - 1, 1)

    def rollout(params, pmin, key) -> ACRollout:
        def step_fn(carry, xs):
            (pstate, prev_pe, prev_kt, prev_df, budget_left, alive, acc_r,
             pmin_run, key) = carry
            sobs, layer_t, tn = xs
            dyn = [prev_pe, prev_kt] + ([prev_df] if ecfg.mix else []) + [tn]
            obs = jnp.concatenate([sobs, jnp.stack(dyn)])
            logits, pstate2 = policy_lib.step(params, pcfg, obs, pstate)
            v = _value(params, pstate2.h if pcfg.kind == "rnn" else obs)
            key, k1, k2, k3 = jax.random.split(key, 4)
            a_pe, lp_pe, _ = policy_lib.sample_action(k1, logits[0])
            a_kt, lp_kt, _ = policy_lib.sample_action(k2, logits[1])
            if ecfg.mix:
                a_df, lp_df, _ = policy_lib.sample_action(k3, logits[2])
            else:
                a_df = jnp.asarray(ecfg.dataflow, jnp.int32)
                lp_df = jnp.zeros(())
            pe = env.pe_table[a_pe]
            kt = env.kt_table[a_kt]
            out = maestro.evaluate(layer_t, pe, kt, a_df)
            perf_pos = (out.latency if ecfg.objective == "latency"
                        else out.energy)
            cons = out.area if ecfg.constraint == "area" else out.power
            P_t = -perf_pos
            if ecfg.scenario == "LP":
                budget_left2 = budget_left - cons
                viol = alive & (budget_left2 < 0)
            else:
                budget_left2 = budget_left
                viol = alive & (cons > env.budget)
            pmin2 = jnp.where(alive, jnp.minimum(pmin_run, P_t), pmin_run)
            r = jnp.where(viol, -acc_r, P_t - pmin2) * alive
            acc_r2 = acc_r + jnp.where(alive & ~viol, r, 0.0)
            mask = alive.astype(jnp.float32)
            alive2 = alive & ~viol
            carry2 = (pstate2,
                      2.0 * a_pe / Lm1 - 1.0, 2.0 * a_kt / Lm1 - 1.0,
                      a_df.astype(jnp.float32) - 1.0,
                      budget_left2, alive2, acc_r2, pmin2, key)
            outs = (obs, jnp.stack([a_pe, a_kt, a_df]).astype(jnp.int32),
                    r, mask, lp_pe + lp_kt + lp_df, v, perf_pos)
            return carry2, outs

        init = (policy_lib.init_state(pcfg),
                jnp.float32(-1.0), jnp.float32(-1.0), jnp.float32(-1.0),
                env.budget, jnp.asarray(True), jnp.float32(0.0), pmin, key)
        carry, outs = jax.lax.scan(
            step_fn, init, (env.static_obs, env.layers, t_norm))
        alive_end, pmin_out = carry[5], carry[7]
        obs, actions, r, mask, logps, values, perf = outs
        return ACRollout(obs, actions, r, mask, logps, values, perf,
                         alive_end, jnp.sum(perf * mask), pmin_out)

    return rollout


def eval_sequence(params, pcfg: policy_lib.PolicyConfig, obs_seq, actions):
    """Re-run the policy over stored observations: logp/value/entropy per t."""
    def step_fn(pstate, xs):
        obs, act = xs
        logits, pstate2 = policy_lib.step(params, pcfg, obs, pstate)
        v = _value(params, pstate2.h if pcfg.kind == "rnn" else obs)
        lp = jnp.zeros(())
        ent = jnp.zeros(())
        for idx, lg in enumerate(logits):
            logp_all = jax.nn.log_softmax(lg)
            lp = lp + logp_all[act[idx]]
            p = jnp.exp(logp_all)
            ent = ent - jnp.sum(p * logp_all)
        return pstate2, (lp, v, ent)

    pstate = policy_lib.init_state(pcfg)
    _, (lps, vs, ents) = jax.lax.scan(step_fn, pstate, (obs_seq, actions))
    return lps, vs, ents


def _gae(rewards, values, mask, gamma, lam):
    """Generalized advantage estimation over a masked episode."""
    def f(carry, xs):
        adv_next, v_next = carry
        r, v, m = xs
        delta = r + gamma * v_next * m - v
        adv = delta + gamma * lam * adv_next * m
        return (adv, v), adv

    (_, _), advs = jax.lax.scan(
        f, (jnp.float32(0.0), jnp.float32(0.0)),
        (rewards[::-1], values[::-1], mask[::-1]))
    return advs[::-1]


def init_ac_search(env: env_lib.EnvArrays, ecfg: env_lib.EnvConfig,
                  pcfg: policy_lib.PolicyConfig, acfg: ACConfig,
                  opt: optim.Adam) -> reinforce.SearchState:
    """Fresh A2C/PPO2 search state (policy + critic params, empty best)."""
    key = jax.random.PRNGKey(acfg.seed)
    key, pkey = jax.random.split(key)
    params = init_ac_params(pkey, pcfg)
    N = env.num_layers
    return reinforce.SearchState(
        params=params, opt_state=opt.init(params),
        pmin=jnp.asarray(jnp.inf, jnp.float32),
        best_value=jnp.asarray(jnp.inf, jnp.float32),
        best_pe_lvl=jnp.zeros((N,), jnp.int32),
        best_kt_lvl=jnp.zeros((N,), jnp.int32),
        best_df=jnp.full((N,), ecfg.dataflow, jnp.int32),
        key=key, epoch=jnp.zeros((), jnp.int32))


def run_ac_search(workload, ecfg: env_lib.EnvConfig,
                  acfg: ACConfig = ACConfig(),
                  pcfg: policy_lib.PolicyConfig | None = None,
                  state: reinforce.SearchState | None = None,
                  chunk: int = 500,
                  on_chunk=None):
    """A2C / PPO2 search with the same interface as reinforce.run_search.

    Resumable: pass the returned ``state`` back in to continue a run (the
    chunk boundaries never change the result -- the epoch scan carries the
    same state either way).  ``on_chunk(state, chunk_history, epochs_done)``
    fires after every chunk, which is how the unified API streams a2c/ppo2
    progress live, exactly like reinforce/two_stage.
    """
    env = env_lib.make_env(workload, ecfg)
    if pcfg is None:
        pcfg = policy_lib.PolicyConfig(obs_dim=ecfg.obs_dim, mix=ecfg.mix,
                                       levels=ecfg.levels)
    opt = optim.Adam(lr=acfg.lr, clip_norm=1.0)
    if state is None:
        state = init_ac_search(env, ecfg, pcfg, acfg, opt)
    rollout = make_ac_rollout(ecfg, pcfg, env)
    E = acfg.episodes_per_epoch

    def a2c_loss(params, rolls, adv, ret):
        lps, vs, ents = jax.vmap(
            lambda o, a: eval_sequence(params, pcfg, o, a))(
                rolls.obs, rolls.actions)
        pl = -jnp.mean((lps * jax.lax.stop_gradient(adv)
                        * rolls.mask).sum(1))
        vl = jnp.mean((jnp.square(vs - ret) * rolls.mask).sum(1))
        el = jnp.mean((ents * rolls.mask).sum(1))
        return pl + acfg.value_coef * vl - acfg.entropy_coef * el

    def ppo_loss(params, rolls, adv, ret, logp_old):
        lps, vs, ents = jax.vmap(
            lambda o, a: eval_sequence(params, pcfg, o, a))(
                rolls.obs, rolls.actions)
        ratio = jnp.exp(lps - logp_old)
        adv_sg = jax.lax.stop_gradient(adv)
        un = ratio * adv_sg
        cl = jnp.clip(ratio, 1 - acfg.clip_eps, 1 + acfg.clip_eps) * adv_sg
        pl = -jnp.mean((jnp.minimum(un, cl) * rolls.mask).sum(1))
        vl = jnp.mean((jnp.square(vs - ret) * rolls.mask).sum(1))
        el = jnp.mean((ents * rolls.mask).sum(1))
        return pl + acfg.value_coef * vl - acfg.entropy_coef * el

    def epoch_fn(state, _):
        key, sub = jax.random.split(state.key)
        keys = jax.random.split(sub, E)
        rolls = jax.vmap(lambda k: rollout(state.params, state.pmin, k))(keys)
        adv = jax.vmap(lambda r, v, m: _gae(r, v, m, acfg.discount,
                                            acfg.gae_lambda))(
            rolls.rewards * rolls.mask, rolls.values * rolls.mask,
            rolls.mask)
        ret = adv + rolls.values * rolls.mask
        # Normalize advantages over valid steps.
        nv = jnp.maximum(rolls.mask.sum(), 1.0)
        am = (adv * rolls.mask).sum() / nv
        astd = jnp.sqrt((jnp.square(adv - am) * rolls.mask).sum() / nv)
        adv = (adv - am) / (astd + 1e-8) * rolls.mask

        params, opt_state = state.params, state.opt_state
        if acfg.algo == "a2c":
            grads = jax.grad(a2c_loss)(params, rolls, adv, ret)
            params, opt_state = opt.update(grads, opt_state, params)
        else:
            logp_old = jax.lax.stop_gradient(rolls.logps)
            for _ in range(acfg.ppo_updates):
                grads = jax.grad(ppo_loss)(params, rolls, adv, ret, logp_old)
                params, opt_state = opt.update(grads, opt_state, params)

        values = jnp.where(rolls.feasible, rolls.model_value, jnp.inf)
        i = jnp.argmin(values)
        better = values[i] < state.best_value
        pick = lambda new, old: jnp.where(better, new, old)
        new_state = reinforce.SearchState(
            params=params, opt_state=opt_state,
            pmin=jnp.min(rolls.pmin),
            best_value=jnp.where(better, values[i], state.best_value),
            best_pe_lvl=pick(rolls.actions[i, :, 0], state.best_pe_lvl),
            best_kt_lvl=pick(rolls.actions[i, :, 1], state.best_kt_lvl),
            best_df=pick(rolls.actions[i, :, 2], state.best_df),
            key=key, epoch=state.epoch + 1)
        metrics = {
            "best_value": new_state.best_value,
            "mean_value": jnp.mean(rolls.model_value),
            "feasible_frac": jnp.mean(rolls.feasible.astype(jnp.float32)),
        }
        return new_state, metrics

    @functools.partial(jax.jit, static_argnames=("n",))
    def scan_chunk(state, n):
        return jax.lax.scan(epoch_fn, state, None, length=n)

    def run_chunk(state, n):
        state, metrics = scan_chunk(state, n)
        return state, jax.tree.map(jax.device_get, metrics)

    state, history = chunk_lib.drive(
        state, acfg.epochs, chunk, run_chunk, on_chunk,
        engine=acfg.algo, evals_per_step=acfg.episodes_per_epoch)
    return state, chunk_lib.concat_hist_dict(history)
