"""ConfuciuX two-stage orchestration (Fig. 3): RL global search -> GA local
fine-tune, plus the LS per-layer analysis of SIV-B.

The launcher, examples and benchmarks now drive this through the unified
optimizer API (``repro.api``, method name "two_stage"); ``confuciux_search``
remains the underlying engine and a thin legacy entry point.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import env as env_lib
from repro.core import ga as ga_lib
from repro.core import policy as policy_lib
from repro.core import reinforce
from repro.costmodel import dataflows as dfl
from repro.costmodel import workloads as workloads_lib
from repro.kernels import ops as kops


@dataclasses.dataclass
class SearchResult:
    best_value: float                 # objective after both stages
    stage1_value: float               # after global RL search
    initial_valid_value: float        # first feasible value seen (Table VII)
    pe: np.ndarray                    # (N,) raw per-layer PE assignment
    kt: np.ndarray                    # (N,) raw per-layer tile counts
    df: np.ndarray                    # (N,) per-layer dataflow style
    history: Dict[str, np.ndarray]    # stage-1 convergence traces
    ga_history: np.ndarray            # stage-2 best-so-far trace
    wall_seconds: float
    epochs: int


def confuciux_search(workload, ecfg: env_lib.EnvConfig,
                     rcfg: reinforce.ReinforceConfig = None,
                     gcfg: ga_lib.LocalGAConfig = None,
                     pcfg: policy_lib.PolicyConfig = None,
                     fine_tune: bool = True,
                     chunk: int = 500,
                     on_chunk=None,
                     ga_chunk: Optional[int] = None,
                     ga_on_chunk=None) -> SearchResult:
    """Run the full two-stage ConfuciuX pipeline on a workload.

    chunk / on_chunk are forwarded to the stage-1 ``reinforce.run_search``
    so callers (the unified API) can stream global-search progress live;
    ga_chunk / ga_on_chunk do the same for the stage-2 local-GA fine-tune
    (``ga_lib.run_local_ga``), which makes stage 2 preemptible at chunk
    granularity too instead of one opaque scan.
    """
    if isinstance(workload, str):
        workload = workloads_lib.get_workload(workload)
    rcfg = rcfg or reinforce.ReinforceConfig()
    gcfg = gcfg or ga_lib.LocalGAConfig()
    t0 = time.time()

    state, hist = reinforce.run_search(workload, ecfg, rcfg, pcfg,
                                       chunk=chunk, on_chunk=on_chunk)
    env = env_lib.make_env(workload, ecfg)
    pe1, kt1, df1 = reinforce.solution_arrays(state, env)
    stage1 = float(state.best_value)
    finite = hist["best_value"][np.isfinite(hist["best_value"])]
    initial_valid = float(finite[0]) if len(finite) else float("inf")

    if fine_tune and np.isfinite(stage1):
        ga_state, ga_hist = ga_lib.run_local_ga(
            workload, ecfg, pe1, kt1, df1, gcfg, chunk=ga_chunk,
            on_chunk=ga_on_chunk, env=env)
        if float(ga_state.best_val) < stage1:
            pe = np.asarray(ga_state.best_genome[..., 0], np.float32)
            kt = np.asarray(ga_state.best_genome[..., 1], np.float32)
            df = np.asarray(df1)
            best = float(ga_state.best_val)
        else:  # GA never improves past the seed by construction, but guard.
            pe, kt, df, best = (np.asarray(pe1), np.asarray(kt1),
                                np.asarray(df1), stage1)
        ga_hist = np.asarray(ga_hist)
    else:
        pe, kt, df, best = (np.asarray(pe1), np.asarray(kt1),
                            np.asarray(df1), stage1)
        ga_hist = np.asarray([])

    return SearchResult(
        best_value=best, stage1_value=stage1,
        initial_valid_value=initial_valid,
        pe=pe, kt=kt, df=df, history=hist, ga_history=ga_hist,
        wall_seconds=time.time() - t0, epochs=rcfg.epochs)


def per_layer_optima(workload, ecfg: env_lib.EnvConfig,
                     use_kernel: bool = False):
    """SIV-B LS study: the full (L x L) action-pair sweep for every layer.

    Returns dict with the (N, L, L) latency/energy grids and per-layer argmin
    pairs -- the data behind Fig. 5's heatmaps.  One batched cost-model call
    evaluates all N * L * L cells.
    """
    if isinstance(workload, str):
        workload = workloads_lib.get_workload(workload)
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    L = ecfg.levels
    pe_g, kt_g = jnp.meshgrid(env.pe_table, env.kt_table, indexing="ij")
    # (L*L, N) design batch: same pair applied to each layer independently.
    pe = jnp.tile(pe_g.reshape(-1, 1), (1, N))
    kt = jnp.tile(kt_g.reshape(-1, 1), (1, N))
    layers = env.layers
    lat, en, area, power = kops.batched_cost(layers, pe, kt,
                                             float(ecfg.dataflow),
                                             use_kernel=use_kernel)
    lat = np.asarray(lat).reshape(L, L, N).transpose(2, 0, 1)
    en = np.asarray(en).reshape(L, L, N).transpose(2, 0, 1)
    area = np.asarray(area).reshape(L, L, N).transpose(2, 0, 1)
    feasible = area <= float(env.budget)
    masked_lat = np.where(feasible, lat, np.inf)
    masked_en = np.where(feasible, en, np.inf)
    opt_lat = np.array([np.unravel_index(np.argmin(m), m.shape)
                        for m in masked_lat])
    opt_en = np.array([np.unravel_index(np.argmin(m), m.shape)
                       for m in masked_en])
    return {"latency": lat, "energy": en, "area": area,
            "optima_latency": opt_lat, "optima_energy": opt_en,
            "pe_table": np.asarray(env.pe_table),
            "kt_table": np.asarray(env.kt_table)}


def heuristic_a(workload, ecfg: env_lib.EnvConfig) -> Dict[str, Any]:
    """Fig. 5 'Heuristic A': tune on the most compute-intensive layer, apply
    that (PE, Buf) pair to every layer."""
    grids = per_layer_optima(workload, ecfg)
    if isinstance(workload, str):
        workload = workloads_lib.get_workload(workload)
    macs = np.array([l.macs() for l in workload])
    hot = int(np.argmax(macs))
    key = "optima_latency" if ecfg.objective == "latency" else "optima_energy"
    pi, ki = grids[key][hot]
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    pe = jnp.full((N,), env.pe_table[pi])
    kt = jnp.full((N,), env.kt_table[ki])
    perf, cons, feas = env_lib.genome_cost(env, ecfg, pe, kt, ecfg.dataflow)
    return {"value": float(perf) if bool(feas) else float("inf"),
            "pe": np.asarray(pe), "kt": np.asarray(kt),
            "hot_layer": hot}


def heuristic_b(workload, ecfg: env_lib.EnvConfig) -> Dict[str, Any]:
    """Fig. 5 'Heuristic B': the single uniform (PE, Buf) pair that optimizes
    the end-to-end whole-model objective."""
    if isinstance(workload, str):
        workload = workloads_lib.get_workload(workload)
    env = env_lib.make_env(workload, ecfg)
    N = env.num_layers
    L = ecfg.levels
    pe_g, kt_g = jnp.meshgrid(env.pe_table, env.kt_table, indexing="ij")
    pe = jnp.tile(pe_g.reshape(-1, 1), (1, N))
    kt = jnp.tile(kt_g.reshape(-1, 1), (1, N))
    perf, cons, feas = env_lib.genome_cost(env, ecfg, pe, kt, ecfg.dataflow)
    fit = np.asarray(jnp.where(feas, perf, jnp.inf))
    i = int(fit.argmin())
    return {"value": float(fit[i]), "pe": np.asarray(pe[i]),
            "kt": np.asarray(kt[i])}


def scalarized_frontier_sweep(workload, ecfg: env_lib.EnvConfig,
                              eps: int, weights=(0.0, 0.25, 0.5, 0.75, 1.0),
                              method: str = "ga", seed: int = 0,
                              options: Optional[Dict[str, Any]] = None):
    """Approximate the latency-energy frontier with k scalarized searches.

    The classic alternative to native multi-objective search: split the
    eval budget across ``len(weights)`` single-objective runs, each
    minimizing the blended objective ``lat^w * en^(1-w)`` (a weighted sum
    in log space -- every minimizer is Pareto-optimal), and collect the
    feasible (lat, en, area, pw) points the winners realize.  Any
    single-objective registry method works; this is the baseline
    ``benchmarks/bench_frontier.py`` pits NSGA-II against at equal budget.

    Returns ``{"points": (k', 4) array, "weights", "outcomes"}`` with one
    row per *feasible* winner (k' <= k).
    """
    from repro import api   # lazy: api itself imports this module

    if isinstance(workload, str):
        workload = workloads_lib.get_workload(workload)
    env = env_lib.make_env(workload, ecfg)
    per_run = max(eps // len(weights), 1)
    points, outcomes = [], []
    for w in weights:
        wcfg = dataclasses.replace(ecfg, objective="blend", blend_weight=w)
        out = api.run_search(api.SearchRequest(
            workload=workload, env=wcfg, eps=per_run, seed=seed,
            method=method, options=dict(options or {})))
        outcomes.append(out)
        if not out.feasible:
            continue
        tl, te, ta, tp, feas = env_lib.genome_costs_multi(
            env, wcfg, jnp.asarray(out.pe, jnp.float32),
            jnp.asarray(out.kt, jnp.float32),
            jnp.asarray(out.df))
        if bool(feas):
            points.append([float(tl), float(te), float(ta), float(tp)])
    pts = (np.asarray(points, np.float64) if points
           else np.empty((0, 4), np.float64))
    return {"points": pts, "weights": list(weights), "outcomes": outcomes}
