#!/usr/bin/env python
"""Validate telemetry artifacts: a JSONL span trace + a Prometheus snapshot.

    python tools/check_telemetry.py --trace telemetry/trace.jsonl \
        --metrics telemetry/metrics.prom

Checks, exiting nonzero on the first failure:

  * the trace parses line-by-line as JSON objects carrying the span schema
    (``name``/``ts_us``/``dur_us``/``tid``/``depth``) with non-negative
    durations and known span names (the taxonomy in
    ``repro.obs.instrument.SPAN_NAMES`` plus ``xla.dispatch`` program
    spans);
  * the metrics file is well-formed Prometheus text exposition: every
    sample is preceded by ``# HELP`` / ``# TYPE`` comments for its metric,
    sample lines match ``name{labels} value``, histogram ``_bucket``
    series are cumulative in ``le`` and end with ``+Inf`` equal to
    ``_count``;
  * (optional) ``--require-spans`` / ``--require-metrics`` assert that
    specific span names / metric names actually occur.

Run after an instrumented search (``--trace-out`` / ``--metrics-out`` on
``repro.launch.search``) -- CI does exactly that and uploads the artifacts.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

SPAN_REQUIRED_KEYS = ("name", "ts_us", "dur_us", "tid", "depth")

# name{labels} value  -- labels optional; value is any float repr.  The
# labels group is greedy up to the LAST closing brace: label values may
# themselves contain braces (e.g. route="/v1/search/{uid}").
_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?P<labels>\{.*\})?'
    r' (?P<value>[0-9eE+.inf-]+)$')
_LABEL_RE = re.compile(r'(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>[^"]*)"')


def fail(msg: str) -> None:
    print(f"check_telemetry: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_trace(path: str, require_spans) -> int:
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    if not lines:
        fail(f"{path}: empty trace")
    seen = set()
    for i, ln in enumerate(lines, 1):
        try:
            rec = json.loads(ln)
        except json.JSONDecodeError as e:
            fail(f"{path}:{i}: not JSON: {e}")
        if not isinstance(rec, dict):
            fail(f"{path}:{i}: span record is not an object")
        for k in SPAN_REQUIRED_KEYS:
            if k not in rec:
                fail(f"{path}:{i}: span missing key {k!r}: {rec}")
        if rec["dur_us"] < 0:
            fail(f"{path}:{i}: negative duration: {rec}")
        if rec["depth"] < 0:
            fail(f"{path}:{i}: negative depth: {rec}")
        seen.add(rec["name"])
    for name in require_spans:
        if name not in seen:
            fail(f"{path}: required span {name!r} never recorded "
                 f"(saw: {sorted(seen)})")
    print(f"check_telemetry: {path}: {len(lines)} spans OK "
          f"({len(seen)} distinct names)")
    return len(lines)


def _parse_value(s: str) -> float:
    if s == "+Inf":
        return float("inf")
    return float(s)


def check_metrics(path: str, require_metrics) -> int:
    with open(path) as f:
        lines = f.read().splitlines()
    if not lines:
        fail(f"{path}: empty metrics file")
    helped, typed = set(), {}
    samples = []   # (name, labels dict, value)
    for i, ln in enumerate(lines, 1):
        if not ln.strip():
            continue
        if ln.startswith("# HELP "):
            helped.add(ln.split()[2])
            continue
        if ln.startswith("# TYPE "):
            parts = ln.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                fail(f"{path}:{i}: malformed TYPE line: {ln!r}")
            typed[parts[2]] = parts[3]
            continue
        if ln.startswith("#"):
            continue
        m = _SAMPLE_RE.match(ln)
        if m is None:
            fail(f"{path}:{i}: malformed sample line: {ln!r}")
        labels = {}
        if m.group("labels"):
            labels = {g.group("k"): g.group("v")
                      for g in _LABEL_RE.finditer(m.group("labels"))}
        try:
            value = _parse_value(m.group("value"))
        except ValueError:
            fail(f"{path}:{i}: bad sample value: {ln!r}")
        samples.append((m.group("name"), labels, value))

    if not samples:
        fail(f"{path}: no samples")

    # Every sample must belong to a declared metric (sample name == the
    # declared name or declared name + _total/_bucket/_sum/_count).
    def base_of(name: str):
        for base in typed:
            if name == base or (name.startswith(base) and name[len(base):]
                                in ("_total", "_bucket", "_sum", "_count")):
                return base
        return None

    for name, _, _ in samples:
        base = base_of(name)
        if base is None:
            fail(f"{path}: sample {name!r} has no # TYPE declaration")
        if base not in helped:
            fail(f"{path}: metric {base!r} has no # HELP line")

    # Histogram buckets: cumulative in le, +Inf present and == _count.
    hists = {n for n, k in typed.items() if k == "histogram"}
    for h in hists:
        series = {}   # non-le labels -> [(le, v)]
        counts = {}
        for name, labels, v in samples:
            if name == f"{h}_bucket":
                le = labels.get("le")
                if le is None:
                    fail(f"{path}: {name} sample missing le label")
                key = tuple(sorted((k, lv) for k, lv in labels.items()
                                   if k != "le"))
                series.setdefault(key, []).append((_parse_value(le), v))
            elif name == f"{h}_count":
                key = tuple(sorted(labels.items()))
                counts[key] = v
        for key, buckets in series.items():
            buckets.sort(key=lambda t: t[0])
            values = [v for _, v in buckets]
            if values != sorted(values):
                fail(f"{path}: {h}{dict(key)}: buckets not cumulative")
            if buckets[-1][0] != float("inf"):
                fail(f"{path}: {h}{dict(key)}: no +Inf bucket")
            if key in counts and buckets[-1][1] != counts[key]:
                fail(f"{path}: {h}{dict(key)}: +Inf bucket "
                     f"{buckets[-1][1]} != _count {counts[key]}")

    for name in require_metrics:
        if not any(base_of(n) == name for n, _, _ in samples):
            fail(f"{path}: required metric {name!r} has no samples")
    print(f"check_telemetry: {path}: {len(samples)} samples across "
          f"{len(typed)} metrics OK")
    return len(samples)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default="",
                    help="JSONL span trace to validate")
    ap.add_argument("--metrics", default="",
                    help="Prometheus text exposition file to validate")
    ap.add_argument("--require-spans", default="",
                    help="comma list of span names that must appear")
    ap.add_argument("--require-metrics", default="",
                    help="comma list of metric names that must have samples")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to check: pass --trace and/or --metrics")
    if args.trace:
        check_trace(args.trace,
                    [s for s in args.require_spans.split(",") if s])
    if args.metrics:
        check_metrics(args.metrics,
                      [s for s in args.require_metrics.split(",") if s])
    print("check_telemetry: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
