#!/usr/bin/env python
"""CI smoke for the HTTP search front door.

    PYTHONPATH=src python tools/serve_http_smoke.py \
        --metrics-out telemetry/http_metrics.prom

Boots a real ``SearchHTTPService`` (ephemeral port) with a persistent
cost cache, drives two tenants over the wire -- a GA search and a random
search -- and asserts the production properties end to end:

  * both tenants' jobs complete over HTTP with full-length histories and
    zero admission rejections (fair completion, no starvation);
  * per-tenant accounting in ``/v1/stats`` adds up (submitted ==
    completed, eps_finished == eps_requested);
  * the persistent cache left shard files on disk after close;
  * the live ``/metrics`` endpoint serves Prometheus text, saved to
    ``--metrics-out`` for ``tools/check_telemetry.py`` to validate.

Exits nonzero on the first violated assertion.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

from repro import obs
from repro.serving import (HttpConfig, SearchClient, SearchHTTPService,
                           SearchService, ServiceConfig)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cache-dir", default="",
                    help="persistent cache root (default: a temp dir)")
    ap.add_argument("--metrics-out", default="telemetry/http_metrics.prom",
                    help="write the live /metrics exposition here")
    ap.add_argument("--eps", type=int, default=200)
    args = ap.parse_args(argv)

    cache_dir = args.cache_dir or tempfile.mkdtemp(prefix="repro-http-")
    obs.enable(trace=True)
    svc = SearchService(ServiceConfig(max_workers=2, cache_dir=cache_dir))
    hub = SearchHTTPService(
        http_cfg=HttpConfig(port=0, max_queue=16,
                            tenant_weights=(("ga", 1), ("rand", 1))),
        service=svc).start()
    print(f"smoke server on {hub.url}, cache at {cache_dir}", flush=True)
    try:
        client = SearchClient(port=hub.port)
        uids = {
            "ga": client.submit({"workload": "ncf", "method": "ga",
                                 "eps": args.eps, "seed": 0,
                                 "population": 20, "tenant": "ga"})["uid"],
            "rand": client.submit({"workload": "ncf", "method": "random",
                                   "eps": args.eps, "seed": 1,
                                   "tenant": "rand"})["uid"],
        }
        outs = {t: client.result(u, timeout=600) for t, u in uids.items()}
        for t, out in outs.items():
            assert len(out["history"]) == args.eps, \
                f"{t}: history {len(out['history'])} != eps {args.eps}"
            print(f"  tenant {t}: method={out['method']} "
                  f"best={out['best_value']:.4e} "
                  f"feasible={out['feasible']}", flush=True)

        st = client.stats()
        tenants = st["front_door"]["tenants"]
        for t in ("ga", "rand"):
            e = tenants[t]
            assert e["completed"] == 1 and e["rejected"] == 0, (t, e)
            assert e["eps_finished"] == e["eps_requested"] == args.eps, e
        assert st["service"]["completed"] == 2, st["service"]
        assert st["service"]["cache_entries"] > 0, st["service"]

        text = client.metrics_text()
        assert "repro_http_requests" in text
        out_dir = os.path.dirname(args.metrics_out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(args.metrics_out, "w") as f:
            f.write(text)
        print(f"wrote {args.metrics_out}", flush=True)
    finally:
        hub.close()
        svc.close()

    # The persistent cache must have flushed shards on close.
    version_dirs = os.listdir(cache_dir)
    assert version_dirs, f"no version namespace under {cache_dir}"
    shards = [n for n in os.listdir(os.path.join(cache_dir,
                                                 version_dirs[0]))
              if n.startswith("shard-") and n.endswith(".bin")]
    assert shards, f"no shard files under {cache_dir}/{version_dirs[0]}"
    print(json.dumps({"tenants": {t: tenants[t]["completed"]
                                  for t in ("ga", "rand")},
                      "cache_entries": st["service"]["cache_entries"],
                      "shards": len(shards)}), flush=True)
    print("serve_http_smoke: OK", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
