#!/usr/bin/env python
"""Docs checker: intra-repo links + executable ``python`` blocks.

    PYTHONPATH=src python tools/check_docs.py [--links-only]

Two checks, both enforced in CI (the ``docs`` job) and in tier-1
(tests/test_docs.py):

1. **Links.** Every markdown link in README.md, docs/*.md and results/*.md
   that points inside the repo must resolve to an existing file (anchors
   are stripped; http(s)/mailto links are ignored).
2. **Doctests.** Every fenced ``` ```python ``` ``` block in docs/*.md runs,
   in file order, in ONE shared namespace per file (notebook-style, so
   later blocks may use earlier imports/variables).  Blocks tagged
   ``` ```python no-run ``` ``` are skipped.  A failing assert or exception
   fails the check -- documented API behavior cannot silently drift.
"""
from __future__ import annotations

import argparse
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\S*)\s*(.*)$")


def _md_files():
    files = [os.path.join(REPO, "README.md")]
    for sub in ("docs", "results"):
        d = os.path.join(REPO, sub)
        if os.path.isdir(d):
            files.extend(os.path.join(d, f) for f in sorted(os.listdir(d))
                         if f.endswith(".md"))
    return [f for f in files if os.path.isfile(f)]


def check_links() -> list:
    errors = []
    for path in _md_files():
        base = os.path.dirname(path)
        rel = os.path.relpath(path, REPO)
        with open(path) as f:
            text = f.read()
        # Drop fenced code blocks -- link syntax inside code is not a link.
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            target = target.split("#")[0]
            if not target:
                continue
            resolved = os.path.normpath(os.path.join(base, target))
            if not os.path.exists(resolved):
                errors.append(f"{rel}: broken link -> {m.group(1)}")
    return errors


def _python_blocks(path: str):
    """Yield (start_line, code) for runnable ```python fences."""
    blocks = []
    in_block, tag, buf, start = False, "", [], 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            m = FENCE_RE.match(line.strip())
            if m and not in_block:
                in_block, tag, buf, start = True, " ".join(
                    x for x in (m.group(1), m.group(2)) if x), [], lineno + 1
            elif m and in_block:
                if tag.split()[0:1] == ["python"] and "no-run" not in tag:
                    blocks.append((start, "".join(buf)))
                in_block = False
            elif in_block:
                buf.append(line)
    return blocks


def run_doctests() -> list:
    errors = []
    docs_dir = os.path.join(REPO, "docs")
    if not os.path.isdir(docs_dir):
        return errors
    for fname in sorted(os.listdir(docs_dir)):
        if not fname.endswith(".md"):
            continue
        path = os.path.join(docs_dir, fname)
        blocks = _python_blocks(path)
        if not blocks:
            continue
        ns = {"__name__": f"docs.{fname}"}
        for start, code in blocks:
            print(f"  running docs/{fname}:{start} "
                  f"({len(code.splitlines())} lines)", flush=True)
            try:
                exec(compile(code, f"docs/{fname}:{start}", "exec"), ns)
            except Exception as e:  # noqa: BLE001
                errors.append(f"docs/{fname}:{start}: {type(e).__name__}: "
                              f"{e}")
                break   # later blocks in this file depend on earlier ones
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--links-only", action="store_true",
                    help="skip executing the docs' python blocks")
    args = ap.parse_args(argv)

    errors = check_links()
    n_files = len(_md_files())
    print(f"checked links in {n_files} markdown files: "
          f"{len(errors)} broken")
    if not args.links_only:
        errors += run_doctests()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print("docs check " + ("FAILED" if errors else "OK"))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
