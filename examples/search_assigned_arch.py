"""ConfuciuX on an assigned architecture: the paper's technique applied to
an LLM serving workload.

    PYTHONPATH=src python examples/search_assigned_arch.py \
        --arch qwen3-32b --tokens 512 [--mix]

The architecture config is lowered to its per-layer GEMM descriptor list
(QKV/O projections, FFN matmuls, attention score/context batched GEMMs --
exactly the paper's (M,N,K) observation encoding for GEMM layers), and the
two-stage search -- via the unified optimizer API -- assigns
(PE, Buffer[, dataflow]) per layer under the platform budget.
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro import api                                      # noqa: E402
from repro.costmodel import arch_workloads                 # noqa: E402
from repro.costmodel import dataflows as dfl               # noqa: E402
from repro.costmodel.layers import total_macs              # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--tokens", type=int, default=512)
    ap.add_argument("--platform", default="cloud")
    ap.add_argument("--epochs", type=int, default=1000)
    ap.add_argument("--mix", action="store_true",
                    help="co-automate the per-layer dataflow style")
    args = ap.parse_args()

    wl = arch_workloads.lower_arch(args.arch, tokens=args.tokens)
    print(f"{args.arch}: {len(wl)} layer descriptors, "
          f"{total_macs(wl)/1e9:.1f} GMACs @ {args.tokens} tokens")

    episodes = 4
    out = api.run_search(api.SearchRequest(
        workload=wl,
        env=api.EnvConfig(objective="latency", constraint="area",
                          platform=args.platform, mix=args.mix),
        eps=args.epochs * episodes,
        method="two_stage",
        options={"episodes_per_epoch": episodes}))

    print(f"\nbest latency: {out.best_value:.3e} cycles "
          f"(stage1 {out.extras['stage1_value']:.3e}) "
          f"in {out.wall_seconds:.1f}s")
    print("\nassignment by layer group:")
    seen = {}
    for i, l in enumerate(wl):
        group = (l.name or f"layer{i}").split(".")[-1]
        key = (group, int(out.pe[i]), int(out.kt[i]), int(out.df[i]))
        seen[key] = seen.get(key, 0) + 1
    for (group, pe, kt, df), n in sorted(seen.items()):
        print(f"  {group:20s} x{n:3d}  PE={pe:4d} kt={kt:3d} "
              f"df={dfl.DATAFLOW_NAMES[df]}")
    assert np.isfinite(out.best_value)


if __name__ == "__main__":
    main()
