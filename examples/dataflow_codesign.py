"""Dataflow-HW co-automation (Table VI / Fig. 8): fixed styles vs MIX.

    PYTHONPATH=src python examples/dataflow_codesign.py [--epochs 800]

Runs Con'X(global) with each fixed dataflow style and with the MIX agent
(third per-layer action choosing the style) -- all through the one
registered "reinforce" optimizer, varying only the EnvConfig -- then prints
the converged values and the per-layer style choices the MIX agent made,
reproducing the paper's observation that early layers favour eye/shi
(activation parallelism) and late layers favour dla (channel parallelism).
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro import api                                      # noqa: E402
from repro.costmodel import dataflows as dfl               # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=800)
    ap.add_argument("--workload", default="mobilenet_v2")
    args = ap.parse_args()

    episodes = 4
    eps = args.epochs * episodes
    opts = {"episodes_per_epoch": episodes}

    results = {}
    for name in ("dla", "eye", "shi"):
        out = api.run_search(api.SearchRequest(
            workload=args.workload,
            env=api.EnvConfig(platform="iot",
                              dataflow=dfl.DATAFLOW_NAMES.index(name)),
            eps=eps, method="reinforce", options=opts))
        results[name] = out.best_value
        print(f"Con'X-{name}: {out.best_value:.3e} cycles")

    mix = api.run_search(api.SearchRequest(
        workload=args.workload, env=api.EnvConfig(platform="iot", mix=True),
        eps=eps, method="reinforce", options=opts))
    results["MIX"] = mix.best_value
    best_fixed = min(v for k, v in results.items() if k != "MIX")
    print(f"Con'X-MIX: {mix.best_value:.3e} cycles "
          f"({100*(1-mix.best_value/best_fixed):+.1f}% vs best fixed)")

    print("\nMIX per-layer dataflow choices:")
    row = "".join(dfl.DATAFLOW_NAMES[int(d)][0] for d in mix.df)
    print(f"  {row}   (d=dla, e=eye, s=shi; layer 0 -> {len(mix.df) - 1})")
    assert np.isfinite(mix.best_value)


if __name__ == "__main__":
    main()
