"""Elastic fault-tolerant restart: train on one mesh, crash, resume on a
DIFFERENT mesh — bit-identical batches, re-sharded state.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_restart.py

Demonstrates the production failure story end to end:
  1. a 4-device (2 data x 2 model) job trains 20 steps and checkpoints;
  2. the job "loses half its slice" -- we restart on a 2-device (2x1)
     mesh; `checkpoint.restore` re-shards every leaf onto the new mesh;
  3. the job "scales out" to 8 devices (4x2) and resumes again;
  4. the deterministic data pipeline (batch = f(step)) plus the restored
     optimizer state make the loss trajectory continue exactly where it
     left off -- verified against an uninterrupted single-mesh run.
"""
import functools
import os
import shutil
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.distributed import sharding  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.training import checkpoint, data, optim  # noqa: E402

CKPT = "/tmp/repro_elastic_ckpt"
STEPS = (20, 30, 40)   # checkpoint boundaries: mesh changes at each


def train_segment(mesh_shape, start, stop, dcfg, cfg, opt, resume):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    psh = sharding.tree_shardings(mesh, params)
    params = jax.device_put(params, psh)
    opt_state = jax.device_put(opt_state,
                               sharding.tree_shardings(mesh, opt_state))
    if resume:
        (params, opt_state), at, _ = checkpoint.restore(
            CKPT, (params, opt_state))
        assert at == start, (at, start)
    pol = sharding.make_policy(mesh, batch=dcfg.global_batch, kind="train")
    bsh = sharding.batch_sharding(mesh, dcfg.global_batch)
    step_fn = jax.jit(functools.partial(lm.train_step, cfg=cfg,
                                        optimizer=opt, pol=pol),
                      donate_argnums=(0, 1))
    ds = data.make_dataset(dcfg)
    losses = []
    with mesh:
        for step in range(start, stop):
            batch = data.device_batch(ds.batch(step), bsh)
            params, opt_state, loss = step_fn(params, opt_state, batch)
            losses.append(float(loss))
    checkpoint.save(CKPT, stop, (params, opt_state))
    return losses


def main():
    n = len(jax.devices())
    assert n >= 8, ("run with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8")
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke("qwen1p5_0p5b"),
                              param_dtype="float32",
                              compute_dtype="float32")
    opt = optim.Adam(lr=1e-3)
    dcfg = data.DataConfig(seq_len=64, global_batch=8,
                           vocab_size=cfg.vocab_size)
    shutil.rmtree(CKPT, ignore_errors=True)

    print("segment 1: (2,2) mesh, steps 0-20")
    l1 = train_segment((2, 2), 0, STEPS[0], dcfg, cfg, opt, resume=False)
    print("segment 2: SHRINK to (2,1), steps 20-30  (node failure)")
    l2 = train_segment((2, 1), STEPS[0], STEPS[1], dcfg, cfg, opt,
                       resume=True)
    print("segment 3: GROW to (4,2), steps 30-40  (scale out)")
    l3 = train_segment((4, 2), STEPS[1], STEPS[2], dcfg, cfg, opt,
                       resume=True)
    elastic = l1 + l2 + l3

    print("reference: uninterrupted (2,2) run, steps 0-40")
    shutil.rmtree(CKPT, ignore_errors=True)
    ref = train_segment((2, 2), 0, STEPS[2], dcfg, cfg, opt, resume=False)

    d = float(np.max(np.abs(np.asarray(elastic) - np.asarray(ref))))
    print(f"\nmax |elastic - uninterrupted| loss delta over 40 steps: "
          f"{d:.2e}")
    assert d < 5e-3, d
    print("ELASTIC RESTART OK: the resharded runs reproduce the "
          "uninterrupted trajectory")


if __name__ == "__main__":
    main()
