"""Batched serving example: bucketed prefill + lockstep decode on any
assigned architecture family (dense / MoE / SSM / hybrid).

    PYTHONPATH=src python examples/serve_batched.py [--arch mamba2_130m]

Spins up the serving engine on the reduced (smoke) config, submits a mixed
stream of synthetic requests with two prompt lengths, and reports
throughput.  Works identically for attention KV caches and SSM state
caches -- the engine is family-agnostic.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2p5_3b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    return serve.main(["--arch", args.arch, "--smoke", "--f32",
                       "--requests", str(args.requests),
                       "--max-new", str(args.max_new)])


if __name__ == "__main__":
    sys.exit(main())
