"""Search-as-a-service: many users, one accelerator, one memo cache.

    PYTHONPATH=src python examples/search_service.py [--users 6]

Submits a mix of "user" searches -- different methods, two popular
workloads, a couple of identical resubmissions -- to one
:class:`repro.serving.SearchService` and streams their progress as it
interleaves.  At the end it prints each user's outcome plus the service
stats: how many cost evaluations the cross-request batcher fused away and
the memo-cache hit rate.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro import api                                      # noqa: E402
from repro.serving import SearchService, ServiceConfig     # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=6)
    ap.add_argument("--eps", type=int, default=400)
    args = ap.parse_args()

    workloads = ("ncf", "mobilenet_v2")
    methods = ("random", "grid", "bo", "reinforce")

    def on_progress(uid):
        return lambda t: print(
            f"  user{uid}: step={t.step} best={t.best_value:.4e}",
            flush=True)

    t0 = time.time()
    with SearchService(ServiceConfig(max_workers=args.users)) as svc:
        tickets = []
        for u in range(args.users):
            tickets.append(svc.submit(api.SearchRequest(
                workload=workloads[u % 2],
                env=api.EnvConfig(platform="cloud"),
                eps=args.eps,
                seed=u // 2,                 # pairs of users share a query
                method=methods[u % 4],
                on_progress=on_progress(u),
                progress_every=args.eps // 3)))
        outs = [t.result() for t in tickets]
        stats = svc.stats()

    print(f"\n{args.users} searches in {time.time() - t0:.1f}s")
    for u, (t, out) in enumerate(zip(tickets, outs)):
        print(f"  user{u}: {out.method:10s} {str(t.request.workload):14s} "
              f"best={out.best_value:.4e} wall={t.wall_seconds:.1f}s")
    print(f"\nbatcher: {stats['dispatches']} dispatches, "
          f"{stats['fused_dispatches']} fused, "
          f"peak {stats['max_items_per_dispatch']} reqs/dispatch")
    print(f"cache:   {stats['cache_hits']} hits / "
          f"{stats['cache_misses']} misses "
          f"(hit rate {stats['cache_hit_rate']:.0%}), "
          f"{stats['fresh_points']} fresh evals "
          f"for {stats['points']} requested points")


if __name__ == "__main__":
    main()
