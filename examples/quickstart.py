"""Quickstart: ConfuciuX on MobileNet-V2 under an IoT area budget.

    PYTHONPATH=src python examples/quickstart.py [--epochs 1500]

Runs the full two-stage pipeline (Fig. 3) -- REINFORCE global search then
local-GA fine-tune -- on the paper's headline workload with NVDLA-style
dataflow, then prints the per-layer (PE, Buffer) assignment and the
improvement breakdown (the Table VII columns).
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core import env as env_lib                      # noqa: E402
from repro.core import ga as ga_lib                        # noqa: E402
from repro.core import reinforce, search                   # noqa: E402
from repro.costmodel import workloads                      # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=1500)
    ap.add_argument("--episodes", type=int, default=4,
                    help="vmapped episodes/epoch (1 = paper-faithful)")
    args = ap.parse_args()

    wl = workloads.mobilenet_v2()
    ecfg = env_lib.EnvConfig(objective="latency", constraint="area",
                             platform="iot", scenario="LP")
    res = search.confuciux_search(
        wl, ecfg,
        rcfg=reinforce.ReinforceConfig(epochs=args.epochs,
                                       episodes_per_epoch=args.episodes),
        gcfg=ga_lib.LocalGAConfig(generations=500))

    print(f"\nMobileNet-V2 / NVDLA-style / IoT area budget "
          f"(objective: latency, {args.epochs} epochs)")
    print(f"  first feasible value : {res.initial_valid_value:.3e} cycles")
    s1 = 100 * (1 - res.stage1_value / res.initial_valid_value)
    s2 = 100 * (1 - res.best_value / res.stage1_value)
    print(f"  after RL global      : {res.stage1_value:.3e}  (-{s1:.1f}%)")
    print(f"  after GA fine-tune   : {res.best_value:.3e}  (-{s2:.1f}%)")
    print(f"  wall time            : {res.wall_seconds:.1f}s\n")

    print("per-layer assignment (first 12 layers):")
    print(f"  {'layer':24s} {'PE':>4s} {'Buf(kt)':>8s}")
    for i in range(min(12, len(wl))):
        print(f"  {wl[i].name:24s} {int(res.pe[i]):4d} {int(res.kt[i]):8d}")
    print(f"  ... ({len(wl)} layers total)")
    assert np.isfinite(res.best_value)


if __name__ == "__main__":
    main()
