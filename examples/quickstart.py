"""Quickstart: ConfuciuX on MobileNet-V2 under an IoT area budget.

    PYTHONPATH=src python examples/quickstart.py [--epochs 1500]

Runs the full two-stage pipeline (Fig. 3) -- REINFORCE global search then
local-GA fine-tune -- through the unified optimizer API, then prints the
per-layer (PE, Buffer) assignment and the improvement breakdown (the
Table VII columns).  Swap ``--method`` for any registered optimizer
(ga, sa, bo, random, grid, a2c, ppo2, ...) to compare under the exact same
request/outcome schema.
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro import api                                      # noqa: E402
from repro.costmodel import workloads                      # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=1500)
    ap.add_argument("--episodes", type=int, default=4,
                    help="vmapped episodes/epoch (1 = paper-faithful)")
    ap.add_argument("--method", default="two_stage",
                    help=f"one of {', '.join(api.list_optimizers())}")
    args = ap.parse_args()

    wl = workloads.mobilenet_v2()
    options = {"episodes_per_epoch": args.episodes}
    if args.method == "two_stage":
        options["ga"] = {"generations": 500}
    out = api.run_search(api.SearchRequest(
        workload=wl,
        env=api.EnvConfig(objective="latency", constraint="area",
                          platform="iot", scenario="LP"),
        eps=args.epochs * args.episodes,
        method=args.method,
        options=options))

    if not out.feasible:
        print(f"\n{out.method}: no feasible point within eps={out.eps} "
              "under the IoT area budget (the paper's NAN)")
        sys.exit(1)

    print(f"\nMobileNet-V2 / NVDLA-style / IoT area budget "
          f"(objective: latency, method: {out.method}, eps: {out.eps})")
    if out.method == "two_stage":
        initial = out.extras["initial_valid_value"]
        stage1 = out.extras["stage1_value"]
        s1 = 100 * (1 - stage1 / initial)
        s2 = 100 * (1 - out.best_value / stage1)
        print(f"  first feasible value : {initial:.3e} cycles")
        print(f"  after RL global      : {stage1:.3e}  (-{s1:.1f}%)")
        print(f"  after GA fine-tune   : {out.best_value:.3e}  (-{s2:.1f}%)")
    else:
        print(f"  best value           : {out.best_value:.3e} cycles")
    print(f"  samples to converge  : {out.samples_to_convergence}")
    print(f"  wall time            : {out.wall_seconds:.1f}s\n")

    print("per-layer assignment (first 12 layers):")
    print(f"  {'layer':24s} {'PE':>4s} {'Buf(kt)':>8s}")
    for i in range(min(12, len(wl))):
        print(f"  {wl[i].name:24s} {int(out.pe[i]):4d} {int(out.kt[i]):8d}")
    print(f"  ... ({len(wl)} layers total)")
    assert np.isfinite(out.best_value)


if __name__ == "__main__":
    main()
