"""End-to-end training driver: train a ~100M-parameter LM for a few hundred
steps with checkpointing, through the framework's public launcher.

    # full run (~100M params, 300 steps; several hours on CPU, minutes on TPU)
    PYTHONPATH=src python examples/train_lm_e2e.py

    # quick CI-sized variant (~5M params, 60 steps, <2 min on CPU)
    PYTHONPATH=src python examples/train_lm_e2e.py --quick

    # sharded over a simulated 8-device (4 data x 2 model) mesh:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/train_lm_e2e.py --quick --mesh 4x2

The driver demonstrates the production loop end to end: config -> mesh ->
sharded init -> deterministic data -> jitted accumulated train step ->
atomic async checkpoints -> resume.  Loss must decrease or the process
exits nonzero.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    if args.quick:
        argv = ["--arch", "qwen1p5_0p5b", "--smoke", "--steps", "60",
                "--batch", "8", "--seq", "128", "--micro", "2", "--f32"]
    else:
        # qwen1.5-0.5b at seq 512: ~0.5B params -- the nearest assigned
        # config; --smoke-free 100M-class run uses the published config with
        # a few hundred steps as the brief's end-to-end driver.
        argv = ["--arch", "qwen1p5_0p5b", "--steps", "300",
                "--batch", "8", "--seq", "512", "--micro", "4"]
    argv += ["--mesh", args.mesh, "--ckpt-dir", args.ckpt_dir,
             "--ckpt-every", "50"]
    rc = train.main(argv)
    if rc == 0:
        print("E2E TRAIN OK: loss decreased, checkpoints written to",
              args.ckpt_dir)
    return rc


if __name__ == "__main__":
    sys.exit(main())
