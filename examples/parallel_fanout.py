"""Parallel fanout search: n seeds of any optimizer, three backends.

    # 4 local "devices" so the in-graph backend has something to map onto:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/parallel_fanout.py --backend device

The ``fanout`` optimizer runs ``n_shards`` independent searches with
distinct seeds and merges the ensemble (best value wins; the trace is the
elementwise min -- the wall-clock view of n workers).  The ``backend``
option picks how the shards actually execute:

  * ``device``  -- one shard per local JAX device, the whole fleet fused
                   into a single shard_map'd XLA program (reinforce / ga)
  * ``threads`` -- one host thread per shard, any inner method
  * ``serial``  -- the debugging loop
  * ``auto``    -- device if possible, else threads

All backends return bit-identical outcomes for the same seeds, so the
choice is purely about wall-clock.  Live progress arrives shard-tagged
through one callback (``Trial.shard``), with ``best_value`` tracking the
ensemble best-so-far.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro import api                                      # noqa: E402
from repro.costmodel import workloads                      # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", default="reinforce",
                    help="inner method each shard runs")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=500,
                    help="sample budget per shard")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "device", "threads", "serial"])
    args = ap.parse_args()

    wl = workloads.mobilenet_v2()[:12]

    def show(trial):
        print(f"  shard {trial.shard}  [{trial.step}/{args.epochs}]  "
              f"ensemble best {trial.best_value:.3e}", flush=True)

    t0 = time.time()
    out = api.run_search(api.SearchRequest(
        workload=wl,
        env=api.EnvConfig(platform="iot"),
        eps=args.epochs,
        method="fanout",
        options={"inner": args.inner, "n_shards": args.shards,
                 "backend": args.backend},
        on_progress=show, progress_every=max(args.epochs // 4, 1)))

    print(f"\nfanout({args.inner} x {args.shards}) via "
          f"backend={out.extras['backend']}  "
          f"[{time.time() - t0:.1f}s wall]")
    print(f"  merged best value : {out.best_value:.3e}")
    print(f"  winning seed      : {out.extras['best_seed']}")
    print(f"  per-shard bests   : "
          f"{[f'{v:.3e}' for v in out.extras['shard_best_values']]}")
    print(f"  total samples     : {out.extras['total_samples']} "
          f"({args.epochs} per shard)")


if __name__ == "__main__":
    main()
