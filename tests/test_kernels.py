"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade property tests to skips, not collection errors
    from hypothesis_stub import given, settings, st

from repro.costmodel import layers_to_array, workloads
from repro.costmodel.layers import LayerSpec
from repro.kernels import ops


def _rand_layers(rng, n):
    out = []
    for _ in range(n):
        t = rng.integers(0, 3)
        if t == 2:
            out.append(LayerSpec.gemm(int(rng.integers(1, 512)),
                                      int(rng.integers(1, 512)),
                                      int(rng.integers(1, 512))))
        elif t == 1:
            c = int(rng.integers(1, 256))
            out.append(LayerSpec.dwconv(c, int(rng.integers(7, 64)),
                                        int(rng.integers(7, 64)), 3, 3))
        else:
            out.append(LayerSpec.conv(int(rng.integers(1, 256)),
                                      int(rng.integers(1, 256)),
                                      int(rng.integers(7, 64)),
                                      int(rng.integers(7, 64)), 3, 3))
    return layers_to_array(out)


@pytest.mark.parametrize("B,N", [(1, 1), (3, 7), (8, 53), (13, 130),
                                 (16, 128)])
def test_costmodel_kernel_shapes(B, N):
    rng = np.random.default_rng(B * 100 + N)
    layers = _rand_layers(rng, N)
    key = jax.random.PRNGKey(B)
    pe = jax.random.randint(key, (B, N), 1, 161).astype(jnp.float32)
    kt = jax.random.randint(jax.random.fold_in(key, 1), (B, N), 1,
                            17).astype(jnp.float32)
    df = jax.random.randint(jax.random.fold_in(key, 2), (B, N), 0,
                            3).astype(jnp.float32)
    got = ops.batched_cost(layers, pe, kt, df, use_kernel=True)
    want = ops.batched_cost(layers, pe, kt, df, use_kernel=False)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("B,N", [(1, 1), (3, 7), (9, 130), (16, 128)])
def test_costmodel_multi_kernel_shapes(B, N):
    """Per-row-layers kernel (multi-tenant batches) vs its oracle."""
    rng = np.random.default_rng(B * 71 + N)
    layers = np.stack([_rand_layers(rng, N) for _ in range(B)])
    key = jax.random.PRNGKey(B + N)
    pe = jax.random.randint(key, (B, N), 1, 161).astype(jnp.float32)
    kt = jax.random.randint(jax.random.fold_in(key, 1), (B, N), 1,
                            17).astype(jnp.float32)
    df = jax.random.randint(jax.random.fold_in(key, 2), (B, N), 0,
                            3).astype(jnp.float32)
    got = ops.batched_cost_multi(layers, pe, kt, df, use_kernel=True)
    want = ops.batched_cost_multi(layers, pe, kt, df, use_kernel=False)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-2)


def test_costmodel_multi_kernel_matches_broadcast_kernel():
    """With every row carrying the SAME workload, multi == broadcast."""
    rng = np.random.default_rng(0)
    layers = _rand_layers(rng, 9)
    B = 5
    pe = rng.integers(1, 161, size=(B, 9)).astype(np.float32)
    kt = rng.integers(1, 17, size=(B, 9)).astype(np.float32)
    df = rng.integers(0, 3, size=(B, 9)).astype(np.float32)
    multi = ops.batched_cost_multi(np.broadcast_to(layers, (B,) + layers.shape),
                                   pe, kt, df, use_kernel=False)
    broad = ops.batched_cost(layers, pe, kt, df, use_kernel=False)
    for m, b in zip(multi, broad):
        np.testing.assert_array_equal(np.asarray(m), np.asarray(b))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), B=st.integers(1, 12),
       N=st.integers(1, 64))
def test_costmodel_kernel_property(seed, B, N):
    rng = np.random.default_rng(seed)
    layers = _rand_layers(rng, N)
    pe = rng.integers(1, 161, (B, N)).astype(np.float32)
    kt = rng.integers(1, 17, (B, N)).astype(np.float32)
    df = rng.integers(0, 3, (B, N)).astype(np.float32)
    got = ops.batched_cost(layers, pe, kt, df, use_kernel=True)
    want = ops.batched_cost(layers, pe, kt, df, use_kernel=False)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("B,I,H", [(1, 10, 128), (5, 10, 128), (8, 11, 128),
                                   (16, 130, 128), (3, 10, 256)])
def test_lstm_kernel_shapes(B, I, H):
    key = jax.random.PRNGKey(B + I)
    x = jax.random.normal(key, (B, I))
    h = jax.random.normal(jax.random.fold_in(key, 1), (B, H)) * 0.1
    c = jax.random.normal(jax.random.fold_in(key, 2), (B, H)) * 0.1
    wx = jax.random.normal(jax.random.fold_in(key, 3), (I, 4 * H)) * 0.1
    wh = jax.random.normal(jax.random.fold_in(key, 4), (H, 4 * H)) * 0.1
    b = jax.random.normal(jax.random.fold_in(key, 5), (4 * H,)) * 0.1
    h1, c1 = ops.lstm_step(x, h, c, wx, wh, b, use_kernel=True)
    h2, c2 = ops.lstm_step(x, h, c, wx, wh, b, use_kernel=False)
    np.testing.assert_allclose(h1, h2, atol=1e-5)
    np.testing.assert_allclose(c1, c2, atol=1e-5)


@pytest.mark.parametrize("B,Hq,Hkv,D,T", [
    (1, 4, 4, 128, 512), (2, 8, 2, 128, 1024), (2, 16, 2, 128, 2048),
    (1, 8, 1, 256, 512),
])
def test_flash_decode_kernel(B, Hq, Hkv, D, T):
    key = jax.random.PRNGKey(T)
    q = jax.random.normal(key, (B, Hq, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, D))
    o1 = ops.decode_attention(q, k, v, use_kernel=True)
    o2 = ops.decode_attention(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


def test_flash_decode_fallback_unaligned():
    """T not divisible by the tile -> silently uses the oracle path."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 128))
    k = jax.random.normal(key, (1, 700, 2, 128))
    v = jax.random.normal(key, (1, 700, 2, 128))
    o = ops.decode_attention(q, k, v, use_kernel=True)
    assert o.shape == (1, 4, 128) and bool(jnp.isfinite(o).all())


# ---------------------------------------------------------------------------
# Multi-DNN mix rows: ragged per-model layer counts through the padded path.
# ---------------------------------------------------------------------------
def _ragged_mix_rows(names, tokens=32):
    """Stack >=3 model configs' layer rows, padding ragged tails with
    repeat=0 layers (benign: all four cost outputs are zero)."""
    import dataclasses

    packs = [layers_to_array(workloads.get_workload(n, tokens=tokens))
             for n in names]
    counts = [len(p) for p in packs]
    N = max(counts)
    pad = dataclasses.replace(LayerSpec.gemm(1, 1, 1), repeat=0).as_row()
    rows = np.stack([np.concatenate([p, np.tile(pad, (N - len(p), 1))])
                     for p in packs]).astype(np.float32)
    return rows, counts


MIX_NAMES = ["qwen1p5_0p5b", "whisper_small", "mamba2_130m"]


def test_mix_rows_oracle_wrapper_is_exact():
    """batched_cost_multi(use_kernel=False) == cost_eval_multi_ref verbatim:
    the wrapper's transpose/broadcast plumbing is lossless on ragged mix
    rows from three different model configs."""
    from repro.kernels import ref

    rows, counts = _ragged_mix_rows(MIX_NAMES)
    assert len(set(counts)) == 3            # genuinely ragged
    B, N = rows.shape[:2]
    rng = np.random.default_rng(7)
    pe = rng.integers(1, 161, (B, N)).astype(np.float32)
    kt = rng.integers(1, 17, (B, N)).astype(np.float32)
    df = rng.integers(0, 3, (B, N)).astype(np.float32)
    got = ops.batched_cost_multi(rows, pe, kt, df, use_kernel=False)
    want = ref.cost_eval_multi_ref(rows.transpose(0, 2, 1), pe, kt, df)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("seed", [0, 3])
def test_mix_rows_kernel_matches_oracle(seed):
    """Pallas per-row-layers kernel on the padded ragged mix: within an ulp
    of the oracle on every output (the kernel's fused accumulations round
    once differently); repeat=0 padding rows are exactly zero."""
    rows, counts = _ragged_mix_rows(MIX_NAMES)
    B, N = rows.shape[:2]
    rng = np.random.default_rng(seed)
    pe = rng.integers(1, 161, (B, N)).astype(np.float32)
    kt = rng.integers(1, 17, (B, N)).astype(np.float32)
    df = rng.integers(0, 3, (B, N)).astype(np.float32)
    got = ops.batched_cost_multi(rows, pe, kt, df, use_kernel=True)
    want = ops.batched_cost_multi(rows, pe, kt, df, use_kernel=False)
    for g, w in zip(got, want):
        g, w = np.asarray(g), np.asarray(w)
        np.testing.assert_allclose(g, w, rtol=5e-7)
        for b, n in enumerate(counts):      # padding stays exactly zero
            assert np.all(g[b, n:] == 0.0)
