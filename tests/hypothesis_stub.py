"""Fallback shims for when ``hypothesis`` is not installed.

Test modules guard their import as::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from hypothesis_stub import given, settings, st

so property-based tests degrade to ``pytest.skip`` (the importorskip
behaviour, but scoped to the decorated tests) instead of erroring the whole
module at collection time.  Non-property tests in the same module keep
running.  ``hypothesis`` itself is declared in the package's ``test`` extra
(pyproject.toml); install it to run the property tests for real.
"""
import pytest


class _StrategyStub:
    """Accepts any ``st.<name>(...)`` call chain at decoration time."""

    def __getattr__(self, name):
        def strategy(*args, **kwargs):
            return _StrategyStub()
        return strategy

    def __call__(self, *args, **kwargs):
        return _StrategyStub()


st = _StrategyStub()


def settings(*args, **kwargs):
    """No-op decorator factory mirroring ``hypothesis.settings``."""
    def deco(fn):
        return fn
    return deco


def given(*args, **kwargs):
    """Replace the property test with a skip carrying the real reason."""
    def deco(fn):
        @pytest.mark.skip(reason="hypothesis not installed")
        def skipper():
            pass  # pragma: no cover
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco
