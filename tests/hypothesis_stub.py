"""Executable fallback shims for when ``hypothesis`` is not installed.

Test modules guard their import as::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from hypothesis_stub import given, settings, st

With real ``hypothesis`` absent (the dev container), the stub *runs* the
property tests instead of skipping them: each ``@given`` test executes a
small, deterministic sample of its strategy space (min(max_examples, 5)
examples drawn from an RNG seeded by the test name, so failures reproduce
across runs).  No shrinking, no coverage-guided search -- real
``hypothesis`` ships in the package's ``test`` extra (pyproject.toml) and
takes over transparently in CI, where the full ``max_examples`` budgets and
shrinking apply.  The point of the stub is that the invariants themselves
execute everywhere: a property that fails on its first five draws fails in
the dev container too, and tier-1 runs report 0 skips instead of 8.

Only the strategy combinators the suite uses are implemented
(``integers``, ``lists``, ``sampled_from``, ``booleans``, ``floats``);
extend ``_Strategies`` when a test needs more.
"""
import functools
import inspect
import random
import zlib

# The dev-container stub caps examples: JAX property tests often recompile
# per draw (fresh closures / distinct shapes), so the full hypothesis
# budgets would dominate tier-1 wall-clock for no extra local signal.
STUB_MAX_EXAMPLES = 5


class _Strategy:
    """A draw function wrapped so strategies compose (``st.lists(st...)``)."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw)


st = _Strategies()


def settings(max_examples=None, **kwargs):
    """Mirror ``hypothesis.settings``: only ``max_examples`` is honored
    (capped at STUB_MAX_EXAMPLES); deadlines etc. are no-ops."""

    def deco(fn):
        if max_examples is not None:
            fn._stub_max_examples = min(max_examples, STUB_MAX_EXAMPLES)
        return fn

    return deco


def given(**strategies):
    """Run the property over a deterministic sample of the strategy space."""

    def deco(fn):
        @functools.wraps(fn)
        def runner():
            n = getattr(runner, "_stub_max_examples", STUB_MAX_EXAMPLES)
            # Seeded by the test name: stable across runs and processes
            # (hash() is salted, crc32 is not), distinct across tests.
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                fn(**{k: s.example(rng) for k, s in strategies.items()})

        # pytest resolves fixtures from the *wrapped* signature; the runner
        # takes none, so hide the property's parameters from collection.
        del runner.__wrapped__
        runner.__signature__ = inspect.Signature()
        return runner

    return deco
