"""The ``repro.obs`` telemetry layer: metrics registry, tracer, flight
recorder, batcher/cache instrumentation, and the docs catalog sync.

Byte-identity of instrumented vs plain searches is covered registry-wide in
tests/test_optimizer_conformance.py::test_telemetry_is_observational; this
file unit-tests the obs primitives themselves plus the serving-stack
accounting (including a multi-thread batcher hammer with exact counter
assertions).
"""
import importlib.util
import json
import os
import threading

import numpy as np
import pytest

from repro import api, obs
from repro.core import env as env_lib
from repro.costmodel import workloads
from repro.obs import instrument, metrics, recorder, state as obs_state
from repro.obs import trace as trace_mod
from repro.serving.batcher import CostEvalBatcher
from repro.serving.cost_cache import CostMemoCache

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ECFG = env_lib.EnvConfig(platform="cloud")


@pytest.fixture(autouse=True)
def _clean_obs():
    """Telemetry is process-global: every test starts and ends disabled
    with zeroed metrics, whatever it does in between."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


def _enabled():
    obs.enable(trace=True)


# ---------------------------------------------------------------------------
# Metrics registry.
# ---------------------------------------------------------------------------
def test_counter_counts_and_is_gated():
    c = metrics.counter("t_obs_counter", "x", labels=("k",))
    c.inc(k="a")                      # disabled -> dropped
    assert c.value(k="a") == 0.0
    _enabled()
    c.inc(k="a")
    c.inc(2.5, k="a")
    c.inc(k="b")
    assert c.value(k="a") == 3.5 and c.value(k="b") == 1.0
    with pytest.raises(ValueError):
        c.inc(-1.0, k="a")            # counters only go up
    with pytest.raises(ValueError):
        c.inc(wrong="label")


def test_gauge_up_down():
    g = metrics.gauge("t_obs_gauge", "x")
    _enabled()
    g.set(5.0)
    g.inc()
    g.dec(2.0)
    assert g.value() == 4.0


def test_histogram_stats_and_buckets():
    h = metrics.histogram("t_obs_hist", "x", buckets=(1.0, 10.0))
    _enabled()
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    st = h.stats()
    assert st["count"] == 3 and st["max"] == 50.0
    assert st["sum"] == pytest.approx(55.5)
    # Exposition: cumulative le buckets ending at +Inf == _count.
    text = obs.REGISTRY.prometheus_text()
    assert 't_obs_hist_bucket{le="1.0"} 1' in text
    assert 't_obs_hist_bucket{le="10.0"} 2' in text
    assert 't_obs_hist_bucket{le="+Inf"} 3' in text
    assert "t_obs_hist_count 3" in text


def test_registry_get_or_create_and_conflicts():
    a = metrics.counter("t_obs_same", "x", labels=("k",))
    b = metrics.counter("t_obs_same", "x", labels=("k",))
    assert a is b
    with pytest.raises(ValueError):
        metrics.gauge("t_obs_same")                   # kind conflict
    with pytest.raises(ValueError):
        metrics.counter("t_obs_same", labels=("other",))   # label conflict


def test_counters_expose_total_suffix_and_reset_zeroes():
    c = metrics.counter("t_obs_totaled", "x")
    _enabled()
    c.inc(3)
    text = obs.REGISTRY.prometheus_text()
    assert "t_obs_totaled_total 3.0" in text
    assert "\nt_obs_totaled 3.0" not in text          # only the _total form
    snap = obs.REGISTRY.snapshot()["t_obs_totaled"]
    assert snap["kind"] == "counter" and snap["values"][""] == 3.0
    obs.REGISTRY.reset()
    assert c.value() == 0.0


def test_exposition_passes_the_telemetry_checker(tmp_path):
    """The registry's own output must satisfy tools/check_telemetry.py --
    the exact validation CI runs on real artifacts."""
    spec = importlib.util.spec_from_file_location(
        "check_telemetry", os.path.join(REPO, "tools", "check_telemetry.py"))
    checker = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(checker)

    _enabled()
    instrument.SEARCH_HARD_EVALS.inc(100, engine="ga")
    instrument.SEARCH_CHUNK_SECONDS.observe(0.5, engine="ga")
    instrument.BATCHER_QUEUE_DEPTH.set(3)
    path = tmp_path / "m.prom"
    obs.write_prometheus(str(path))
    n = checker.check_metrics(str(path), ["repro_search_hard_evals"])
    assert n > 0


# ---------------------------------------------------------------------------
# Tracer.
# ---------------------------------------------------------------------------
def test_spans_nest_with_depth_and_parent():
    t = trace_mod.Tracer()
    with t.span("outer", k=1):
        with t.span("inner"):
            pass
    inner, outer = t.spans()
    assert inner["name"] == "inner" and inner["depth"] == 1
    assert inner["parent"] == "outer"
    assert outer["name"] == "outer" and outer["depth"] == 0
    assert "parent" not in outer
    assert outer["attrs"] == {"k": 1}
    assert outer["dur_us"] >= inner["dur_us"] >= 0


def test_ring_bounds_and_counts_drops():
    t = trace_mod.Tracer(ring=2)
    for i in range(5):
        with t.span(f"s{i}"):
            pass
    assert [r["name"] for r in t.spans()] == ["s3", "s4"]
    assert t.dropped == 3


def test_disabled_span_is_the_shared_null(tmp_path):
    assert trace_mod.span("x") is trace_mod.NULL_SPAN
    with trace_mod.span("x", a=1) as sp:
        assert sp.set(b=2) is sp      # chaining-safe on the disabled path
    _enabled()
    with trace_mod.span("real") as sp:
        assert sp is not trace_mod.NULL_SPAN


def test_jsonl_sink_and_chrome_export(tmp_path):
    jsonl = tmp_path / "t.jsonl"
    t = trace_mod.Tracer(jsonl_path=str(jsonl))
    with t.span("a", n=3):
        pass
    t.close()
    recs = [json.loads(ln) for ln in jsonl.read_text().splitlines()]
    assert len(recs) == 1 and recs[0]["name"] == "a"
    assert recs[0]["attrs"] == {"n": 3}
    ct = t.chrome_trace()
    (ev,) = ct["traceEvents"]
    assert ev["ph"] == "X" and ev["name"] == "a" and ev["dur"] >= 0
    # save() picks the format from the extension.
    out = tmp_path / "t.json"
    t.save(str(out))
    assert json.loads(out.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# Flight recorder.
# ---------------------------------------------------------------------------
def test_recorder_summary_counts_series_and_ratios():
    r = recorder.FlightRecorder(engine="ga")
    r.add("points", 10)
    r.add("cached_points", 4)
    r.add("fresh_points", 6)
    r.observe("dispatch_s", 0.2)
    r.observe("dispatch_s", 0.4)
    s = r.summary()
    assert s["engine"] == "ga" and s["points"] == 10
    assert s["cache_hit_rate"] == pytest.approx(0.4)
    assert s["fresh_frac"] == pytest.approx(0.6)
    d = s["dispatch_s"]
    assert d["count"] == 2 and d["max"] == pytest.approx(0.4)
    assert d["mean"] == pytest.approx(0.3)


def test_recording_is_thread_local_and_gated():
    r = recorder.FlightRecorder()
    recorder.record("k")              # no recorder, disabled -> no-op
    _enabled()
    with recorder.recording(r):
        recorder.record("k", 2)
        seen = []
        th = threading.Thread(
            target=lambda: seen.append(recorder.current_recorder()))
        th.start()
        th.join()
        assert seen == [None]         # other threads see no recorder
    recorder.record("k")              # uninstalled again
    assert r.count("k") == 2.0


# ---------------------------------------------------------------------------
# Dispatch/compile tracking.
# ---------------------------------------------------------------------------
def test_dispatch_span_counts_first_sighting_as_compile():
    _enabled()
    rec = recorder.FlightRecorder()
    with recorder.recording(rec):
        for _ in range(3):
            with instrument.dispatch_span("t_prog", key=256):
                pass
        with instrument.dispatch_span("t_prog", key=512):
            pass
    assert instrument.JIT_COMPILES.value(program="t_prog") == 2.0
    assert instrument.DISPATCH_SECONDS.stats(program="t_prog")["count"] == 4
    assert rec.count("jit_compiles") == 2.0
    spans = [s for s in obs.tracer().spans() if s["name"] == "xla.dispatch"]
    assert [s["attrs"]["compile"] for s in spans] == [
        True, False, False, True]


def test_hard_evals_helper_feeds_registry_and_recorder():
    instrument.hard_evals("random", 50)      # disabled -> free no-op
    assert instrument.SEARCH_HARD_EVALS.value(engine="random") == 0.0
    _enabled()
    rec = recorder.FlightRecorder()
    with recorder.recording(rec):
        instrument.hard_evals("random", 50)
    assert instrument.SEARCH_HARD_EVALS.value(engine="random") == 50.0
    assert rec.count("hard_evals") == 50.0


# ---------------------------------------------------------------------------
# Cache + batcher accounting.
# ---------------------------------------------------------------------------
def test_empty_cache_hit_rate_is_zero():
    cache = CostMemoCache()
    assert cache.hit_rate == 0.0
    assert cache.stats()["hit_rate"] == 0.0


def test_batcher_cache_stats_merge_asserts_disjoint_keys():
    b = CostEvalBatcher()
    try:
        s = b.stats()
        assert s["cache_hits"] == 0           # cache_ namespaced in
        assert "dispatches" in s
        # A batcher-native key colliding with the cache_ namespace must
        # fail loudly, not silently shadow.
        with b._stats_lock:
            b._stats["cache_hits"] = 99
        with pytest.raises(AssertionError):
            b.stats()
    finally:
        with b._stats_lock:
            b._stats.pop("cache_hits", None)
        b.close()


def test_batcher_hammer_exact_counters_and_attribution():
    """Satellite: N searches hammer one batcher from worker threads; every
    process-wide counter and per-search flight-recorder count must come out
    exact (no lost updates), and concurrency stays within the pool."""
    _enabled()
    env = env_lib.make_env(workloads.get_workload("ncf"), ECFG)
    layers = np.asarray(env.layers, np.float32)
    N = layers.shape[0]
    T, K, B = 4, 3, 8            # threads x submits x genomes-per-submit
    workers = 2
    b = CostEvalBatcher(window_ms=1.0, use_kernel=False,
                        dispatch_workers=workers)
    recs = [recorder.FlightRecorder(engine=f"t{i}") for i in range(T)]
    fits = [None] * T
    errors = []

    def worker(i):
        rng = np.random.default_rng(i)
        try:
            with recorder.recording(recs[i]):
                out = []
                for _ in range(K):
                    pe = rng.integers(1, 64, (B, N)).astype(np.float32)
                    kt = rng.integers(1, 64, (B, N)).astype(np.float32)
                    out.append(b.evaluate(layers, pe, kt, 0.0, ECFG,
                                          env.budget))
                fits[i] = out
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        assert not errors
        s = b.stats()
        assert s["items"] == T * K
        assert s["points"] == T * K * B * N
        assert 1 <= s["dispatches"] <= T * K
        # The cache is consulted once per unique row per dispatch.
        assert s["cache_hits"] + s["cache_misses"] == s["unique_points"]
        assert s["fresh_points"] == s["cache_misses"]
        assert s["max_concurrent_dispatches"] <= workers
        assert s["dispatch_workers"] == workers

        # Process-wide metrics agree with the batcher's own ledger.
        pts = instrument.BATCHER_POINTS
        assert pts.value(kind="submitted") == s["points"]
        assert pts.value(kind="unique") == s["unique_points"]
        assert pts.value(kind="fresh") == s["fresh_points"]
        assert instrument.BATCHER_DISPATCHES.value() == s["dispatches"]
        assert instrument.BATCHER_FUSE_WIDTH.stats()["count"] == \
            s["dispatches"]
        assert instrument.BATCHER_QUEUE_WAIT.stats()["count"] == T * K

        # Per-search attribution: each rider credited exactly its share.
        for r in recs:
            t = r.summary()
            assert t["eval_batches"] == K
            assert t["points"] == K * B * N
            assert t["fresh_points"] + t["cached_points"] == t["points"]
            assert t["queue_wait_s"]["count"] == K
        assert sum(r.count("fresh_points") for r in recs) == \
            s["fresh_points"]

        # Sanity: results are real fitness vectors.
        for out in fits:
            assert len(out) == K and all(f.shape == (B,) for f in out)
    finally:
        b.close()


# ---------------------------------------------------------------------------
# Outcome summary + docs catalog sync.
# ---------------------------------------------------------------------------
def test_outcome_summary_renders_telemetry():
    req = api.SearchRequest(workload="ncf", env=ECFG, eps=20, seed=3,
                            method="random")
    plain = api.run_search(req)
    text = plain.summary()
    assert "method=random" in text and "seed=3" in text
    assert f"best_value={plain.best_value:.6g}" in text
    assert "telemetry" not in text
    _enabled()
    traced = api.run_search(req)
    text = traced.summary()
    assert "telemetry: " in text and "hard_evals=20" in text


def test_docs_document_every_metric_and_span():
    doc = open(os.path.join(REPO, "docs", "observability.md")).read()
    for name in instrument.METRIC_NAMES:
        assert f"`{name}`" in doc, f"{name} missing from docs/observability.md"
    for name in instrument.SPAN_NAMES:
        assert f"`{name}`" in doc, f"{name} missing from docs/observability.md"
