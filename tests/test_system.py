"""End-to-end system behaviour: the two-stage ConfuciuX pipeline on real
workloads, with the paper's qualitative claims as assertions.

These exercise the same public API as launch/search.py and the examples.
"""
import numpy as np
import pytest

from repro.core import env as env_lib
from repro.core import ga as ga_lib
from repro.core import reinforce, search
from repro.costmodel import workloads
from repro.costmodel.layers import LayerSpec

# Small-but-real workload so the end-to-end run stays < ~1 min on CPU.
WL = [
    LayerSpec.conv(32, 16, 28, 28, 3, 3, name="c0"),
    LayerSpec.dwconv(64, 14, 14, 3, 3, name="dw"),
    LayerSpec.conv(64, 64, 14, 14, 1, 1, name="pw"),
    LayerSpec.gemm(64, 256, 128, name="fc"),
]


def _cfg(**kw):
    return env_lib.EnvConfig(**{"platform": "iot", "objective": "latency",
                                "constraint": "area", **kw})


def test_two_stage_pipeline_improves_monotonically():
    """Fig. 9 / Table VII behaviour: stage-1 finds a feasible point and
    improves on the first feasible value; stage-2 never regresses."""
    res = search.confuciux_search(
        WL, _cfg(),
        rcfg=reinforce.ReinforceConfig(epochs=300, episodes_per_epoch=2,
                                       seed=0),
        gcfg=ga_lib.LocalGAConfig(population=16, generations=150))
    assert np.isfinite(res.best_value)
    assert res.stage1_value <= res.initial_valid_value
    assert res.best_value <= res.stage1_value
    # The reported solution actually achieves the reported value + budget.
    env = env_lib.make_env(WL, _cfg())
    perf, cons, feas = env_lib.genome_cost(
        env, _cfg(), res.pe, res.kt, res.df)
    assert bool(feas)
    assert float(perf) == pytest.approx(res.best_value, rel=1e-5)


def test_search_respects_tight_constraint():
    """IoTx (5% of C_max): the solution must fit the budget (Table IV)."""
    ecfg = _cfg(platform="iotx")
    res = search.confuciux_search(
        WL, ecfg,
        rcfg=reinforce.ReinforceConfig(epochs=400, episodes_per_epoch=2),
        fine_tune=False)
    env = env_lib.make_env(WL, ecfg)
    if np.isfinite(res.best_value):
        _, cons, feas = env_lib.genome_cost(env, ecfg, res.pe, res.kt, res.df)
        assert bool(feas) and float(cons) <= float(env.budget) * (1 + 1e-6)


def test_mix_dataflow_beats_or_matches_fixed():
    """Table VI: per-layer dataflow co-automation >= fixed styles
    (statistically; here we assert it beats the WORST fixed style)."""
    fixed = []
    for df in (0, 1, 2):
        res = search.confuciux_search(
            WL, _cfg(dataflow=df),
            rcfg=reinforce.ReinforceConfig(epochs=250, episodes_per_epoch=2),
            fine_tune=False)
        fixed.append(res.best_value)
    mix = search.confuciux_search(
        WL, _cfg(mix=True),
        rcfg=reinforce.ReinforceConfig(epochs=400, episodes_per_epoch=2),
        fine_tune=False)
    assert np.isfinite(mix.best_value)
    assert mix.best_value <= max(fixed) * 1.05


def test_ls_per_layer_optima_differ_across_layers():
    """Fig. 5: no single action pair is optimal for every layer."""
    grids = search.per_layer_optima(workloads.mobilenet_v2()[:12], _cfg())
    opt = grids["optima_latency"]
    assert len({tuple(o) for o in opt}) > 1


def test_heuristics_underperform_per_layer_optima():
    """Fig. 5: Heuristic A/B are dominated by per-layer tuning."""
    wl = workloads.mobilenet_v2()[:12]
    ecfg = _cfg(scenario="LS")
    ha = search.heuristic_a(wl, ecfg)
    hb = search.heuristic_b(wl, ecfg)
    grids = search.per_layer_optima(wl, ecfg)
    per_layer_best = sum(
        grids["latency"][i][tuple(grids["optima_latency"][i])]
        for i in range(len(wl)))
    assert per_layer_best <= hb["value"] * (1 + 1e-6)
    assert hb["value"] <= ha["value"] * (1 + 1e-6)  # B optimizes end-to-end
