"""Search service: serial parity, cache accounting, cancellation, batcher.

The load-bearing guarantee is EXACTNESS: a search routed through the
service -- cross-request fusion, per-point dedup and memo-cache hits
included -- returns bit-identical outcomes to the same ``api.run_search``
call executed serially.  Everything else (hit/miss bookkeeping, ticket
lifecycle, a cancelled request never stalling the batcher) is what makes
the service operable.
"""
import threading
import time

import numpy as np
import pytest

from repro import api
from repro.core import env as env_lib
from repro.serving import (CostEvalBatcher, CostMemoCache,
                           PersistentCostCache, SearchCancelled,
                           SearchService, ServiceConfig)
from repro.serving.batcher import ROW_WIDTH

ECFG = env_lib.EnvConfig(platform="cloud")


def _req(method, eps=200, seed=0, wl="ncf", **kw):
    return api.SearchRequest(workload=wl, env=ECFG, eps=eps, seed=seed,
                             method=method, **kw)


@pytest.fixture
def svc():
    s = SearchService(ServiceConfig(max_workers=4,
                                    default_progress_every=50))
    yield s
    s.close()


# ---------------------------------------------------------------------------
# Exact parity with serial dispatch.
# ---------------------------------------------------------------------------
def test_concurrent_batched_methods_identical_to_serial(svc):
    """random/grid/bo through the fused batcher == serial, bit for bit."""
    reqs = [_req(m, eps=200, seed=3) for m in ("random", "grid", "bo")]
    serial = [api.run_search(_req(m, eps=200, seed=3))
              for m in ("random", "grid", "bo")]
    tickets = [svc.submit(r) for r in reqs]
    for t, want in zip(tickets, serial):
        got = t.result(timeout=300)
        assert got.best_value == want.best_value
        assert got.history.tobytes() == want.history.tobytes()
        np.testing.assert_array_equal(got.pe, want.pe)
        np.testing.assert_array_equal(got.kt, want.kt)
    assert svc.stats()["completed"] == 3
    # The fused path actually ran: points flowed through the batcher.
    assert svc.stats()["points"] > 0


def test_chunked_engine_identical_to_serial(svc):
    """reinforce multiplexes at chunk granularity, still bit-identical."""
    want = api.run_search(_req("reinforce", eps=60, seed=7))
    got = svc.submit(_req("reinforce", eps=60, seed=7)).result(timeout=300)
    assert got.best_value == pytest.approx(want.best_value)
    np.testing.assert_allclose(got.history, want.history)


def test_ga_sa_through_service_byte_identical_to_serial(svc):
    """ga/sa route their fitness through the fused batcher (raw eval_fn);
    outcomes must equal the serial in-graph runs byte for byte."""
    cases = [("ga", {"population": 40}), ("sa", {})]
    serial = [api.run_search(_req(m, eps=200, seed=3, options=dict(o)))
              for m, o in cases]
    points_before = svc.stats()["points"]
    tickets = [svc.submit(_req(m, eps=200, seed=3, options=dict(o)))
               for m, o in cases]
    for t, want in zip(tickets, serial):
        got = t.result(timeout=300)
        assert got.best_value == want.best_value
        assert got.history.tobytes() == want.history.tobytes()
        np.testing.assert_array_equal(got.pe, want.pe)
        np.testing.assert_array_equal(got.kt, want.kt)
    # The fused path actually ran: GA/SA points flowed through the batcher.
    assert svc.stats()["points"] > points_before


def test_dispatch_pool_byte_identical_to_single_thread():
    """A multi-worker dispatch pool returns the same bytes as one thread."""
    reqs = [("random", {}), ("ga", {"population": 30}), ("sa", {}),
            ("grid", {})]
    outs = {}
    for workers in (1, 3):
        svc = SearchService(ServiceConfig(max_workers=4,
                                          dispatch_workers=workers))
        try:
            outs[workers] = svc.run_all(
                [_req(m, eps=200, seed=2, options=dict(o)) for m, o in reqs])
            assert svc.stats()["dispatch_workers"] == workers
        finally:
            svc.close()
    for a, b in zip(outs[1], outs[3]):
        assert a.best_value == b.best_value
        assert a.history.tobytes() == b.history.tobytes()
        np.testing.assert_array_equal(a.pe, b.pe)
        np.testing.assert_array_equal(a.kt, b.kt)


def test_same_seed_concurrent_duplicates_agree(svc):
    """Identical queries racing each other return identical outcomes."""
    tickets = [svc.submit(_req("random", eps=300, seed=5)) for _ in range(4)]
    outs = [t.result(timeout=300) for t in tickets]
    for o in outs[1:]:
        assert o.best_value == outs[0].best_value
        assert o.history.tobytes() == outs[0].history.tobytes()


def test_run_all_preserves_request_order(svc):
    outs = svc.run_all([_req("random", eps=150, seed=s) for s in range(3)])
    assert [o.seed for o in outs] == [0, 1, 2]
    assert all(o.method == "random" for o in outs)


# ---------------------------------------------------------------------------
# Cache accounting.
# ---------------------------------------------------------------------------
def test_cache_hit_miss_accounting_is_consistent(svc):
    svc.submit(_req("random", eps=200, seed=1)).result(timeout=300)
    s1 = svc.stats()
    # Every unique point was either a hit or a fresh (miss) evaluation.
    assert s1["cache_hits"] + s1["cache_misses"] == s1["unique_points"]
    assert s1["cache_misses"] == s1["fresh_points"] > 0
    assert s1["cache_entries"] == s1["cache_misses"]  # nothing evicted

    # Resubmitting the identical query evaluates NOTHING fresh.
    svc.submit(_req("random", eps=200, seed=1)).result(timeout=300)
    s2 = svc.stats()
    assert s2["cache_misses"] == s1["cache_misses"]
    assert s2["fresh_points"] == s1["fresh_points"]
    assert s2["cache_hits"] > s1["cache_hits"]
    assert s2["cache_hit_rate"] > s1["cache_hit_rate"]


def test_cache_shared_across_objectives():
    """The point key excludes the objective: latency and energy users on
    the same workload reuse each other's evaluations."""
    svc = SearchService(ServiceConfig(max_workers=2))
    try:
        svc.submit(_req("random", eps=200, seed=2)).result(timeout=300)
        misses = svc.stats()["cache_misses"]
        env2 = env_lib.EnvConfig(platform="cloud", objective="energy",
                                 constraint="power")
        svc.submit(api.SearchRequest(workload="ncf", env=env2, eps=200,
                                     seed=2, method="random")
                   ).result(timeout=300)
        assert svc.stats()["cache_misses"] == misses  # same points, 0 fresh
    finally:
        svc.close()


def test_cache_lru_eviction_accounting():
    cache = CostMemoCache(capacity=4)
    keys = [bytes([i]) for i in range(6)]
    vals = np.arange(24, dtype=np.float32).reshape(6, 4)
    cache.put_many(keys, list(vals))
    assert len(cache) == 4 and cache.evictions == 2
    values, miss = cache.get_many(keys)
    assert miss == [0, 1]                      # oldest two evicted
    np.testing.assert_array_equal(values[5], vals[5])
    assert cache.hits == 4 and cache.misses == 2


def test_cache_rejects_bad_capacity():
    with pytest.raises(ValueError, match="capacity"):
        CostMemoCache(capacity=0)


# ---------------------------------------------------------------------------
# Cancellation.
# ---------------------------------------------------------------------------
def test_cancel_mid_stream_chunked_engine(svc):
    got = []
    t = svc.submit(_req("reinforce", eps=100000, on_progress=got.append,
                        progress_every=10))
    deadline = time.time() + 120
    while not got and time.time() < deadline:
        time.sleep(0.02)
    assert got, "no progress streamed before deadline"
    t.cancel()
    with pytest.raises(SearchCancelled):
        t.result(timeout=120)
    assert t.status == "cancelled"
    assert svc.stats()["cancelled"] == 1


@pytest.mark.parametrize("method,opts,chunk_samples", [
    ("ga", {"population": 50}, 100),   # progress_every=100 -> 2-gen chunks
    ("sa", {}, 100),                   # progress_every=100 -> 100-step chunks
])
def test_cancel_ga_sa_within_one_chunk(svc, method, opts, chunk_samples):
    """GA/SA cancel at chunk granularity now, not at run end: submit an
    effectively unbounded search, cancel after the first progress event,
    and require the engine to stop within one further chunk."""
    eps = 10_000_000
    got = []
    t = svc.submit(_req(method, eps=eps, on_progress=got.append,
                        progress_every=chunk_samples, options=dict(opts)))
    deadline = time.time() + 120
    while not got and time.time() < deadline:
        time.sleep(0.02)
    assert got, "no progress streamed before deadline"
    t.cancel()
    # Baseline AFTER cancel(): the flag is set, so the engine can append at
    # most the in-flight chunk plus one boundary that races the flag.
    # (Reading before cancel() would let a main-thread stall between the
    # read and the cancel inflate the gap and flake the bound.)
    at_cancel = t.trials[-1].step
    with pytest.raises(SearchCancelled):
        t.result(timeout=120)
    assert t.status == "cancelled"
    # Stopped within one chunk of the cancel (+ one chunk of slack for a
    # boundary that races the cancel flag) -- nowhere near the 10M-sample
    # budget the old run-to-completion engines would have burned.
    last = t.trials[-1].step
    assert last <= at_cancel + 2 * chunk_samples


def test_cancelled_request_does_not_stall_batcher(svc):
    """Cancel a batched-method request mid-flight; the batcher keeps
    serving everyone else and fresh requests still complete."""
    victim = svc.submit(_req("random", eps=500000, seed=9))
    survivor = svc.submit(_req("random", eps=200, seed=4))
    deadline = time.time() + 120
    while svc.stats()["dispatches"] == 0 and time.time() < deadline:
        time.sleep(0.02)
    victim.cancel()
    with pytest.raises(SearchCancelled):
        victim.result(timeout=120)
    want = api.run_search(_req("random", eps=200, seed=4))
    got = survivor.result(timeout=120)
    assert got.best_value == want.best_value
    late = svc.submit(_req("grid", eps=150, seed=1)).result(timeout=120)
    assert late.eps == 150
    assert svc.stats()["cancelled"] == 1
    assert svc.stats()["completed"] == 2


def test_cancel_while_queued_never_runs():
    """A ticket cancelled before a worker picks it up is never executed."""
    svc = SearchService(ServiceConfig(max_workers=1))
    try:
        blocker = svc.submit(_req("random", eps=2000, seed=0))
        queued = svc.submit(_req("random", eps=150, seed=1))
        queued.cancel()          # still waiting behind the 1-worker pool
        with pytest.raises(SearchCancelled):
            queued.result(timeout=300)
        assert queued.status == "cancelled"
        assert blocker.result(timeout=300).feasible
        s = svc.stats()
        assert s["cancelled"] == 1 and s["completed"] == 1
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Ticket / service lifecycle.
# ---------------------------------------------------------------------------
def test_failed_request_reports_error_not_hang(svc):
    t = svc.submit(_req("random", eps=100, wl="no_such_workload"))
    with pytest.raises(Exception, match="no_such_workload"):
        t.result(timeout=120)
    assert t.status == "failed"
    assert svc.stats()["failed"] == 1


def test_progress_recorded_on_ticket(svc):
    t = svc.submit(_req("reinforce", eps=60))
    t.result(timeout=300)
    steps = [tr.step for tr in t.trials]
    assert steps and steps == sorted(steps) and steps[-1] == 60


def test_closed_service_rejects_submissions():
    svc = SearchService(ServiceConfig(max_workers=1))
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(_req("random"))


def test_submit_vs_close_race_every_ticket_terminates():
    """Hammer submit() from several threads while close() runs.  Every
    ticket submit() RETURNED must terminate -- the old unlocked _closed
    check could count a ticket, hit the shut-down pool's RuntimeError and
    leave result() blocking forever."""
    svc = SearchService(ServiceConfig(max_workers=2))
    tickets: list = []
    tlock = threading.Lock()
    stop = threading.Event()

    def spam():
        while not stop.is_set():
            try:
                t = svc.submit(_req("random", eps=30, seed=1))
            except RuntimeError:
                return          # service closed: the legal rejection path
            with tlock:
                tickets.append(t)

    threads = [threading.Thread(target=spam) for _ in range(4)]
    for th in threads:
        th.start()
    time.sleep(0.05)            # let submissions overlap the close
    svc.close()
    stop.set()
    for th in threads:
        th.join(timeout=60)
        assert not th.is_alive()
    assert tickets, "race window produced no accepted submissions"
    for t in tickets:
        assert t.done(), f"ticket {t.uid} leaked: status={t.status}"
        assert t.status in ("done", "failed", "cancelled")
        try:
            t.result(timeout=1)     # must never block post-close
        except Exception:  # noqa: BLE001 -- failed/cancelled is fine
            pass
    # Conservation: every accepted ticket finished exactly one way.
    s = svc.stats()
    assert s["submitted"] == len(tickets)
    assert s["completed"] + s["failed"] + s["cancelled"] == len(tickets)


def test_queued_cancel_finishes_without_waiting_for_worker():
    """cancel() on a still-queued ticket resolves IMMEDIATELY -- not when
    the saturated pool finally dequeues work it will only throw away."""
    svc = SearchService(ServiceConfig(max_workers=1,
                                      default_progress_every=50))
    try:
        blocker = svc.submit(_req("reinforce", eps=10_000_000))
        queued = svc.submit(_req("random", eps=150, seed=1))
        t0 = time.time()
        queued.cancel()
        with pytest.raises(SearchCancelled):
            queued.result(timeout=5)
        assert time.time() - t0 < 5.0
        assert queued.status == "cancelled" and queued.done()
        # The proof we didn't wait: the worker is still busy with the
        # effectively-unbounded blocker.
        assert not blocker.done()
        blocker.cancel()
        with pytest.raises(SearchCancelled):
            blocker.result(timeout=120)
        s = svc.stats()
        assert s["cancelled"] == 2 and s["completed"] == 0
    finally:
        svc.close()


def test_result_error_isolated_per_caller(svc):
    """Concurrent result() callers each raise their OWN exception object:
    re-raising one shared instance would let the callers mutate each
    other's __traceback__ mid-flight."""
    t = svc.submit(_req("random", eps=50, wl="no_such_workload"))
    caught = []
    clock = threading.Lock()

    def grab():
        try:
            t.result(timeout=120)
        except Exception as e:  # noqa: BLE001 -- the point of the test
            with clock:
                caught.append(e)

    threads = [threading.Thread(target=grab) for _ in range(2)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=120)
    assert len(caught) == 2
    e1, e2 = caught
    assert e1 is not e2                      # per-caller copies ...
    assert e1 is not t._error and e2 is not t._error
    assert type(e1) is type(t._error) and e1.args == t._error.args
    assert e1.__cause__ is t._error          # ... chained to the original,
    assert e2.__cause__ is t._error          # whose traceback stays pinned
    assert "no_such_workload" in str(e1)


def test_batcher_close_fails_pending_when_dispatch_hangs():
    """A dispatch thread hung inside _dispatch must not turn close() into
    a silent strand: still-queued evaluations get a RuntimeError and the
    leak is reported in stats."""
    b = CostEvalBatcher(window_ms=0.0, dispatch_workers=1,
                        join_timeout_s=0.2)
    entered = threading.Event()
    release = threading.Event()

    def stuck_dispatch(items):
        entered.set()
        release.wait(60)            # simulates a wedged device dispatch
        for it in items:
            it.error = RuntimeError("released")
            it.event.set()

    b._dispatch = stuck_dispatch
    errs = {}

    def submit(name):
        try:
            b.evaluate(np.ones((1, 8), np.float32),
                       np.ones((1, 1), np.float32),
                       np.ones((1, 1), np.float32), np.float32(0), ECFG,
                       np.float32(1.0))
        except BaseException as e:  # noqa: BLE001
            errs[name] = e

    ta = threading.Thread(target=submit, args=("hung",))
    ta.start()
    assert entered.wait(timeout=60)      # dispatcher is now wedged
    tb = threading.Thread(target=submit, args=("stranded",))
    tb.start()
    deadline = time.time() + 60
    while time.time() < deadline:        # wait for b's item to queue up
        with b._cv:
            if b._pending:
                break
        time.sleep(0.005)
    b.close()
    assert b.stats()["leaked_dispatch_threads"] == 1
    tb.join(timeout=60)
    assert isinstance(errs["stranded"], RuntimeError)
    assert "hung dispatch" in str(errs["stranded"])
    release.set()                        # unwedge; the hung item resolves
    ta.join(timeout=60)
    assert "released" in str(errs["hung"])


def test_batcher_clean_close_reports_zero_leaks():
    b = CostEvalBatcher(dispatch_workers=2)
    b.close()
    assert b.stats()["leaked_dispatch_threads"] == 0


# ---------------------------------------------------------------------------
# Persistent cost cache.
# ---------------------------------------------------------------------------
def test_persistent_cache_round_trip(tmp_path):
    """Entries written by one cache incarnation are served by the next:
    flush on close, vectorized reload on open, 100% hit rate."""
    d = str(tmp_path / "cache")
    keys = [np.arange(i, i + 3, dtype=np.float32).tobytes()
            for i in range(10)]
    vals = [np.arange(4, dtype=np.float32) + i for i in range(10)]
    c = PersistentCostCache(d, version="v1", flush_every=1000)
    c.put_many(keys, vals)
    assert c.stats()["pending_flush"] == 10      # buffered, not yet on disk
    c.close()
    assert c.stats()["pending_flush"] == 0 and c.persisted == 10

    c2 = PersistentCostCache(d, version="v1")
    assert len(c2) == 10 and c2.shards_loaded == 1
    values, miss = c2.get_many(keys)
    assert miss == [] and c2.hit_rate == 1.0
    for v, want in zip(values, vals):
        np.testing.assert_array_equal(v, want)

    # Re-inserting loaded entries is not "fresh": nothing new flushes.
    c2.put_many(keys, vals)
    assert c2.stats()["pending_flush"] == 0
    c2.close()


def test_persistent_cache_version_invalidates(tmp_path):
    """The version namespace is the directory: a cost-model edit opens an
    empty store instead of serving stale tuples."""
    d = str(tmp_path / "cache")
    keys = [bytes([i, i + 1]) for i in range(4)]
    vals = [np.full(4, i, np.float32) for i in range(4)]
    c = PersistentCostCache(d, version="model-a")
    c.put_many(keys, vals)
    c.close()
    other = PersistentCostCache(d, version="model-b")
    assert len(other) == 0 and other.shards_loaded == 0
    _, miss = other.get_many(keys)
    assert miss == list(range(4))
    other.close()


def test_persistent_cache_skips_corrupt_shards(tmp_path):
    import os

    d = str(tmp_path / "cache")
    keys = [bytes([i, i, i]) for i in range(6)]
    vals = [np.full(4, float(i), np.float32) for i in range(6)]
    c = PersistentCostCache(d, version="v1")
    c.put_many(keys[:3], vals[:3])
    c.flush()
    c.put_many(keys[3:], vals[3:])
    c.flush()
    c.close()
    shard_dir = os.path.join(d, "v1")
    shards = sorted(n for n in os.listdir(shard_dir) if n.endswith(".bin"))
    assert len(shards) == 2
    # Truncate one shard mid-body and drop in one garbage file.
    victim = os.path.join(shard_dir, shards[0])
    with open(victim, "rb") as f:
        blob = f.read()
    with open(victim, "wb") as f:
        f.write(blob[:-5])
    with open(os.path.join(shard_dir, "shard-999-000000.bin"), "wb") as f:
        f.write(b"not a shard at all")

    c2 = PersistentCostCache(d, version="v1")
    assert c2.corrupt_shards == 2
    assert c2.shards_loaded == 1 and len(c2) == 3    # survivors still serve
    values, miss = c2.get_many(keys)
    assert len(miss) == 3
    for i in (3, 4, 5):
        np.testing.assert_array_equal(values[i], vals[i])
    c2.close()


def test_service_warm_restart_serves_fully_from_disk(tmp_path):
    """ServiceConfig.cache_dir end to end: a restarted service re-runs the
    same query with ZERO fresh evaluations and identical bytes."""
    d = str(tmp_path / "cache")
    svc1 = SearchService(ServiceConfig(max_workers=2, cache_dir=d))
    try:
        want = svc1.submit(_req("random", eps=200, seed=5)).result(
            timeout=300)
        s1 = svc1.stats()
        assert s1["fresh_points"] > 0
        assert isinstance(svc1.cache, PersistentCostCache)
    finally:
        svc1.close()          # final flush happens here
    assert s1["fresh_points"] >= 0

    svc2 = SearchService(ServiceConfig(max_workers=2, cache_dir=d))
    try:
        assert len(svc2.cache) > 0               # warm from disk
        got = svc2.submit(_req("random", eps=200, seed=5)).result(
            timeout=300)
        s2 = svc2.stats()
        assert s2["cache_misses"] == 0 and s2["fresh_points"] == 0
        assert s2["cache_hit_rate"] == 1.0       # 100% warm
        assert got.best_value == want.best_value
        assert got.history.tobytes() == want.history.tobytes()
        np.testing.assert_array_equal(got.pe, want.pe)
        np.testing.assert_array_equal(got.kt, want.kt)
    finally:
        svc2.close()


# ---------------------------------------------------------------------------
# Batcher internals.
# ---------------------------------------------------------------------------
def test_batcher_direct_matches_genome_cost():
    """CostEvalBatcher.evaluate == the serial jitted genome evaluation."""
    from repro.core.baselines import _decode_and_eval
    import jax
    import jax.numpy as jnp

    wl = api.SearchRequest(workload="ncf", env=ECFG).resolve_workload()
    env = env_lib.make_env(wl, ECFG)
    rng = np.random.default_rng(0)
    g = rng.integers(0, ECFG.levels, size=(64, env.num_layers, 2))
    want, _, _ = jax.jit(lambda g: _decode_and_eval(env, ECFG, g))(
        jnp.asarray(g))
    pe = np.asarray(env.pe_table)[g[..., 0]]
    kt = np.asarray(env.kt_table)[g[..., 1]]
    b = CostEvalBatcher(window_ms=0.0)
    try:
        got = b.evaluate(np.asarray(env.layers), pe, kt,
                         np.float32(ECFG.dataflow), ECFG,
                         np.float32(env.budget))
        assert got.tobytes() == np.asarray(want).tobytes()
        # A second identical call is served fully from cache -- still exact.
        again = b.evaluate(np.asarray(env.layers), pe, kt,
                           np.float32(ECFG.dataflow), ECFG,
                           np.float32(env.budget))
        assert again.tobytes() == got.tobytes()
        assert b.stats()["fresh_points"] == b.stats()["cache_misses"]
    finally:
        b.close()


def test_batcher_point_row_width_covers_all_fields():
    from repro.costmodel.layers import NUM_FIELDS

    assert ROW_WIDTH == NUM_FIELDS + 3  # fields + pe + kt + df


def test_closed_batcher_rejects_evaluations():
    b = CostEvalBatcher()
    b.close()
    with pytest.raises(RuntimeError, match="closed"):
        b.evaluate(np.ones((1, 8), np.float32), np.ones((1, 1), np.float32),
                   np.ones((1, 1), np.float32), np.float32(0), ECFG,
                   np.float32(1.0))


# ---------------------------------------------------------------------------
# Multi-objective (nsga2) through the service.
# ---------------------------------------------------------------------------
def test_nsga2_through_service_byte_identical_to_serial(svc):
    """nsga2 routes (b, 4)-cost batches through evaluate_costs; outcomes
    (history, assignment AND frontier) equal the serial run byte for byte."""
    opt = {"population": 15}
    want = api.run_search(_req("nsga2", eps=120, seed=3, options=dict(opt)))
    got = svc.submit(_req("nsga2", eps=120, seed=3,
                          options=dict(opt))).result(timeout=300)
    assert got.best_value == want.best_value
    assert got.history.tobytes() == want.history.tobytes()
    np.testing.assert_array_equal(got.pe, want.pe)
    np.testing.assert_array_equal(got.kt, want.kt)
    for k in ("lat", "en", "area", "pw"):
        np.testing.assert_array_equal(got.frontier[k], want.frontier[k])
    assert svc.stats()["points"] > 0


def test_evaluate_costs_matches_local_eval_and_shares_cache():
    """Batcher evaluate_costs == the serial make_local_costs_eval bytes,
    and its per-point cache entries are shared with scalar evaluate()."""
    from repro.costmodel import workloads
    from repro.serving.batcher import make_local_costs_eval

    env = env_lib.make_env(workloads.get_workload("ncf"), ECFG)
    layers = np.asarray(env.layers, np.float32)
    rng = np.random.default_rng(0)
    b, N = 9, env.num_layers
    pe = env.pe_table[rng.integers(0, 12, (b, N))].astype(np.float32)
    kt = env.kt_table[rng.integers(0, 12, (b, N))].astype(np.float32)
    df = np.full((b, N), ECFG.dataflow, np.float32)

    bat = CostEvalBatcher()
    try:
        costs = bat.evaluate_costs(layers, pe, kt, df, ECFG,
                                   float(env.budget))
        assert costs.shape == (b, 4)
        local = make_local_costs_eval(env, ECFG, use_kernel=False)
        np.testing.assert_array_equal(costs,
                                      np.asarray(local(pe, kt, df)))
        # Scalar fitness over the same points: all cache hits, zero fresh.
        misses = bat.cache.misses
        fit = bat.evaluate(layers, pe, kt, df, ECFG, float(env.budget))
        assert bat.cache.misses == misses
        # And the scalar view agrees with the multi view's objective.
        feasible = np.isfinite(fit)
        np.testing.assert_array_equal(fit[feasible], costs[feasible, 0])
    finally:
        bat.close()


def test_cache_keys_never_collide_across_workloads():
    """Two different layer descriptors with the SAME (pe, kt, df) must
    occupy distinct cache entries -- the key covers the full point row."""
    from repro.costmodel import layers_to_array
    from repro.costmodel.layers import LayerSpec
    from repro.serving.batcher import pack_point_rows

    a = layers_to_array([LayerSpec.gemm(64, 64, 64)])
    c = layers_to_array([LayerSpec.conv(16, 16, 14, 14, 3, 3)])
    pe = np.asarray([[32.0]], np.float32)
    kt = np.asarray([[4.0]], np.float32)
    df = np.asarray([[0.0]], np.float32)
    rows_a = pack_point_rows(a, pe, kt, df)
    rows_c = pack_point_rows(c, pe, kt, df)
    assert rows_a.tobytes() != rows_c.tobytes()

    bat = CostEvalBatcher()
    try:
        budget = 1e18
        fa = bat.evaluate(a, pe, kt, df, ECFG, budget)
        fc = bat.evaluate(c, pe, kt, df, ECFG, budget)
        assert len(bat.cache) == 2          # one entry per distinct row
        assert bat.cache.misses == 2        # no cross-workload hit
        assert fa[0] != fc[0]               # and genuinely different costs
    finally:
        bat.close()
