"""Sharding rules: divisibility guards, per-family placement, policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade property tests to skips, not collection errors
    from hypothesis_stub import given, settings, st
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding
from repro.models import lm


@pytest.fixture(scope="module")
def mesh():
    # single CPU device: a (1,1) mesh still exercises all the rule logic
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_rules_match_paths(mesh):
    assert sharding.param_spec(mesh, "blocks/attn/wq", (64, 64)) == \
        P("data", "model")
    assert sharding.param_spec(mesh, "blocks/attn/wo", (64, 64)) == \
        P("model", "data")
    assert sharding.param_spec(mesh, "blocks/moe/w_gate", (8, 64, 64)) == \
        P("model", "data", None)
    assert sharding.param_spec(mesh, "embed/tok", (256, 64)) == \
        P("model", "data")
    assert sharding.param_spec(mesh, "blocks/ln1", (64,)) == P()
    # stacked leading dims replicate
    assert sharding.param_spec(mesh, "blocks/mlp/w_up", (4, 64, 64)) == \
        P(None, "data", "model")


def test_divisibility_fallback():
    """A dim that doesn't divide the axis falls back, never errors."""
    big = jax.make_mesh((1, 1), ("data", "model"))
    # pretend-mesh of size 1 always divides; test assign_spec directly
    spec = sharding.assign_spec(big, (7, 13), ((("model",),), (("data",),)))
    assert spec == P("model", "data")  # size-1 axes divide everything

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 16}

    spec = sharding.assign_spec(FakeMesh(), (7, 64),
                                ((("model",),), (("model",), ("data",))))
    assert spec == P(None, "model")  # 7 % 16 != 0 -> None; 64 % 16 == 0


@settings(max_examples=50, deadline=None)
@given(d0=st.integers(1, 512), d1=st.integers(1, 512),
       data=st.sampled_from([2, 4, 16]), model=st.sampled_from([2, 16]))
def test_assign_spec_properties(d0, d1, data, model):
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": data, "model": model}

    spec = sharding.assign_spec(
        FakeMesh(), (d0, d1),
        ((("data",), ("model",)), (("model",), ("data",))))
    sizes = {"data": data, "model": model}
    used = [a for a in spec if a is not None]
    assert len(used) == len(set(used))        # each axis used at most once
    for dim, ax in zip((d0, d1), spec):
        if ax is not None:
            assert dim % sizes[ax] == 0       # divisibility always honored


@pytest.mark.parametrize("arch", ["qwen3_32b", "qwen3_moe_235b",
                                  "mamba2_130m", "zamba2_1p2b"])
def test_tree_shardings_cover_params(mesh, arch):
    cfg = configs.get_smoke(arch)
    shapes = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    sh = sharding.tree_shardings(mesh, shapes)
    assert jax.tree_util.tree_structure(sh) == \
        jax.tree_util.tree_structure(shapes)


def test_policy_noop_on_tiny_mesh(mesh):
    pol = sharding.make_policy(mesh, batch=4, kind="train")
    x = jnp.ones((4, 8, 16))
    np.testing.assert_array_equal(np.asarray(pol.resid(x)), np.asarray(x))


def test_batch_axis_selection():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    assert sharding._batch_axis(FakeMesh(), 256) == ("pod", "data")
    assert sharding._batch_axis(FakeMesh(), 16) == ("data",)
    assert sharding._batch_axis(FakeMesh(), 1) is None
