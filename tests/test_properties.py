"""Hypothesis property tests on system-level invariants."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade property tests to skips, not collection errors
    from hypothesis_stub import given, settings, st

from repro.core import env as env_lib
from repro.core import ga as ga_lib
from repro.core import reinforce
from repro.costmodel import dataflows as dfl
from repro.costmodel.layers import LayerSpec

WL = [LayerSpec.conv(16, 8, 14, 14, 3, 3),
      LayerSpec.dwconv(32, 7, 7, 3, 3),
      LayerSpec.gemm(32, 64, 64)]


@settings(max_examples=15, deadline=None)
@given(pe=st.lists(st.integers(1, 128), min_size=3, max_size=3),
       kt=st.lists(st.integers(1, 12), min_size=3, max_size=3),
       df=st.sampled_from([dfl.DLA, dfl.EYE, dfl.SHI]))
def test_lp_constraint_is_sum_of_layers(pe, kt, df):
    """LP whole-model constraint == sum of per-layer constraints."""
    ecfg = env_lib.EnvConfig(platform="cloud", dataflow=df)
    env = env_lib.make_env(WL, ecfg)
    pe_a = jnp.asarray(pe, jnp.float32)
    kt_a = jnp.asarray(kt, jnp.float32)
    _, cons, _ = env_lib.genome_cost(env, ecfg, pe_a, kt_a, df)
    per_layer = sum(
        float(env_lib.layer_cost(env, ecfg, t, pe_a[t], kt_a[t], df)[1])
        for t in range(3))
    np.testing.assert_allclose(float(cons), per_layer, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rollout_rewards_nonnegative_while_feasible(seed):
    """Paper SIII-E: R = P_t - P_min >= 0 whenever the budget holds."""
    import jax

    ecfg = env_lib.EnvConfig(platform="cloud")
    env = env_lib.make_env(WL, ecfg)
    pcfg = __import__("repro.core.policy", fromlist=["PolicyConfig"]
                      ).PolicyConfig(obs_dim=ecfg.obs_dim)
    params = __import__("repro.core.policy", fromlist=["init_params"]
                        ).init_params(jax.random.PRNGKey(seed), pcfg)
    rollout = reinforce.make_rollout(ecfg, pcfg, env, 0.9)
    out = rollout(params, jnp.asarray(jnp.inf, jnp.float32),
                  jax.random.PRNGKey(seed + 1))
    r = np.asarray(out.rewards)
    mask = np.asarray(out.mask).astype(bool)
    feasible = bool(out.feasible)
    if feasible:
        assert (r[mask] >= -1e-5).all(), r


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_local_ga_never_worse_than_feasible_seed(seed):
    """Stage-2 fine-tune keeps the seed in the elite: monotone improvement."""
    ecfg = env_lib.EnvConfig(platform="cloud")
    env = env_lib.make_env(WL, ecfg)
    rng = np.random.default_rng(seed)
    pe = env.pe_table[rng.integers(0, 12, size=3)]
    kt = env.kt_table[rng.integers(0, 12, size=3)]
    perf, _, feas = env_lib.genome_cost(
        env, ecfg, jnp.asarray(pe, jnp.float32),
        jnp.asarray(kt, jnp.float32), ecfg.dataflow)
    if not bool(feas):
        return
    res = ga_lib.local_ga(WL, ecfg, pe, kt,
                          np.full(3, ecfg.dataflow, np.int32),
                          ga_lib.LocalGAConfig(population=8,
                                               generations=40, seed=seed))
    assert float(res.best_value) <= float(perf) * (1 + 1e-6)


def test_collective_loop_scaling_monotone():
    """Loop-scaled collective bytes >= unscaled (trip counts >= 1)."""
    from repro.distributed import hlo_analysis
    hlo = """
HloModule m
%cond (s: (s32[], f32[8])) -> pred[] {
  %s = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %k = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}
%body (s: (s32[], f32[8])) -> (s32[], f32[8]) {
  %s = (s32[], f32[8]) parameter(0)
  %x = f32[8]{0} get-tuple-element(%s), index=1
  %ar = f32[8]{0} all-reduce(%x), channel_id=1, replica_groups={{0,1}}, to_apply=%add
  %i = s32[] get-tuple-element(%s), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}
ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8]) tuple(%z, %p)
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %o = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    scaled = hlo_analysis.collective_stats(hlo)
    raw = hlo_analysis.collective_stats(hlo, scale_loops=False)
    assert scaled["all-reduce"] == 5 * raw["all-reduce"] > 0
