"""REINFORCE core: reward shaping semantics + search convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import env as env_lib
from repro.core import ga as ga_lib
from repro.core import policy as policy_lib
from repro.core import reinforce, search
from repro.costmodel import workloads
from repro.costmodel.layers import LayerSpec


def _tiny_workload():
    return [LayerSpec.conv(32, 16, 28, 28, 3, 3),
            LayerSpec.dwconv(64, 14, 14, 3, 3),
            LayerSpec.gemm(64, 256, 128)]


def _rollout_once(ecfg, seed=0):
    env = env_lib.make_env(_tiny_workload(), ecfg)
    pcfg = policy_lib.PolicyConfig(obs_dim=ecfg.obs_dim, mix=ecfg.mix)
    params = policy_lib.init_params(jax.random.PRNGKey(seed), pcfg)
    rollout = reinforce.make_rollout(ecfg, pcfg, env, 0.9)
    return rollout(params, jnp.asarray(jnp.inf), jax.random.PRNGKey(seed))


def test_rewards_nonnegative_while_feasible():
    """R = P_t - P_min >= 0 whenever the budget holds (SIII-E)."""
    ecfg = env_lib.EnvConfig(platform="unlimited")
    out = _rollout_once(ecfg)
    assert bool(out.feasible)
    assert np.all(np.asarray(out.rewards) >= -1e-4)


def test_violation_penalty_is_negative_accumulated():
    """Violating step reward == -(sum of previous rewards); episode ends."""
    ecfg = env_lib.EnvConfig(platform="iotx")
    found = False
    for seed in range(20):
        out = _rollout_once(ecfg, seed)
        r = np.asarray(out.rewards)
        m = np.asarray(out.mask)
        if not bool(out.feasible):
            t = int(m.sum()) - 1          # the violating step
            assert r[t] <= 0
            assert r[t] == pytest.approx(-r[:t].sum(), rel=1e-4, abs=1e-3)
            assert np.all(m[t + 1:] == 0)  # steps after violation masked
            found = True
            break
    assert found, "no violating episode found under IoTx"


def test_pmin_monotone():
    ecfg = env_lib.EnvConfig(platform="unlimited")
    env = env_lib.make_env(_tiny_workload(), ecfg)
    pcfg = policy_lib.PolicyConfig(obs_dim=ecfg.obs_dim)
    params = policy_lib.init_params(jax.random.PRNGKey(0), pcfg)
    rollout = reinforce.make_rollout(ecfg, pcfg, env, 0.9)
    pmin = jnp.asarray(jnp.inf)
    prev = np.inf
    for s in range(5):
        out = rollout(params, pmin, jax.random.PRNGKey(s))
        pmin = out.pmin
        assert float(pmin) <= prev
        prev = float(pmin)


def test_search_converges_and_beats_random():
    ecfg = env_lib.EnvConfig(platform="iot")
    rcfg = reinforce.ReinforceConfig(epochs=300, episodes_per_epoch=4,
                                     lr=3e-3, seed=0)
    state, hist = reinforce.run_search(_tiny_workload(), ecfg, rcfg)
    assert np.isfinite(hist["best_value"][-1])
    # improves over its first feasible value
    finite = hist["best_value"][np.isfinite(hist["best_value"])]
    assert finite[-1] < finite[0]
    # the solution respects the constraint when re-evaluated
    env = env_lib.make_env(_tiny_workload(), ecfg)
    pe, kt, df = reinforce.solution_arrays(state, env)
    perf, cons, feas = env_lib.genome_cost(env, ecfg, pe, kt, df)
    assert bool(feas)
    assert float(perf) == pytest.approx(float(state.best_value), rel=1e-4)


def test_mix_agent_runs():
    ecfg = env_lib.EnvConfig(platform="iot", mix=True)
    rcfg = reinforce.ReinforceConfig(epochs=100, episodes_per_epoch=2)
    state, hist = reinforce.run_search(_tiny_workload(), ecfg, rcfg)
    assert np.isfinite(hist["best_value"][-1])
    assert set(np.unique(np.asarray(state.best_df))) <= {0, 1, 2}


def test_mlp_policy_runs():
    ecfg = env_lib.EnvConfig(platform="cloud")
    pcfg = policy_lib.PolicyConfig(obs_dim=ecfg.obs_dim, kind="mlp")
    rcfg = reinforce.ReinforceConfig(epochs=50, episodes_per_epoch=2)
    state, hist = reinforce.run_search(_tiny_workload(), ecfg, rcfg, pcfg)
    assert np.isfinite(hist["best_value"][-1])


def test_two_stage_improves():
    ecfg = env_lib.EnvConfig(platform="iot")
    res = search.confuciux_search(
        _tiny_workload(), ecfg,
        reinforce.ReinforceConfig(epochs=200, episodes_per_epoch=4),
        ga_lib.LocalGAConfig(generations=200))
    assert res.best_value <= res.stage1_value
    assert res.stage1_value <= res.initial_valid_value


def test_ls_per_layer_optima():
    ecfg = env_lib.EnvConfig(platform="unlimited", scenario="LS")
    grids = search.per_layer_optima(_tiny_workload(), ecfg)
    assert grids["latency"].shape[0] == 3
    # each layer's optimum is the true grid argmin
    for i in range(3):
        m = grids["latency"][i]
        assert m.min() == m[tuple(grids["optima_latency"][i])]
