"""Cross-optimizer conformance: every registry name honors the unified
``SearchRequest``/``SearchOutcome`` contract.

Parametrized over ``api.list_optimizers()`` -- a newly registered method is
covered automatically (and fails here first if it breaks the schema).  The
contract, per method:

  * fixed seed => deterministic ``SearchOutcome`` (best/history/pe/kt bytes);
  * ``history`` is a per-sample best-so-far trace: length == ``eps``,
    monotone non-increasing once finite, ending at ``best_value``;
  * streamed ``Trial``s cover the full budget (max step == eps, monotone
    per shard) -- trial accounting matches the request;
  * chunked engines (the RL family, ga, sa) stream at least one Trial
    *before* completion (live progress, not a post-hoc replay).
"""
import numpy as np
import pytest

from repro import api
from repro.core import env as env_lib

ECFG = env_lib.EnvConfig(platform="cloud")

# Per-method budget/options keeping the sweep fast on a 2-core container.
# Every canonical registry name must appear here -- the completeness test
# below fails when a new optimizer is registered without a conformance row.
CASES = {
    "random": (150, {}),
    "grid": (150, {}),
    "bo": (150, {"init_random": 32, "batch": 16}),
    "sa": (150, {}),
    "ga": (120, {"population": 30}),
    "reinforce": (30, {}),
    "two_stage": (30, {"ga": {"generations": 40}}),
    "a2c": (20, {}),
    "ppo2": (20, {}),
    "fanout": (100, {"inner": "random", "n_shards": 2, "backend": "serial"}),
    "dist_reinforce": (20, {}),
    "relaxed": (60, {"steps_per_eval": 5, "restarts": 2}),
    "nsga2": (120, {"population": 30}),
}

# Engines that stream live through on_chunk (cancellation points); the
# single-shot baselines emit their trace post-hoc instead.
CHUNKED = ("reinforce", "two_stage", "a2c", "ppo2", "ga", "sa", "relaxed",
           "nsga2")


def _req(method, **kw):
    eps, options = CASES[method]
    return api.SearchRequest(workload="ncf", env=ECFG, eps=eps, seed=7,
                             method=method, options=dict(options), **kw)


def test_every_registered_method_has_a_conformance_case():
    assert set(CASES) == set(api.list_optimizers())


@pytest.mark.parametrize("method", sorted(CASES))
def test_outcome_contract(method):
    eps = CASES[method][0]
    out = api.run_search(_req(method))
    assert out.method == method
    assert out.eps == eps and out.seed == 7
    assert out.history.shape == (eps,)
    finite = out.history[np.isfinite(out.history)]
    assert np.all(np.diff(finite) <= 1e-9)      # monotone best-so-far
    assert out.history[-1] == pytest.approx(out.best_value)
    N = out.pe.shape[0]
    assert out.pe.shape == out.kt.shape == out.df.shape == (N,)
    assert 1 <= out.samples_to_convergence <= eps
    assert out.feasible == bool(np.isfinite(out.best_value))


@pytest.mark.parametrize("method", sorted(CASES))
def test_fixed_seed_is_deterministic(method):
    a = api.run_search(_req(method))
    b = api.run_search(_req(method))
    assert a.best_value == b.best_value
    assert a.history.tobytes() == b.history.tobytes()
    assert a.pe.tobytes() == b.pe.tobytes()
    assert a.kt.tobytes() == b.kt.tobytes()


@pytest.mark.parametrize("method", sorted(CASES))
def test_trial_stream_covers_the_budget(method):
    eps = CASES[method][0]
    trials = []
    out = api.run_search(_req(method, on_progress=trials.append,
                              progress_every=max(eps // 3, 1)))
    assert trials, "no Trial ever streamed"
    by_shard = {}
    for t in trials:
        assert 1 <= t.step <= eps
        by_shard.setdefault(t.shard, []).append(t.step)
    for steps in by_shard.values():
        assert steps == sorted(steps)           # monotone per shard
        assert steps[-1] == eps                 # full budget accounted
    # best_value converges to the outcome's best.
    assert min(t.best_value for t in trials) == pytest.approx(out.best_value)


@pytest.mark.parametrize("method", sorted(CASES))
def test_reported_best_is_feasible(method):
    """Registry-wide guarantee: a reported best assignment satisfies the
    platform budget under ``aggregate_costs`` -- no optimizer may claim a
    feasible outcome whose genome the env rejects."""
    import jax.numpy as jnp

    out = api.run_search(_req(method))
    if not out.feasible:
        return
    from repro.costmodel import workloads

    env = env_lib.make_env(workloads.get_workload("ncf"), ECFG)
    ok = env_lib.feasibility_mask(
        env, ECFG, jnp.asarray(out.pe, jnp.float32),
        jnp.asarray(out.kt, jnp.float32), np.asarray(out.df))
    assert bool(ok), (out.pe, out.kt, out.df)


@pytest.mark.parametrize("method", CHUNKED)
def test_chunked_engines_stream_before_completion(method):
    """Live streaming: the first Trial arrives mid-run (step < eps), not as
    a post-hoc replay of a finished trace -- this is the cancellation
    point the search service relies on."""
    eps = CASES[method][0]
    trials = []
    api.run_search(_req(method, on_progress=trials.append,
                        progress_every=max(eps // 3, 1)))
    assert len(trials) >= 2
    assert trials[0].step < eps


@pytest.mark.parametrize("method", sorted(CASES))
def test_telemetry_is_observational(method):
    """Registry-wide byte-identity: enabling ``repro.obs`` telemetry never
    changes a search result.  The instrumented run must also come back with
    a populated ``outcome.telemetry`` (hard-eval accounting at minimum)."""
    from repro import obs

    plain = api.run_search(_req(method))
    obs.reset()
    obs.enable(trace=True)
    try:
        instrumented = api.run_search(_req(method))
    finally:
        obs.disable()

    assert plain.best_value == instrumented.best_value
    assert plain.history.tobytes() == instrumented.history.tobytes()
    assert plain.pe.tobytes() == instrumented.pe.tobytes()
    assert plain.kt.tobytes() == instrumented.kt.tobytes()
    assert plain.df.tobytes() == instrumented.df.tobytes()
    assert plain.telemetry is None
    t = instrumented.telemetry
    assert t is not None and t["engine"] == method
    assert t.get("hard_evals", 0) > 0, t
