"""HLO parsing: collective byte accounting + while-trip scaling."""
import pytest

from repro.distributed import hlo_analysis as H

SYNTH = """\
HloModule test

%wide.body_spmd (wide.param: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %ag.1 = f32[8,64]{1,0} all-gather(%x), dimensions={1}
  %inner = (s32[], f32[4]) while(%t), condition=%inner.cond, body=%inner.body
  ROOT %r = (s32[], f32[8,16]) tuple(%i, %y)
}

%inner.body (p0: (s32[], f32[4])) -> (s32[], f32[4]) {
  %q = (s32[], f32[4]) parameter(0)
  %ar.2 = f32[4]{0} all-reduce(%z), to_apply=%add
  ROOT %r2 = (s32[], f32[4]) tuple(%j, %w)
}

%inner.cond (p1: (s32[], f32[4])) -> pred[] {
  %iv = s32[] get-tuple-element(%p1), index=0
  %limit = s32[] constant(5)
  ROOT %cmp = pred[] compare(%iv, %limit), direction=LT
}

%wide.cond_spmd (wp: (s32[], f32[8,16])) -> pred[] {
  %iv2 = s32[] get-tuple-element(%wp), index=0
  %lim2 = s32[] constant(12)
  ROOT %c2 = pred[] compare(%iv2, %lim2), direction=LT
}

ENTRY %main_spmd (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %ar.0 = f32[8,16]{1,0} all-reduce(%a), to_apply=%add
  %loop = (s32[], f32[8,16]) while(%init), condition=%wide.cond_spmd, body=%wide.body_spmd
  ROOT %out = f32[8,16] get-tuple-element(%loop), index=1
}
"""


def test_shape_bytes():
    assert H._shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert H._shape_bytes("(bf16[2,4], s32[3])") == 2 * 4 * 2 + 3 * 4
    assert H._shape_bytes("pred[]") == 1


def test_multipliers_nested():
    mult = H.computation_multipliers(SYNTH)
    assert mult["wide.body_spmd"] == 12
    assert mult["inner.body"] == 12 * 5


def test_collective_scaling():
    raw = H.collective_stats(SYNTH, scale_loops=False)
    scaled = H.collective_stats(SYNTH)
    # entry all-reduce 8*16*4; inner all-reduce 4*4 (x60); ag 8*64*4 (x12)
    assert raw["all-reduce"] == 8 * 16 * 4 + 4 * 4
    assert scaled["all-reduce"] == 8 * 16 * 4 + 4 * 4 * 60
    assert scaled["all-gather"] == 8 * 64 * 4 * 12
    assert scaled["total_wire_bytes"] == pytest.approx(
        2 * scaled["all-reduce"] + scaled["all-gather"])


def test_roofline_terms():
    t = H.roofline_terms(197e12, 819e9, 50e9)
    assert t["t_compute"] == pytest.approx(1.0)
    assert t["t_memory"] == pytest.approx(1.0)
    assert t["t_collective"] == pytest.approx(1.0)
    t2 = H.roofline_terms(1e12, 819e9 * 10, 0)
    assert t2["bottleneck"] == "t_memory"
