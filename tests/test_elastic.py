"""Elastic restart: shrink + grow the mesh mid-training; the deterministic
data pipeline + resharding checkpoints must reproduce the uninterrupted
loss trajectory (examples/elastic_restart.py as a test)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_elastic_restart_matches_uninterrupted():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples",
                                      "elastic_restart.py")],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-1500:])
    assert "ELASTIC RESTART OK" in out.stdout
