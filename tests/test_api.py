"""Unified optimizer API: registry round-trip, legacy parity, schema."""
import numpy as np
import pytest

from repro import api
from repro.core import baselines, env as env_lib, reinforce
from repro.costmodel.layers import LayerSpec

EXPECTED_METHODS = {"reinforce", "two_stage", "ga", "sa", "bo", "random",
                    "grid", "a2c", "ppo2", "fanout", "dist_reinforce"}


def _wl():
    return [LayerSpec.conv(32, 16, 28, 28, 3, 3),
            LayerSpec.dwconv(64, 14, 14, 3, 3),
            LayerSpec.gemm(64, 256, 128)]


ECFG = env_lib.EnvConfig(platform="cloud")


def _req(method, eps=200, seed=0, **kw):
    return api.SearchRequest(workload=_wl(), env=ECFG, eps=eps, seed=seed,
                             method=method, **kw)


# ---------------------------------------------------------------------------
# Registry round-trip.
# ---------------------------------------------------------------------------
def test_registry_lists_every_method():
    assert EXPECTED_METHODS <= set(api.list_optimizers())


def test_every_name_resolves_to_an_optimizer():
    for name in api.list_optimizers():
        opt = api.get_optimizer(name)
        assert opt.name == name
        assert callable(opt.run)


def test_aliases_resolve_to_canonical_methods():
    assert api.get_optimizer("ppo").name == "ppo2"
    assert api.get_optimizer("bayes").name == "bo"
    assert api.get_optimizer("conx").name == "two_stage"


def test_unknown_name_raises_keyerror_listing_methods():
    with pytest.raises(KeyError, match="no_such_method"):
        api.get_optimizer("no_such_method")


# ---------------------------------------------------------------------------
# Parity with the legacy entry points (fixed seed, small eps).
# ---------------------------------------------------------------------------
def test_random_parity_with_legacy():
    out = api.run_search(_req("random", eps=200, seed=3))
    legacy = baselines.random_search(_wl(), ECFG, eps=200, seed=3)
    assert out.best_value == float(legacy.best_value)
    np.testing.assert_array_equal(out.pe, legacy.best_pe)


def test_sa_parity_with_legacy():
    out = api.run_search(_req("sa", eps=150, seed=5))
    legacy = baselines.simulated_annealing(
        _wl(), ECFG, eps=150, cfg=baselines.SAConfig(seed=5))
    assert out.best_value == float(legacy.best_value)


def test_reinforce_parity_with_legacy():
    out = api.run_search(_req("reinforce", eps=80, seed=7))
    state, hist = reinforce.run_search(
        _wl(), ECFG,
        reinforce.ReinforceConfig(epochs=80, episodes_per_epoch=1, seed=7))
    assert out.best_value == pytest.approx(float(state.best_value))
    np.testing.assert_allclose(out.history, hist["best_value"])


# ---------------------------------------------------------------------------
# Outcome schema.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method,eps", [
    ("random", 150), ("grid", 150), ("sa", 150), ("ga", 150), ("bo", 150),
    ("reinforce", 60), ("two_stage", 60),
])
def test_outcome_schema(method, eps):
    out = api.run_search(_req(method, eps=eps))
    assert out.method == method
    assert len(out.history) == eps
    finite = out.history[np.isfinite(out.history)]
    # Monotone non-increasing best-so-far; inf prefix allowed.
    assert np.all(np.diff(finite) <= 1e-9)
    assert out.history[-1] == pytest.approx(out.best_value)
    assert out.pe.shape == out.kt.shape == out.df.shape == (3,)
    assert 1 <= out.samples_to_convergence <= eps
    assert out.wall_seconds >= 0
    assert out.feasible == bool(np.isfinite(out.best_value))


def test_expand_trace_credits_spans_honestly():
    """A span's best lands on its LAST sample; earlier samples inherit the
    previous span's best (no look-ahead, mirroring the baselines fix)."""
    from repro.api import types
    tr = types.expand_trace([5.0, 3.0, 4.0], span=4)
    assert len(tr) == 12
    assert np.all(np.isinf(tr[:3])) and tr[3] == 5.0
    assert np.all(tr[4:7] == 5.0) and tr[7] == 3.0
    assert np.all(tr[8:] == 3.0)  # best-so-far, span 3 never improved


def test_fanout_rejects_self_nesting():
    with pytest.raises(ValueError, match="nest itself"):
        api.run_search(_req("fanout", eps=50, options={"inner": "fanout"}))


def test_two_stage_outcome_carries_stage_breakdown():
    out = api.run_search(_req("two_stage", eps=80,
                              options={"ga": {"generations": 60}}))
    assert out.best_value <= out.extras["stage1_value"]
    assert out.extras["stage1_value"] <= out.extras["initial_valid_value"]
    assert len(out.history) == 80


def test_one_shared_options_dict_works_across_methods():
    """Adapters ignore options they don't understand (method sweeps)."""
    opts = {"population": 30, "temperature": 5.0, "episodes_per_epoch": 2}
    for method in ("ga", "sa", "random"):
        out = api.run_search(_req(method, eps=100, options=opts))
        assert len(out.history) == 100


# ---------------------------------------------------------------------------
# Progress callbacks.
# ---------------------------------------------------------------------------
def test_progress_callback_streams_trials():
    trials = []
    out = api.run_search(_req("random", eps=200, on_progress=trials.append,
                              progress_every=50))
    assert len(trials) == 4
    steps = [t.step for t in trials]
    assert steps == sorted(steps) and steps[-1] == 200
    assert trials[-1].best_value == pytest.approx(out.best_value)


def test_reinforce_streaming_matches_single_shot():
    """Chunked (streaming) runs are bit-identical to one-shot runs."""
    plain = api.run_search(_req("reinforce", eps=60, seed=11))
    trials = []
    streamed = api.run_search(_req("reinforce", eps=60, seed=11,
                                   on_progress=trials.append,
                                   progress_every=20))
    assert streamed.best_value == pytest.approx(plain.best_value)
    assert len(trials) == 3
    np.testing.assert_allclose(streamed.history, plain.history)


@pytest.mark.parametrize("method", ["a2c", "ppo2"])
def test_actor_critic_streams_live_and_matches_single_shot(method):
    """a2c/ppo2 stream through on_chunk like reinforce (no carve-out)."""
    plain = api.run_search(_req(method, eps=30, seed=4))
    trials = []
    streamed = api.run_search(_req(method, eps=30, seed=4,
                                   on_progress=trials.append,
                                   progress_every=10))
    assert streamed.best_value == pytest.approx(plain.best_value)
    assert len(trials) == 3
    steps = [t.step for t in trials]
    assert steps == sorted(steps) and steps[-1] == 30
    np.testing.assert_allclose(streamed.history, plain.history)


def test_ac_search_resumes_from_prior_state():
    """run_ac_search continues bit-identically from a returned state."""
    from repro.core import rl_baselines

    full_cfg = rl_baselines.ACConfig(algo="a2c", epochs=20,
                                     episodes_per_epoch=1, seed=9)
    half_cfg = rl_baselines.ACConfig(algo="a2c", epochs=10,
                                     episodes_per_epoch=1, seed=9)
    state_full, hist_full = rl_baselines.run_ac_search(_wl(), ECFG, full_cfg)
    state_half, hist_a = rl_baselines.run_ac_search(_wl(), ECFG, half_cfg)
    state_res, hist_b = rl_baselines.run_ac_search(_wl(), ECFG, half_cfg,
                                                   state=state_half)
    assert float(state_res.best_value) == float(state_full.best_value)
    np.testing.assert_array_equal(
        np.concatenate([hist_a["best_value"], hist_b["best_value"]]),
        hist_full["best_value"])


def test_ga_search_resumes_from_prior_state():
    """run_ga_search: one full run == two halves stitched via ``state=``."""
    from repro.core import ga as ga_lib

    full_cfg = ga_lib.GAConfig(population=20, generations=20, seed=9)
    half_cfg = ga_lib.GAConfig(population=20, generations=10, seed=9)
    state_full, hist_full = ga_lib.run_ga_search(_wl(), ECFG, full_cfg)
    state_half, hist_a = ga_lib.run_ga_search(_wl(), ECFG, half_cfg)
    state_res, hist_b = ga_lib.run_ga_search(_wl(), ECFG, half_cfg,
                                             state=state_half)
    assert float(state_res.best_val) == float(state_full.best_val)
    assert int(state_res.generation) == 20
    assert np.concatenate([hist_a, hist_b]).tobytes() == hist_full.tobytes()
    assert (np.asarray(state_res.best_genome).tobytes()
            == np.asarray(state_full.best_genome).tobytes())


def test_sa_search_resumes_from_prior_state():
    """run_sa_search: one full run == two halves stitched via ``state=``."""
    cfg = baselines.SAConfig(seed=5)
    state_full, hist_full = baselines.run_sa_search(_wl(), ECFG, 100, cfg)
    state_half, hist_a = baselines.run_sa_search(_wl(), ECFG, 50, cfg)
    state_res, hist_b = baselines.run_sa_search(_wl(), ECFG, 50, cfg,
                                                state=state_half)
    assert float(state_res.best_fit) == float(state_full.best_fit)
    assert int(state_res.step) == 100
    assert np.concatenate([hist_a, hist_b]).tobytes() == hist_full.tobytes()
    assert (np.asarray(state_res.best_genome).tobytes()
            == np.asarray(state_full.best_genome).tobytes())


@pytest.mark.parametrize("method,opts", [
    ("ga", {"population": 30}), ("sa", {}),
])
def test_ga_sa_streaming_matches_single_shot(method, opts):
    """Chunked (streaming) GA/SA runs are byte-identical to one-shot runs."""
    plain = api.run_search(_req(method, eps=150, seed=11, options=opts))
    trials = []
    streamed = api.run_search(_req(method, eps=150, seed=11, options=opts,
                                   on_progress=trials.append,
                                   progress_every=50))
    assert streamed.best_value == plain.best_value
    assert streamed.history.tobytes() == plain.history.tobytes()
    assert len(trials) >= 2
    steps = [t.step for t in trials]
    assert steps == sorted(steps) and steps[-1] == 150


# ---------------------------------------------------------------------------
# Distributed wrappers.
# ---------------------------------------------------------------------------
def test_fanout_merges_shards():
    out = api.run_search(_req(
        "fanout", eps=100,
        options={"inner": "random", "n_shards": 3}))
    shard_bests = out.extras["shard_best_values"]
    assert len(shard_bests) == 3
    assert out.best_value == min(shard_bests)
    assert len(out.history) == 100


@pytest.mark.parametrize("inner,eps,iopts", [
    ("random", 200, {}), ("sa", 150, {}), ("reinforce", 30, {}),
])
def test_fanout_threads_parity_with_serial(inner, eps, iopts):
    """threads and serial backends return identical merged outcomes."""
    outs = {}
    for backend in ("serial", "threads"):
        outs[backend] = api.run_search(_req(
            "fanout", eps=eps, seed=2,
            options={"inner": inner, "n_shards": 3, "backend": backend,
                     "inner_options": iopts}))
    a, b = outs["serial"], outs["threads"]
    assert a.best_value == b.best_value
    assert a.history.tobytes() == b.history.tobytes()
    np.testing.assert_array_equal(a.pe, b.pe)
    np.testing.assert_array_equal(a.kt, b.kt)
    assert a.extras["shard_best_values"] == b.extras["shard_best_values"]
    assert a.extras["best_seed"] == b.extras["best_seed"]


@pytest.mark.parametrize("backend", ["serial", "threads"])
def test_fanout_progress_is_shard_tagged_and_monotone(backend):
    """Merged chunks carry their shard id; steps are monotone per shard."""
    trials = []
    out = api.run_search(_req(
        "fanout", eps=200, progress_every=50, on_progress=trials.append,
        options={"inner": "random", "n_shards": 3, "backend": backend}))
    assert sorted({t.shard for t in trials}) == [0, 1, 2]
    for s in range(3):
        steps = [t.step for t in trials if t.shard == s]
        assert steps == sorted(steps) and steps[-1] == 200
    # Ensemble best-so-far is monotone in emission order and ends at the
    # merged best.
    bests = [t.best_value for t in trials]
    assert all(b2 <= b1 for b1, b2 in zip(bests, bests[1:]))
    assert bests[-1] == pytest.approx(out.best_value)


def test_fanout_streaming_rl_inner_matches_unstreamed():
    """Live-streamed fanout (chunked inner) equals the silent run."""
    plain = api.run_search(_req(
        "fanout", eps=30, options={"inner": "reinforce", "n_shards": 2,
                                   "backend": "serial"}))
    trials = []
    streamed = api.run_search(_req(
        "fanout", eps=30, progress_every=10, on_progress=trials.append,
        options={"inner": "reinforce", "n_shards": 2, "backend": "serial"}))
    assert streamed.best_value == plain.best_value
    assert streamed.history.tobytes() == plain.history.tobytes()
    assert len(trials) == 6  # 2 shards x 3 chunks


def test_fanout_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown fanout backend"):
        api.run_search(_req("fanout", eps=50,
                            options={"inner": "random",
                                     "backend": "mpi"}))


def test_fanout_device_backend_requires_jax_native_inner():
    with pytest.raises(ValueError, match="JAX-native"):
        api.run_search(_req("fanout", eps=50,
                            options={"inner": "sa", "backend": "device"}))


def test_fanout_device_backend_requires_enough_devices():
    import jax

    n = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="local devices"):
        api.run_search(_req("fanout", eps=50,
                            options={"inner": "reinforce", "n_shards": n,
                                     "backend": "device"}))
