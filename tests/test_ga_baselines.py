"""GA (both stages) and the classic baselines."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade property tests to skips, not collection errors
    from hypothesis_stub import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import baselines, env as env_lib, ga as ga_lib
from repro.costmodel import dataflows as dfl
from repro.costmodel.layers import LayerSpec


def _wl():
    return [LayerSpec.conv(32, 16, 28, 28, 3, 3),
            LayerSpec.dwconv(64, 14, 14, 3, 3),
            LayerSpec.gemm(64, 256, 128),
            LayerSpec.conv(64, 32, 14, 14, 1, 1)]


ECFG = env_lib.EnvConfig(platform="cloud")


def test_baseline_ga_improves():
    res = ga_lib.baseline_ga(_wl(), ECFG,
                             ga_lib.GAConfig(population=50, generations=30))
    hist = np.asarray(res.history)
    finite = hist[np.isfinite(hist)]
    assert len(finite) and finite[-1] <= finite[0]


def test_ga_fitness_kernel_path_matches_oracle():
    """GAConfig.use_kernel routes fitness through the Pallas batched cost
    kernel (interpret mode off-TPU) with the same feasibility/objective."""
    env = env_lib.make_env(_wl(), ECFG)
    key = jax.random.PRNGKey(0)
    pe = jax.random.choice(key, env.pe_table, (8, env.num_layers))
    kt = jax.random.choice(jax.random.fold_in(key, 1), env.kt_table,
                           (8, env.num_layers))
    df = jnp.asarray(ECFG.dataflow, jnp.int32)
    oracle = ga_lib._fitness(env, ECFG, pe, kt, df, use_kernel=False)
    kernel = ga_lib._fitness(env, ECFG, pe, kt, df, use_kernel=True)
    np.testing.assert_array_equal(np.isfinite(oracle), np.isfinite(kernel))
    finite = np.isfinite(np.asarray(oracle))
    np.testing.assert_allclose(np.asarray(kernel)[finite],
                               np.asarray(oracle)[finite], rtol=1e-5)


def test_local_ga_improves_on_seed_and_stays_feasible():
    env = env_lib.make_env(_wl(), ECFG)
    N = env.num_layers
    init_pe = np.full((N,), 16, np.int32)
    init_kt = np.full((N,), 4, np.int32)
    df = np.zeros((N,), np.int32)
    perf0, cons0, feas0 = env_lib.genome_cost(
        env, ECFG, jnp.asarray(init_pe, jnp.float32),
        jnp.asarray(init_kt, jnp.float32), df)
    assert bool(feas0)
    res = ga_lib.local_ga(_wl(), ECFG, init_pe, init_kt, df,
                          ga_lib.LocalGAConfig(population=16,
                                               generations=150))
    assert float(res.best_value) <= float(perf0) * 1.0001
    perf, cons, feas = env_lib.genome_cost(env, ECFG, res.best_pe,
                                           res.best_kt, res.best_df)
    assert bool(feas)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000))
def test_local_mutation_respects_bounds(seed):
    """Fine-stage genomes always stay in [PE_MIN,PE_MAX] x [KT_MIN,KT_MAX]."""
    res = ga_lib.local_ga(
        _wl(), ECFG, np.full((4,), 100), np.full((4,), 14),
        np.zeros((4,), np.int32),
        ga_lib.LocalGAConfig(population=8, generations=20, seed=seed))
    assert np.all(np.asarray(res.best_pe) >= dfl.PE_MIN)
    assert np.all(np.asarray(res.best_pe) <= dfl.PE_MAX)
    assert np.all(np.asarray(res.best_kt) >= dfl.KT_MIN)
    assert np.all(np.asarray(res.best_kt) <= dfl.KT_MAX)


def test_random_search_feasible_loose_infeasible_tight():
    loose = baselines.random_search(_wl(), ECFG, eps=400)
    assert np.isfinite(loose.best_value)
    tight = baselines.random_search(
        _wl(), env_lib.EnvConfig(platform="iotx"), eps=200)
    # Under IoTx random almost surely fails (paper Table IV "NAN").
    assert not np.isfinite(tight.best_value) or tight.best_value > 0


def test_grid_search_deterministic():
    a = baselines.grid_search(_wl(), ECFG, eps=300)
    b = baselines.grid_search(_wl(), ECFG, eps=300)
    assert a.best_value == b.best_value


def test_simulated_annealing_runs():
    res = baselines.simulated_annealing(_wl(), ECFG, eps=400)
    hist = np.asarray(res.history)
    assert len(hist) == 400
    finite = hist[np.isfinite(hist)]
    if len(finite):
        assert finite[-1] <= finite[0] + 1e-6


def test_bayes_opt_runs_and_improves():
    res = baselines.bayes_opt(_wl(), ECFG, eps=300, seed=0)
    assert np.isfinite(res.best_value)


def test_ga_solution_quality_vs_random():
    """GA should beat random search at equal sample budget (loose cstr).

    2000 samples: below that the comparison is noise on this toy workload.
    """
    ga_res = ga_lib.baseline_ga(
        _wl(), ECFG, ga_lib.GAConfig(population=50, generations=40))
    rnd = baselines.random_search(_wl(), ECFG, eps=2000)
    assert float(ga_res.best_value) <= rnd.best_value * 1.10
