"""NSGA-II engine contract: chunk-invariant resume, eval_fn byte-identity,
frontier semantics, and the multi-DNN co-design scenario.

The cross-method schema checks live in test_optimizer_conformance.py; here:

  * chunk boundaries never change the result (one-shot == chunked == two
    sequential state-fed calls, byte for byte);
  * the injected host ``eval_fn`` path (the service's batcher programs) is
    deterministic and equals what the registry adapter reports;
  * the reported frontier is mutually non-dominating, budget-feasible, and
    its genomes actually realize their stated costs;
  * ``EnvConfig(mix=True)`` co-design over a multi-model workload searches
    per-layer dataflows and still honors the shared budget.
"""
import numpy as np
import pytest

from repro import api
from repro.core import env as env_lib
from repro.core import nsga2
from repro.costmodel import workloads

ECFG = env_lib.EnvConfig(platform="cloud")
CFG = nsga2.NSGA2Config(population=14, generations=9, seed=5)
NCF = workloads.get_workload("ncf")


def _bytes(state):
    return tuple(np.asarray(x).tobytes() for x in state)


def test_chunk_invariant_one_shot_vs_chunked():
    s1, h1 = nsga2.run_nsga2_search(NCF, ECFG, CFG)
    s2, h2 = nsga2.run_nsga2_search(NCF, ECFG, CFG, chunk=2)
    s3, h3 = nsga2.run_nsga2_search(NCF, ECFG, CFG, chunk=4)
    assert h1.tobytes() == h2.tobytes() == h3.tobytes()
    assert _bytes(s1) == _bytes(s2) == _bytes(s3)


def test_resume_from_state_matches_uninterrupted_run():
    import dataclasses

    s_full, h_full = nsga2.run_nsga2_search(NCF, ECFG, CFG)
    first = dataclasses.replace(CFG, generations=4)
    rest = dataclasses.replace(CFG, generations=5)
    s_a, h_a = nsga2.run_nsga2_search(NCF, ECFG, first)
    s_b, h_b = nsga2.run_nsga2_search(NCF, ECFG, rest, state=s_a)
    assert np.concatenate([h_a, h_b]).tobytes() == h_full.tobytes()
    assert _bytes(s_b) == _bytes(s_full)
    assert int(s_b.generation) == CFG.generations


def test_injected_eval_fn_is_deterministic_and_matches_adapter():
    from repro.serving import batcher as batcher_lib

    env = env_lib.make_env(workloads.get_workload("ncf"), ECFG)
    eval_fn = batcher_lib.make_local_costs_eval(env, ECFG, use_kernel=False)
    s1, h1 = nsga2.run_nsga2_search(NCF, ECFG, CFG, eval_fn=eval_fn,
                                    env=env)
    s2, h2 = nsga2.run_nsga2_search(NCF, ECFG, CFG, chunk=3,
                                    eval_fn=eval_fn, env=env)
    assert h1.tobytes() == h2.tobytes()
    assert _bytes(s1) == _bytes(s2)
    # The registry adapter (which defaults to this very eval path) agrees.
    out = api.run_search(api.SearchRequest(
        workload="ncf", env=ECFG, eps=CFG.population * CFG.generations,
        seed=CFG.seed, method="nsga2", options={"population": CFG.population,
                                                "generations":
                                                CFG.generations}))
    assert out.best_value == pytest.approx(float(s1.best_val))
    assert np.float32(out.history[-1]) == np.float32(s1.best_val)


def _check_frontier(out, wl, ecfg):
    f = out.frontier
    F = len(f["lat"])
    assert F >= 1
    obj = np.stack([f["lat"], f["en"]], axis=-1)
    assert nsga2.non_dominated_mask(obj).all()
    assert np.all(np.diff(f["lat"]) >= 0)          # sorted by latency
    # Every frontier genome realizes its stated costs and fits the budget.
    import jax.numpy as jnp

    env = env_lib.make_env(wl, ecfg)
    for i in range(F):
        tl, te, ta, tp, feas = env_lib.genome_costs_multi(
            env, ecfg, jnp.asarray(f["pe"][i], jnp.float32),
            jnp.asarray(f["kt"][i], jnp.float32), np.asarray(f["df"][i]))
        assert bool(feas)
        np.testing.assert_allclose(
            [float(tl), float(te), float(ta), float(tp)],
            [f["lat"][i], f["en"][i], f["area"][i], f["pw"][i]], rtol=1e-6)
    return F


def test_frontier_is_nondominated_and_feasible():
    out = api.run_search(api.SearchRequest(
        workload="ncf", env=ECFG, eps=150, seed=1, method="nsga2",
        options={"population": 15}))
    _check_frontier(out, workloads.get_workload("ncf"), ECFG)
    # The scalar best is the frontier's best primary objective.
    assert out.best_value == pytest.approx(float(np.min(out.frontier["lat"])))


def test_mix_codesign_searches_dataflows_under_one_budget():
    wl = workloads.multi_dnn(["qwen1p5_0p5b", "whisper_small",
                              "mamba2_130m"], tokens=32)
    names = [l.name for l in wl]
    assert len({n.split(".")[0] for n in names}) == 3   # ragged 3-model mix
    ecfg = env_lib.EnvConfig(platform="cloud", mix=True)
    out = api.run_search(api.SearchRequest(
        workload=wl, env=ecfg, eps=120, seed=0, method="nsga2",
        options={"population": 12}))
    assert out.feasible
    assert out.df.shape == (len(wl),)
    assert set(np.unique(out.df)) <= {0, 1, 2}          # per-layer dataflow
    _check_frontier(out, wl, ecfg)


def test_aliases_resolve_to_nsga2():
    assert type(api.get_optimizer("pareto")).__name__ == "NSGA2Optimizer"
    assert type(api.get_optimizer("moo")).__name__ == "NSGA2Optimizer"
