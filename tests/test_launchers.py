"""Launcher smoke tests: train (+resume), serve, search CLIs end to end."""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=600, devices=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    if devices:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable, "-m"] + args,
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    return out.stdout


def test_train_launcher_and_resume(tmp_path):
    ckpt = str(tmp_path / "ck")
    out = _run(["repro.launch.train", "--arch", "qwen2p5_3b", "--smoke",
                "--steps", "24", "--batch", "2", "--seq", "32", "--f32",
                "--ckpt-dir", ckpt, "--ckpt-every", "12",
                "--log-every", "12"])
    first = json.loads(out.strip().splitlines()[-1])
    assert first["final_loss"] < first["first_loss"]
    # Resume continues from the saved step.
    out2 = _run(["repro.launch.train", "--arch", "qwen2p5_3b", "--smoke",
                 "--steps", "30", "--batch", "2", "--seq", "32", "--f32",
                 "--ckpt-dir", ckpt, "--resume", "--log-every", "6"])
    assert "resumed from step 24" in out2


def test_train_launcher_sharded():
    out = _run(["repro.launch.train", "--arch", "qwen1p5_0p5b", "--smoke",
                "--steps", "30", "--batch", "4", "--seq", "32", "--f32",
                "--mesh", "2x2", "--log-every", "10"], devices=4)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["final_loss"] < rec["first_loss"]


def test_serve_launcher():
    out = _run(["repro.launch.serve", "--arch", "qwen1p5_0p5b", "--smoke",
                "--f32", "--requests", "4", "--max-new", "4"])
    stats = json.loads(out.strip().splitlines()[-1])
    assert stats["requests"] == 4 and stats["tokens"] == 16


def test_search_launcher(tmp_path):
    out_file = str(tmp_path / "res.json")
    _run(["repro.launch.search", "--workload", "ncf", "--epochs", "150",
          "--ga-generations", "50", "--platform", "iot",
          "--out", out_file])
    rec = json.load(open(out_file))
    assert rec["best_value"] <= rec["stage1_value"]
    assert len(rec["assignment"]["pe"]) == len(rec["assignment"]["layers"])


def test_search_launcher_arch_target(tmp_path):
    out_file = str(tmp_path / "res.json")
    _run(["repro.launch.search", "--arch", "qwen1.5-0.5b", "--tokens", "64",
          "--epochs", "120", "--no-finetune", "--platform", "cloud",
          "--out", out_file])
    rec = json.load(open(out_file))
    assert rec["best_value"] < float("inf")
