"""Multi-device behaviour via subprocesses (the main process keeps 1 CPU
device; --xla_force_host_platform_device_count must be set before jax init).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_search_converges():
    out = run_with_devices("""
import jax, numpy as np
from repro.core import env as env_lib, reinforce
from repro.distributed import dist_search
from repro.costmodel.layers import LayerSpec
wl = [LayerSpec.conv(32,16,28,28,3,3), LayerSpec.dwconv(64,14,14,3,3),
      LayerSpec.gemm(64,256,128)]
mesh = jax.make_mesh((4,2), ("data","model"))
state, hist = dist_search.run_distributed_search(
    wl, env_lib.EnvConfig(platform="iot"), mesh,
    reinforce.ReinforceConfig(epochs=80, lr=3e-3),
    dist_search.DistConfig(episodes_per_device=2))
assert np.isfinite(float(state.best_value)), hist["best_value"][-5:]
first = hist["best_value"][np.isfinite(hist["best_value"])][0]
assert float(state.best_value) <= first
print("OK", float(state.best_value))
""")
    assert "OK" in out


def test_straggler_masking_preserves_convergence():
    out = run_with_devices("""
import jax, numpy as np
from repro.core import env as env_lib, reinforce
from repro.distributed import dist_search
from repro.costmodel.layers import LayerSpec
wl = [LayerSpec.conv(32,16,28,28,3,3), LayerSpec.gemm(64,256,128)]
mesh = jax.make_mesh((4,2), ("data","model"))
mask = np.ones(8, bool); mask[[2,6]] = False
state, hist = dist_search.run_distributed_search(
    wl, env_lib.EnvConfig(platform="iot"), mesh,
    reinforce.ReinforceConfig(epochs=80, lr=3e-3),
    dist_search.DistConfig(episodes_per_device=2), straggler_mask=mask)
assert np.isfinite(float(state.best_value))
print("OK")
""")
    assert "OK" in out


def test_int8_psum_error_bound():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.dist_search import psum_int8
mesh = jax.make_mesh((8,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
def f(xs):
    local = xs[0]
    exact = jax.lax.psum(local, "pod")
    approx = psum_int8(local, "pod")
    return exact[None], approx[None]
exact, approx = shard_map(f, mesh=mesh, in_specs=P("pod", None),
                          out_specs=P("pod", None))(x)
err = float(jnp.abs(exact - approx).max())
scale = float(jnp.abs(x).max()) / 127.0
assert err <= 8 * scale * 0.51 + 1e-6, (err, scale)  # n * scale/2 bound
print("OK", err)
""")
    assert "OK" in out


def test_int8_compressed_pod_reduction_converges():
    out = run_with_devices("""
import jax, numpy as np
from repro.core import env as env_lib, reinforce
from repro.distributed import dist_search
from repro.costmodel.layers import LayerSpec
wl = [LayerSpec.conv(32,16,28,28,3,3), LayerSpec.gemm(64,256,128)]
mesh = jax.make_mesh((2,2,2), ("pod","data","model"))
state, hist = dist_search.run_distributed_search(
    wl, env_lib.EnvConfig(platform="iot"), mesh,
    reinforce.ReinforceConfig(epochs=80, lr=3e-3),
    dist_search.DistConfig(episodes_per_device=2, compress_pod_axis=True))
assert np.isfinite(float(state.best_value))
print("OK")
""")
    assert "OK" in out


def test_masked_int8_pod_reduction_matches_plain_masked_psum():
    """Hierarchical masked+compressed reduction == flat masked_psum within
    int8 quantization tolerance, on 1-pod, 4-pod and asymmetric-alive
    meshes (regression: the old path divided by the axis count and a
    hardcoded npods=2)."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.dist_search import masked_psum, masked_hierarchical_psum

def run_case(mesh_shape, axes, alive_np):
    n = int(np.prod(mesh_shape))
    mesh = jax.make_mesh(mesh_shape, axes)
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 64))
    def f(xs, al):
        local, a = xs[0], al[0]
        plain = masked_psum({"g": local}, a, axes)["g"]
        comp = masked_hierarchical_psum({"g": local}, a, axes,
                                        compress=True)["g"]
        return plain[None], comp[None]
    plain, comp = shard_map(f, mesh=mesh, in_specs=(P(axes, None), P(axes)),
                            out_specs=(P(axes, None), P(axes, None)),
                            check_rep=False)(x, jnp.asarray(alive_np))
    plain, comp = np.asarray(plain[0]), np.asarray(comp[0])
    rel = np.abs(plain - comp).max() / max(np.abs(plain).max(), 1e-9)
    assert rel < 0.05, (mesh_shape, axes, rel)
    return rel

# 1-pod mesh (pod axis of size 1: the cross-pod hop is a no-op).
run_case((1, 4), ("pod", "data"), np.ones(4, bool))
# 4-pod mesh, all alive (old code scaled by npods=2 -> 2x error).
run_case((4, 2), ("pod", "data"), np.ones(8, bool))
# Asymmetric alive: pod 0 keeps 1 of 2 devices, others keep 2 -- per-pod
# means averaged across pods would NOT equal the global masked mean.
mask = np.ones(8, bool); mask[[1, 2, 3]] = False
run_case((4, 2), ("pod", "data"), mask)
# Pod-only mesh: empty in-pod axis set.
mask = np.ones(8, bool); mask[5] = False
run_case((8,), ("pod",), mask)
print("OK")
""")
    assert "OK" in out


def test_fanout_device_backend_bit_identical_to_serial():
    """fanout backend='device' == backend='serial' for reinforce and ga."""
    out = run_with_devices("""
import numpy as np
from repro import api
from repro.core import env as env_lib
from repro.costmodel.layers import LayerSpec

wl = [LayerSpec.conv(32,16,28,28,3,3), LayerSpec.gemm(64,256,128)]
ecfg = env_lib.EnvConfig(platform="cloud")
for inner, eps, iopts in [("reinforce", 40, {}),
                          ("ga", 200, {"population": 20})]:
    outs = {}
    for backend in ("serial", "device"):
        outs[backend] = api.run_search(api.SearchRequest(
            workload=wl, env=ecfg, eps=eps, seed=3, method="fanout",
            options={"inner": inner, "n_shards": 4, "backend": backend,
                     "inner_options": iopts}))
    a, b = outs["serial"], outs["device"]
    assert a.best_value == b.best_value, (inner, a.best_value, b.best_value)
    assert a.history.tobytes() == b.history.tobytes(), inner
    np.testing.assert_array_equal(a.pe, b.pe)
    np.testing.assert_array_equal(a.kt, b.kt)
    np.testing.assert_array_equal(a.df, b.df)
    assert a.extras["shard_best_values"] == b.extras["shard_best_values"]
    assert a.extras["best_seed"] == b.extras["best_seed"]
print("OK")
""", n=4)
    assert "OK" in out


def test_fanout_device_backend_streams_tagged_progress():
    """Device backend streams shard-tagged, per-shard-monotone chunks."""
    out = run_with_devices("""
from repro import api
from repro.core import env as env_lib
from repro.costmodel.layers import LayerSpec

wl = [LayerSpec.conv(32,16,28,28,3,3), LayerSpec.gemm(64,256,128)]
trials = []
out = api.run_search(api.SearchRequest(
    workload=wl, env=env_lib.EnvConfig(platform="cloud"), eps=40, seed=3,
    method="fanout", progress_every=10, on_progress=trials.append,
    options={"inner": "reinforce", "n_shards": 4, "backend": "device"}))
assert sorted({t.shard for t in trials}) == [0, 1, 2, 3]
for s in range(4):
    steps = [t.step for t in trials if t.shard == s]
    assert steps == sorted(steps) and steps[-1] == 40, steps
bests = [t.best_value for t in trials]
assert all(b2 <= b1 for b1, b2 in zip(bests, bests[1:]))
print("OK")
""", n=4)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """pjit train step on a (2,2) mesh == unsharded result."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np, dataclasses, functools
from repro import configs
from repro.models import lm
from repro.training import optim
from repro.distributed import sharding
cfg = dataclasses.replace(configs.get_smoke("qwen1p5_0p5b"),
                          param_dtype="float32", compute_dtype="float32")
opt = optim.Adam(lr=1e-3)
params = lm.init_params(jax.random.PRNGKey(0), cfg)
ost = opt.init(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}
step = functools.partial(lm.train_step, cfg=cfg, optimizer=opt)
p1, o1, l1 = jax.jit(step)(params, ost, batch)

mesh = jax.make_mesh((2, 2), ("data", "model"))
psh = sharding.tree_shardings(mesh, params)
params_s = jax.device_put(params, psh)
ost_s = jax.device_put(ost, sharding.tree_shardings(mesh, ost))
batch_s = {k: jax.device_put(v, sharding.batch_sharding(mesh, 4))
           for k, v in batch.items()}
pol = sharding.make_policy(mesh, batch=4, kind="train")
step_s = functools.partial(lm.train_step, cfg=cfg, optimizer=opt, pol=pol)
with mesh:
    p2, o2, l2 = jax.jit(step_s)(params_s, ost_s, batch_s)
assert abs(float(l1) - float(l2)) < 1e-4, (float(l1), float(l2))
# Adam update with lr=1e-3: reduction-order f32 noise in grads moves params
# by O(lr * eps_rel); 5e-4 = half an optimizer step of slack.
d = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.abs(a - np.asarray(b)).max()), p1, p2)))
assert d < 5e-4, d
print("OK", float(l1), d)
""")
    assert "OK" in out
