"""Multi-device behaviour via subprocesses (the main process keeps 1 CPU
device; --xla_force_host_platform_device_count must be set before jax init).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_search_converges():
    out = run_with_devices("""
import jax, numpy as np
from repro.core import env as env_lib, reinforce
from repro.distributed import dist_search
from repro.costmodel.layers import LayerSpec
wl = [LayerSpec.conv(32,16,28,28,3,3), LayerSpec.dwconv(64,14,14,3,3),
      LayerSpec.gemm(64,256,128)]
mesh = jax.make_mesh((4,2), ("data","model"))
state, hist = dist_search.run_distributed_search(
    wl, env_lib.EnvConfig(platform="iot"), mesh,
    reinforce.ReinforceConfig(epochs=80, lr=3e-3),
    dist_search.DistConfig(episodes_per_device=2))
assert np.isfinite(float(state.best_value)), hist["best_value"][-5:]
first = hist["best_value"][np.isfinite(hist["best_value"])][0]
assert float(state.best_value) <= first
print("OK", float(state.best_value))
""")
    assert "OK" in out


def test_straggler_masking_preserves_convergence():
    out = run_with_devices("""
import jax, numpy as np
from repro.core import env as env_lib, reinforce
from repro.distributed import dist_search
from repro.costmodel.layers import LayerSpec
wl = [LayerSpec.conv(32,16,28,28,3,3), LayerSpec.gemm(64,256,128)]
mesh = jax.make_mesh((4,2), ("data","model"))
mask = np.ones(8, bool); mask[[2,6]] = False
state, hist = dist_search.run_distributed_search(
    wl, env_lib.EnvConfig(platform="iot"), mesh,
    reinforce.ReinforceConfig(epochs=80, lr=3e-3),
    dist_search.DistConfig(episodes_per_device=2), straggler_mask=mask)
assert np.isfinite(float(state.best_value))
print("OK")
""")
    assert "OK" in out


def test_int8_psum_error_bound():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.dist_search import psum_int8
mesh = jax.make_mesh((8,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
def f(xs):
    local = xs[0]
    exact = jax.lax.psum(local, "pod")
    approx = psum_int8(local, "pod")
    return exact[None], approx[None]
exact, approx = shard_map(f, mesh=mesh, in_specs=P("pod", None),
                          out_specs=P("pod", None))(x)
err = float(jnp.abs(exact - approx).max())
scale = float(jnp.abs(x).max()) / 127.0
assert err <= 8 * scale * 0.51 + 1e-6, (err, scale)  # n * scale/2 bound
print("OK", err)
""")
    assert "OK" in out


def test_int8_compressed_pod_reduction_converges():
    out = run_with_devices("""
import jax, numpy as np
from repro.core import env as env_lib, reinforce
from repro.distributed import dist_search
from repro.costmodel.layers import LayerSpec
wl = [LayerSpec.conv(32,16,28,28,3,3), LayerSpec.gemm(64,256,128)]
mesh = jax.make_mesh((2,2,2), ("pod","data","model"))
state, hist = dist_search.run_distributed_search(
    wl, env_lib.EnvConfig(platform="iot"), mesh,
    reinforce.ReinforceConfig(epochs=80, lr=3e-3),
    dist_search.DistConfig(episodes_per_device=2, compress_pod_axis=True))
assert np.isfinite(float(state.best_value))
print("OK")
""")
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """pjit train step on a (2,2) mesh == unsharded result."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np, dataclasses, functools
from repro import configs
from repro.models import lm
from repro.training import optim
from repro.distributed import sharding
cfg = dataclasses.replace(configs.get_smoke("qwen1p5_0p5b"),
                          param_dtype="float32", compute_dtype="float32")
opt = optim.Adam(lr=1e-3)
params = lm.init_params(jax.random.PRNGKey(0), cfg)
ost = opt.init(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}
step = functools.partial(lm.train_step, cfg=cfg, optimizer=opt)
p1, o1, l1 = jax.jit(step)(params, ost, batch)

mesh = jax.make_mesh((2, 2), ("data", "model"))
psh = sharding.tree_shardings(mesh, params)
params_s = jax.device_put(params, psh)
ost_s = jax.device_put(ost, sharding.tree_shardings(mesh, ost))
batch_s = {k: jax.device_put(v, sharding.batch_sharding(mesh, 4))
           for k, v in batch.items()}
pol = sharding.make_policy(mesh, batch=4, kind="train")
step_s = functools.partial(lm.train_step, cfg=cfg, optimizer=opt, pol=pol)
with mesh:
    p2, o2, l2 = jax.jit(step_s)(params_s, ost_s, batch_s)
assert abs(float(l1) - float(l2)) < 1e-4, (float(l1), float(l2))
# Adam update with lr=1e-3: reduction-order f32 noise in grads moves params
# by O(lr * eps_rel); 5e-4 = half an optimizer step of slack.
d = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.abs(a - np.asarray(b)).max()), p1, p2)))
assert d < 5e-4, d
print("OK", float(l1), d)
""")
    assert "OK" in out
