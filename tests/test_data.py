"""Data pipeline determinism + shapes (the restart/elasticity contract)."""
import numpy as np

from repro.training import data


def _cfg(**kw):
    base = dict(seq_len=32, global_batch=8, vocab_size=128)
    base.update(kw)
    return data.DataConfig(**base)


def test_synthetic_deterministic():
    ds1 = data.make_dataset(_cfg())
    ds2 = data.make_dataset(_cfg())
    for step in (0, 1, 17):
        a = ds1.batch(step)
        b = ds2.batch(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(ds1.batch(0)["tokens"],
                              ds1.batch(1)["tokens"])


def test_labels_are_shifted_tokens():
    ds = data.make_dataset(_cfg())
    b = ds.batch(0)
    assert b["tokens"].shape == (8, 32) and b["labels"].shape == (8, 32)
    # labels[t] continues tokens: label[:, :-1] == tokens[:, 1:]
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_sharded_batches_partition():
    ds = data.make_dataset(_cfg())
    full_shapes = [ds.batch(5, shard=i, n_shards=4)["tokens"].shape
                   for i in range(4)]
    assert all(s == (2, 32) for s in full_shapes)
    # different shards see different data at the same step
    a = ds.batch(5, shard=0, n_shards=4)["tokens"]
    b = ds.batch(5, shard=1, n_shards=4)["tokens"]
    assert not np.array_equal(a, b)


def test_synthetic_learnable_structure():
    """Bigram structure exists: next-token entropy < uniform."""
    ds = data.make_dataset(_cfg(seq_len=256, global_batch=16))
    b = ds.batch(0)
    toks, labs = b["tokens"].ravel(), b["labels"].ravel()
    # count how often the label is one of the 4 bigram successors
    hits = np.mean([l in ds._next[t] for t, l in zip(toks, labs)])
    assert hits > 0.5


def test_memmap_roundtrip(tmp_path):
    path = str(tmp_path / "tokens.bin")
    data.write_token_file(path, 10_000, 128, seed=1)
    ds = data.make_dataset(_cfg(source="memmap", path=path))
    a = ds.batch(3)
    b = ds.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].max() < 128
