"""Cost model (Env) behaviour: the landscape structure the paper relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade property tests to skips, not collection errors
    from hypothesis_stub import given, settings, st

from repro.costmodel import (CONV, DLA, EYE, GEMM, KT_LEVELS, PE_LEVELS, SHI,
                             evaluate, layers_to_array, model_cost, workloads)
from repro.costmodel.layers import LayerSpec, total_macs


def test_known_mac_counts():
    """MobileNet-V2 ~300M MACs, ResNet-50 ~3.8G (published numbers)."""
    assert abs(total_macs(workloads.mobilenet_v2()) / 300e6 - 1) < 0.15
    assert abs(total_macs(workloads.resnet50()) / 3.8e9 - 1) < 0.15


def test_workload_registry_covers_paper_and_archs():
    names = workloads.workload_names()
    for n in ("mobilenet_v2", "resnet50", "mnasnet", "gnmt", "transformer",
              "ncf", "qwen3_32b", "mamba2_130m", "zamba2_1p2b"):
        assert n in names
    wl = workloads.get_workload("qwen3_32b", tokens=256)
    assert len(wl) > 3 and total_macs(wl) > 0


def test_pe_overprovision_plateau():
    """Latency flattens once PEs exceed available parallelism (Fig. 5)."""
    small = LayerSpec.conv(16, 16, 14, 14, 3, 3).as_row()
    lats = [float(evaluate(small, p, 4.0, DLA).latency) for p in PE_LEVELS]
    assert lats[-1] == pytest.approx(lats[-2], rel=0.01)
    assert lats[0] > 10 * lats[-1]  # and parallelism does help before that


def test_buffer_overprovision_plateau():
    """Once kt >= K_out the latency is exactly flat (Fig. 5 plateau): a
    bigger L1 only costs area/power.  BELOW the plateau latency is genuinely
    non-monotone in the tile size -- the paper's own Fig. 5 shows this
    ("two separate purple regions", Layer-34) and the tile size IS the
    action, so quantization effects are faithful landscape structure."""
    K = 32
    l = LayerSpec.conv(K, 64, 28, 28, 3, 3).as_row()
    for df in (DLA, EYE, SHI):
        on_plateau = [float(evaluate(l, 16.0, float(k), df).latency)
                      for k in (K, K + 3, K + 40)]
        assert on_plateau[0] == on_plateau[1] == on_plateau[2]
        areas = [float(evaluate(l, 16.0, float(k), df).area)
                 for k in (K, K + 3, K + 40)]
        assert areas[0] < areas[1] < areas[2]
        # Below the plateau the landscape is rich: multiple distinct values.
        lats = [float(evaluate(l, 16.0, float(k), df).latency)
                for k in KT_LEVELS]
        assert len(set(lats)) > 1


def test_dwconv_kt_indifference_dla():
    """Paper Layer-23: DWCONV gains nothing from bigger tiles under dla."""
    dw = LayerSpec.dwconv(192, 28, 28, 3, 3).as_row()
    lats = [float(evaluate(dw, 32.0, k, DLA).latency) for k in KT_LEVELS[:6]]
    assert max(lats) / min(lats) < 1.05


def test_latency_not_monotone_in_pe():
    """More PEs can hurt (refetch/bandwidth terms) -- Fig. 4 discussion."""
    arr = layers_to_array(workloads.mobilenet_v2())
    found = False
    for i in range(0, arr.shape[0], 5):
        lat = np.array([[float(evaluate(arr[i], p, k, DLA).latency)
                         for k in KT_LEVELS] for p in PE_LEVELS])
        if (np.diff(lat, axis=0) > 1e-3).any():
            found = True
            break
    assert found


def test_energy_latency_distinct_optima():
    arr = layers_to_array(workloads.mobilenet_v2())
    l = arr[12]
    en = np.array([[float(evaluate(l, p, k, DLA).energy)
                    for k in KT_LEVELS] for p in PE_LEVELS])
    lat = np.array([[float(evaluate(l, p, k, DLA).latency)
                     for k in KT_LEVELS] for p in PE_LEVELS])
    assert en.max() / en.min() > 3      # rich landscape (Fig. 4)
    assert lat.max() / lat.min() > 10


@settings(max_examples=30, deadline=None)
@given(K=st.integers(1, 512), C=st.integers(1, 512),
       Y=st.integers(3, 64), R=st.sampled_from([1, 3, 5, 7]),
       pe=st.integers(1, 160), kt=st.integers(1, 16),
       df=st.sampled_from([DLA, EYE, SHI]))
def test_cost_invariants(K, C, Y, R, pe, kt, df):
    """Positive finite costs; area/power monotone in pe and kt."""
    l = LayerSpec.conv(K, C, max(Y, R), max(Y, R), R, R).as_row()
    out = evaluate(l, float(pe), float(kt), df)
    for v in (out.latency, out.energy, out.area, out.power):
        assert np.isfinite(float(v)) and float(v) > 0
    out2 = evaluate(l, float(pe + 8), float(kt), df)
    assert float(out2.area) > float(out.area)
    assert float(out2.power) > float(out.power)
    out3 = evaluate(l, float(pe), float(kt + 2), df)
    assert float(out3.area) > float(out.area)
    # once the tile covers every output channel, latency plateaus exactly
    p1 = evaluate(l, float(pe), float(K), df)
    p2 = evaluate(l, float(pe), float(K + 5), df)
    assert float(p1.latency) == float(p2.latency)


@settings(max_examples=20, deadline=None)
@given(M=st.integers(1, 2048), N=st.integers(1, 2048), Kg=st.integers(1, 2048))
def test_gemm_macs(M, N, Kg):
    l = LayerSpec.gemm(M, N, Kg)
    assert l.macs() == M * N * Kg


def test_lp_vs_ls_aggregation():
    arr = layers_to_array(workloads.ncf())
    N = arr.shape[0]
    pe = jnp.full((N,), 16.0)
    kt = jnp.full((N,), 4.0)
    lp = model_cost(arr, pe, kt, DLA, "LP")
    ls = model_cost(arr, pe, kt, DLA, "LS")
    assert float(lp.latency) == pytest.approx(float(ls.latency), rel=1e-6)
    assert float(lp.area) > float(ls.area)  # LP sums partitions; LS shares


def test_batched_broadcasting():
    arr = layers_to_array(workloads.ncf())
    B, N = 4, arr.shape[0]
    pe = jnp.ones((B, N)) * 8
    out = evaluate(arr[None], pe, 4.0, DLA)
    assert out.latency.shape == (B, N)
    # row 0 equals unbatched
    single = evaluate(arr, pe[0], 4.0, DLA)
    np.testing.assert_allclose(out.latency[0], single.latency, rtol=1e-6)


def test_repeat_scales_all_costs():
    a = LayerSpec.gemm(64, 64, 64, repeat=1).as_row()
    b = LayerSpec.gemm(64, 64, 64, repeat=3).as_row()
    oa = evaluate(a, 8.0, 4.0, DLA)
    ob = evaluate(b, 8.0, 4.0, DLA)
    for fa, fb in [(oa.latency, ob.latency), (oa.energy, ob.energy),
                   (oa.area, ob.area), (oa.power, ob.power)]:
        assert float(fb) == pytest.approx(3 * float(fa), rel=1e-5)
