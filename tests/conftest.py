"""Shared fixtures.  NOTE: no XLA_FLAGS here -- tests see the real single
CPU device; multi-device behaviour is tested via subprocesses
(tests/test_distributed.py) and the dry-run launcher owns its own flags."""
import dataclasses

import pytest


@pytest.fixture
def f32(request):
    return None


def f32_cfg(cfg):
    """Run smoke configs in f32 on CPU (bf16 matmuls are slow + noisy)."""
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")
