"""Differentiable cost-model core + relaxed engine tests.

Four layers of guarantees:

  * The *hard* path is bit-identical to the pre-refactor model: golden
    scalar values recorded before the primitives split, plus exact equality
    between the kernel oracle and the model core (they share the hard
    primitives, so this is structural -- the test guards the structure).
  * The *soft* path is a faithful relaxation: ``jax.grad`` is finite and
    non-zero everywhere (including on hard plateaus), agrees with finite
    differences, and converges to the hard values as ``tau -> 0``.
  * The relaxed engine honors the shared chunked/resumable/injectable
    contract (the cross-method schema checks live in
    ``test_optimizer_conformance.py``; here: chunk invariance, eval_fn
    byte-identity, resume accounting).
  * The cost cache is versioned on the model content hash: entries written
    under one model version can never be served under another.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import env as env_lib
from repro.core import relaxed
from repro.costmodel import dataflows as dfl
from repro.costmodel import maestro, workloads, layers_to_array
from repro.costmodel.layers import LayerSpec
from repro.kernels import ref

ECFG = env_lib.EnvConfig(platform="cloud")
CONV = LayerSpec.conv(32, 64, 28, 28, 3, 3).as_row()
DW = LayerSpec.dwconv(192, 28, 28, 3, 3).as_row()
GEMM = LayerSpec.gemm(128, 256, 512).as_row()


# ---------------------------------------------------------------------------
# Hard path: bit-identity with the pre-refactor model.
# ---------------------------------------------------------------------------
# (pe, kt, df) -> (latency, energy, area, power), recorded from the model
# *before* the primitives refactor.  Exact f32 equality, not allclose: the
# hard path must stay byte-for-byte the oracle it always was.
GOLDEN_CONV = {
    (16.0, 4.0, 0): (778776.0, 69904.8203125, 115200.0, 24.6560001373291),
    (37.0, 7.0, 1): (524186.09375, 109936.4140625, 199800.0,
                     51.02300262451172),
    (128.0, 16.0, 2): (129055.3125, 80536.5390625, 819200.0,
                       188.03201293945312),
    (1.0, 1.0, 0): (12460053.0, 391123.375, 4200.0, 1.2710000276565552),
    (160.0, 12.0, 1): (179744.65625, 86053.25, 1184000.0,
                       249.44000244140625),
}
GOLDEN_DW = {
    (32.0, 6.0, 0): (36529.65625, 63195.5546875, 294400.0,
                     55.07200241088867),
}


@pytest.mark.parametrize("point,want", sorted(GOLDEN_CONV.items()))
def test_hard_path_golden_values_conv(point, want):
    pe, kt, df = point
    out = maestro.evaluate(CONV, pe, kt, df)
    got = (np.float32(out.latency), np.float32(out.energy),
           np.float32(out.area), np.float32(out.power))
    assert got == tuple(np.float32(w) for w in want)


def test_hard_path_golden_values_dwconv():
    (pe, kt, df), want = next(iter(GOLDEN_DW.items()))
    out = maestro.evaluate(DW, pe, kt, df)
    got = (np.float32(out.latency), np.float32(out.energy),
           np.float32(out.area), np.float32(out.power))
    assert got == tuple(np.float32(w) for w in want)


def test_kernel_oracle_is_exactly_the_model_core():
    """ref.cost_eval_ref and maestro.evaluate share the hard primitives --
    the dedup satellite's guarantee is exact equality, not allclose."""
    rng = np.random.default_rng(0)
    arr = layers_to_array(workloads.get_workload("ncf"))
    N = arr.shape[0]
    pe = rng.integers(1, 161, (16, N)).astype(np.float32)
    kt = rng.integers(1, 17, (16, N)).astype(np.float32)
    df = rng.integers(0, 3, (16, N)).astype(np.float32)
    lat, en, area, pw = ref.cost_eval_ref(arr.T, pe, kt, df)
    out = maestro.evaluate(arr[None], pe, kt, df)
    np.testing.assert_array_equal(np.asarray(lat), np.asarray(out.latency))
    np.testing.assert_array_equal(np.asarray(en), np.asarray(out.energy))
    np.testing.assert_array_equal(np.asarray(area), np.asarray(out.area))
    np.testing.assert_array_equal(np.asarray(pw), np.asarray(out.power))


# ---------------------------------------------------------------------------
# Soft path: finite, non-zero, FD-consistent gradients.
# ---------------------------------------------------------------------------
def _onehot(d):
    return jnp.eye(dfl.NUM_DATAFLOWS, dtype=jnp.float32)[d]


def _soft_obj(layer):
    def obj(pe, kt, w, tau):
        o = maestro.soft_evaluate(layer, pe, kt, w, tau)
        return o.latency + o.energy + o.area + o.power
    return obj


@pytest.mark.parametrize("layer", [CONV, DW, GEMM],
                         ids=["conv", "dwconv", "gemm"])
@pytest.mark.parametrize("df", [0, 1, 2], ids=dfl.DATAFLOW_NAMES)
def test_soft_grads_finite_and_nonzero(layer, df):
    obj = _soft_obj(layer)
    g = jax.jit(jax.vmap(jax.grad(obj, argnums=(0, 1, 2)),
                         in_axes=(0, 0, None, None)))
    pe = jnp.array([1.0, 7.3, 16.0, 80.0, 137.2, 160.0])
    kt = jnp.array([1.0, 3.5, 8.0, 12.0, 15.5, 16.0])
    gpe, gkt, gw = g(pe, kt, _onehot(df), 1.0)
    for arr in (gpe, gkt, gw):
        assert bool(jnp.all(jnp.isfinite(arr)))
    # Non-zero everywhere: the whole point of the relaxation.
    assert bool(jnp.all(jnp.abs(gpe) > 0))
    assert bool(jnp.all(jnp.abs(gkt) > 0))
    # The dataflow simplex gets gradient signal too.
    assert bool(jnp.all(jnp.abs(gw).max(-1) > 0))


@pytest.mark.parametrize("layer", [CONV, DW, GEMM],
                         ids=["conv", "dwconv", "gemm"])
@pytest.mark.parametrize("df", [0, 1, 2], ids=dfl.DATAFLOW_NAMES)
def test_soft_grad_matches_finite_differences(layer, df):
    """Central differences agree with jax.grad on the soft model.

    The soft staircase has regions of high curvature (near cell edges at
    small kt) where the *FD estimate itself* does not converge in f32 --
    there the truncation error swamps the comparison, so a point only
    counts when two step sizes agree with each other (FD has converged);
    converged points must then match the analytic gradient.  Wrong or
    zero gradients still fail: most probe points converge.
    """
    obj = _soft_obj(layer)
    w, tau = _onehot(df), 1.0
    f = jax.jit(lambda pe, kt: obj(pe, kt, w, tau))
    g = jax.jit(jax.grad(lambda pe, kt: obj(pe, kt, w, tau),
                         argnums=(0, 1)))

    def fd(fun, x0, h):
        return float((fun(x0 + h) - fun(x0 - h)) / (2 * h))

    checked = 0
    for pe, kt in [(9.7, 3.3), (33.4, 8.6), (121.1, 13.9), (64.5, 11.2),
                   (100.3, 9.6)]:
        gpe, gkt = g(pe, kt)
        probes = ((float(gpe), (lambda x: f(x, kt)), pe),
                  (float(gkt), (lambda x: f(pe, x)), kt))
        for an, fun, x0 in probes:
            # h well under the soft staircase's shortest cell (~kt^2/K) so
            # truncation can actually vanish.
            h2 = 0.005
            fd1 = fd(fun, x0, 0.02)
            fd2 = fd(fun, x0, h2)
            # f32 FD cannot resolve gradients below the cancellation noise
            # floor ~ eps*|f|/(2h); fold it into the comparison scale.
            noise = 64 * np.finfo(np.float32).eps * \
                max(abs(float(fun(x0))), 1.0) / (2 * h2)
            scale = max(abs(an), abs(fd2), noise)
            if abs(fd1 - fd2) / scale > 0.05:
                continue                   # FD itself not converged here
            checked += 1
            assert abs(an - fd2) / scale < 0.15, (pe, kt, an, fd1, fd2)
    assert checked >= 4                    # most probe points do converge


@pytest.mark.parametrize("scenario", ["LP", "LS"])
def test_soft_model_cost_grads_both_scenarios(scenario):
    """Whole-model aggregation stays differentiable in both deployment
    scenarios; under LS the smooth max routes constraint gradient to every
    layer, not just the argmax layer."""
    arr = layers_to_array(workloads.get_workload("ncf"))
    N = arr.shape[0]
    w = jnp.tile(_onehot(0), (N, 1))

    def agg(pe, kt):
        mc = maestro.soft_model_cost(arr, pe, kt, w, 0.5, scenario)
        return mc.latency + mc.area
    g = jax.jit(jax.grad(agg, argnums=(0, 1)))
    gpe, gkt = g(jnp.full((N,), 16.0), jnp.full((N,), 4.0))
    assert bool(jnp.all(jnp.isfinite(gpe)) and jnp.all(jnp.isfinite(gkt)))
    assert bool(jnp.all(jnp.abs(gpe) > 0) and jnp.all(jnp.abs(gkt) > 0))


def test_soft_grad_nonzero_on_hard_plateau():
    """kt > K_out over-provisions the buffer without changing the hard
    latency (min(kt, K_out) plateau): hard grad is exactly 0, soft isn't."""
    layer = LayerSpec.conv(8, 64, 28, 28, 3, 3).as_row()   # K_out = 8

    def lat(model, kt, tau=None):
        if model == "hard":
            return maestro.evaluate(layer, 16.0, kt, 0).latency
        return maestro.soft_evaluate(layer, 16.0, kt, _onehot(0), tau).latency

    hard_g = jax.grad(lambda kt: lat("hard", kt))(9.0)
    assert float(hard_g) == 0.0
    for kt in (9.0, 12.0):
        soft_g = jax.grad(lambda kt: lat("soft", kt, 1.0))(kt)
        assert bool(jnp.isfinite(soft_g)) and float(soft_g) != 0.0


def test_soft_converges_to_hard_as_tau_shrinks():
    """At the integer points the engines actually round to, the soft model's
    values approach the hard model's as tau anneals toward 0."""
    rng = np.random.default_rng(3)
    arr = layers_to_array(workloads.get_workload("ncf"))
    N = arr.shape[0]
    pe = rng.integers(1, 161, (8, N)).astype(np.float32)
    kt = rng.integers(1, 17, (8, N)).astype(np.float32)
    df = rng.integers(0, 3, (8, N))
    w = jnp.eye(dfl.NUM_DATAFLOWS, dtype=jnp.float32)[df]
    hard = maestro.evaluate(arr[None], pe, kt, df.astype(np.float32))
    errs = []
    for tau in (1.0, 0.3, 0.05):
        soft = maestro.soft_evaluate(arr[None], jnp.asarray(pe),
                                     jnp.asarray(kt), w, tau)
        rel = np.abs(np.asarray(soft.latency) - np.asarray(hard.latency)) \
            / np.maximum(np.asarray(hard.latency), 1.0)
        errs.append(float(np.median(rel)))
    assert errs[-1] < errs[0]
    assert errs[-1] < 0.05


# ---------------------------------------------------------------------------
# Relaxed engine: chunked/resumable/injectable contract.
# ---------------------------------------------------------------------------
CFG = relaxed.RelaxedConfig(steps_per_eval=5, restarts=2, seed=7)


@pytest.fixture(scope="module")
def ncf_env():
    wl = workloads.get_workload("ncf")
    return wl, env_lib.make_env(wl, ECFG)


def test_relaxed_chunk_boundaries_never_change_bytes(ncf_env):
    wl, env = ncf_env
    _, h1 = relaxed.run_relaxed_search(wl, ECFG, 30, CFG, env=env)
    s3, h3 = relaxed.run_relaxed_search(wl, ECFG, 30, CFG, chunk=7, env=env)
    assert h1.tobytes() == h3.tobytes()
    assert h1.shape == (30,)
    assert int(s3.evals) == 30


def test_relaxed_eval_fn_injection_is_byte_identical(ncf_env):
    wl, env = ncf_env
    calls = []

    @jax.jit
    def _fit(pe, kt, df):
        perf, cons, feas = env_lib.genome_cost(env, ECFG, pe, kt, df)
        return jnp.where(feas, perf, jnp.inf)

    def eval_fn(pe, kt, df):
        calls.append(pe.shape)
        return np.asarray(_fit(jnp.asarray(pe[0]), jnp.asarray(kt[0]),
                               df))[None]

    _, h1 = relaxed.run_relaxed_search(wl, ECFG, 25, CFG, env=env)
    _, h2 = relaxed.run_relaxed_search(wl, ECFG, 25, CFG, eval_fn=eval_fn,
                                       env=env)
    assert h1.tobytes() == h2.tobytes()
    assert len(calls) == 25            # eps counts hard evals, exactly


def test_relaxed_resume_continues_the_trajectory(ncf_env):
    wl, env = ncf_env
    sa, ha = relaxed.run_relaxed_search(wl, ECFG, 15, CFG, env=env)
    sb, hb = relaxed.run_relaxed_search(wl, ECFG, 15, CFG, state=sa, env=env)
    assert int(sb.evals) == 30
    assert int(sb.gstep) > int(sa.gstep)
    assert float(sb.best_fit) <= float(sa.best_fit)
    assert ha.shape == hb.shape == (15,)


def test_relaxed_finds_feasible_point_and_respects_budget(ncf_env):
    wl, env = ncf_env
    state, hist = relaxed.run_relaxed_search(wl, ECFG, 40, CFG, env=env)
    assert np.isfinite(float(state.best_fit))
    pe, kt, df = relaxed.relaxed_solution(state)
    perf, cons, feas = env_lib.genome_cost(
        env, ECFG, jnp.asarray(pe), jnp.asarray(kt), jnp.asarray(df))
    assert bool(feas)
    assert float(perf) == pytest.approx(float(state.best_fit))
    # Rounded assignments live inside the fine search bounds.
    assert np.all((pe >= dfl.PE_MIN) & (pe <= dfl.PE_MAX))
    assert np.all((kt >= dfl.KT_MIN) & (kt <= dfl.KT_MAX))
    assert np.all(pe == np.round(pe)) and np.all(kt == np.round(kt))


# ---------------------------------------------------------------------------
# Cache versioning on the model content hash.
# ---------------------------------------------------------------------------
def test_cost_cache_is_versioned_on_model_hash():
    from repro.serving.cost_cache import CostMemoCache

    key = np.arange(11, dtype=np.float32).tobytes()
    val = np.ones(4, np.float32)
    c_default = CostMemoCache()
    assert c_default.version == maestro.content_hash()

    old = CostMemoCache(version="old-model")
    old.put_many([key], [val])
    hit, miss = old.get_many([key])
    assert miss == [] and hit[0] is val

    # Same raw key under a different model version: a clean miss, never a
    # stale tuple from the old semantics.
    new = CostMemoCache(version="new-model")
    new._data = old._data          # simulate a shared/persistent store
    vals, miss = new.get_many([key])
    assert miss == [0] and vals[0] is None


def test_content_hash_is_stable_and_source_sensitive():
    h1 = maestro.content_hash()
    assert h1 == maestro.content_hash()
    assert len(h1) == 16
    assert all(c in "0123456789abcdef" for c in h1)
