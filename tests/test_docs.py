"""Docs stay truthful: intra-repo links resolve, api.md examples run.

Thin wrappers around tools/check_docs.py (the same tool CI's ``docs`` job
runs) so the tier-1 suite catches documentation drift locally too.
"""
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_docs  # noqa: E402


def test_docs_tree_exists():
    for f in ("architecture.md", "search_service.md", "paper_map.md",
              "api.md"):
        assert os.path.isfile(os.path.join(REPO, "docs", f)), f


def test_no_broken_intra_repo_links():
    assert check_docs.check_links() == []


def test_paper_map_covers_every_benchmark():
    """Every benchmarks/bench_*.py module must appear in docs/paper_map.md."""
    with open(os.path.join(REPO, "docs", "paper_map.md")) as f:
        text = f.read()
    benches = sorted(f for f in os.listdir(os.path.join(REPO, "benchmarks"))
                     if f.startswith("bench_") and f.endswith(".py"))
    missing = [b for b in benches if b not in text]
    assert not missing, f"paper_map.md misses benchmarks: {missing}"


def test_api_md_python_blocks_execute():
    """The fenced examples in docs/api.md are the API's executable spec."""
    errors = check_docs.run_doctests()
    assert errors == [], errors


def test_api_md_documents_every_registered_method():
    from repro import api

    with open(os.path.join(REPO, "docs", "api.md")) as f:
        text = f.read()
    missing = [n for n in api.list_optimizers() if f"`{n}`" not in text]
    assert not missing, f"api.md misses methods: {missing}"


@pytest.mark.parametrize("doc", ["architecture.md", "search_service.md"])
def test_named_modules_exist(doc):
    """Back-tick'd repro module paths mentioned in the docs must import."""
    import importlib
    import re

    with open(os.path.join(REPO, "docs", doc)) as f:
        text = f.read()
    for mod in set(re.findall(r"`(repro(?:\.\w+)+)`", text)):
        importlib.import_module(mod.rsplit(".", 1)[0]
                                if mod.count(".") > 1 else mod)
