"""Sharding-mode correctness: tp / tp_serve / fsdp / dp must all produce
the same numbers, and their parameter placements must match their
contracts (SPerf hillclimb machinery)."""
import os
import subprocess
import sys

import numpy as np

from jax.sharding import PartitionSpec as P

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_param_spec_modes():
    import jax
    from repro.distributed import sharding
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # tp: rules fire (divisibility-guarded; 1-sized axes always divide).
    spec = sharding.param_spec(mesh, "blocks/mlp/w_gate", (64, 256), "tp")
    assert spec == P("data", "model")
    # tp_serve: the data/FSDP dim is dropped, model TP kept.
    spec = sharding.param_spec(mesh, "blocks/mlp/w_gate", (64, 256),
                               "tp_serve")
    assert spec == P(None, "model")
    # dp: everything replicated.
    assert sharding.param_spec(mesh, "blocks/mlp/w_gate", (64, 256),
                               "dp") == P()
    # fsdp: largest divisible dim over all axes.
    spec = sharding.param_spec(mesh, "blocks/mlp/w_gate", (64, 256), "fsdp")
    assert spec == P(None, ("data", "model"))


def _run(code: str, n: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_all_modes_agree_numerically():
    """One train step under tp / fsdp / dp == the unsharded result."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np, dataclasses, functools
from repro import configs
from repro.models import lm
from repro.training import optim
from repro.distributed import sharding
cfg = dataclasses.replace(configs.get_smoke("qwen2p5_3b"),
                          param_dtype="float32", compute_dtype="float32")
opt = optim.Adam(lr=1e-3)
params = lm.init_params(jax.random.PRNGKey(0), cfg)
ost = opt.init(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                            cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}
ref_step = functools.partial(lm.train_step, cfg=cfg, optimizer=opt)
p_ref, _, l_ref = jax.jit(ref_step)(params, ost, batch)

mesh = jax.make_mesh((4, 2), ("data", "model"))
for mode in ("tp", "fsdp", "dp"):
    psh = sharding.tree_shardings(mesh, params, mode)
    params_s = jax.device_put(params, psh)
    ost_s = jax.device_put(ost, sharding.tree_shardings(mesh, ost, mode))
    bsh = sharding.batch_sharding(mesh, 8, mode=mode)
    batch_s = {k: jax.device_put(v, bsh) for k, v in batch.items()}
    pol = sharding.make_policy(mesh, batch=8, kind="train", mode=mode)
    step = functools.partial(lm.train_step, cfg=cfg, optimizer=opt, pol=pol)
    with mesh:
        p2, _, l2 = jax.jit(step)(params_s, ost_s, batch_s)
    assert abs(float(l_ref) - float(l2)) < 1e-4, (mode, float(l_ref),
                                                  float(l2))
    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - np.asarray(b)).max()), p_ref, p2)))
    assert d < 5e-4, (mode, d)
    print("OK", mode, float(l2), d)
""")
    assert out.count("OK") == 3


def test_remat_policies_agree():
    """full / dots / none remat produce identical losses and gradients."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np, dataclasses, functools
from repro import configs
from repro.models import lm
cfg = dataclasses.replace(configs.get_smoke("qwen1p5_0p5b"),
                          param_dtype="float32", compute_dtype="float32")
params = lm.init_params(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                            cfg.vocab_size)
vals = {}
for remat in ("full", "dots", "none"):
    f = functools.partial(lm.lm_loss, remat=remat)
    l, g = jax.jit(jax.value_and_grad(f), static_argnums=(1,))(
        params, cfg, tokens, tokens)
    vals[remat] = (float(l), g)
for remat in ("dots", "none"):
    assert abs(vals["full"][0] - vals[remat][0]) < 1e-5
    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()),
        vals["full"][1], vals[remat][1])))
    assert d < 1e-4, (remat, d)
print("OK")
""", n=1)
    assert "OK" in out


def test_wire_accounting_reduce_scatter_and_dtype():
    from repro.distributed import hlo_analysis
    hlo = """
HloModule m
ENTRY %main (p: f32[256,128]) -> f32[32,128] {
  %p = f32[256,128]{1,0} parameter(0)
  %rs = f32[32,128]{1,0} reduce-scatter(%p), channel_id=1, replica_groups=[2,8]<=[16], dimensions={0}, to_apply=%add
  ROOT %out = f32[32,128]{1,0} copy(%rs)
}
"""
    stats = hlo_analysis.collective_stats(hlo)
    # result 32*128*4 = 16384 B; group size 8 -> operand-equivalent 131072.
    assert stats["reduce-scatter"] == 32 * 128 * 4 * 8
    stats2 = hlo_analysis.collective_stats(hlo, f32_elem_bytes=2)
    assert stats2["reduce-scatter"] == 32 * 128 * 2 * 8
