"""Checkpointing: atomicity, round-trip, deterministic resume, elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as env_lib, reinforce
from repro.costmodel.layers import LayerSpec
from repro.training import checkpoint, optim


def _wl():
    return [LayerSpec.conv(32, 16, 28, 28, 3, 3),
            LayerSpec.gemm(64, 256, 128)]


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.asarray(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path), 7, t, meta={"note": "x"})
    like = jax.tree.map(jnp.zeros_like, t)
    got, step, meta = checkpoint.restore(str(tmp_path), like)
    assert step == 7 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_partial_ignored(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path), 1, t)
    # simulate a crashed save: tmp dir with garbage
    os.makedirs(tmp_path / "tmp.2.999", exist_ok=True)
    (tmp_path / "tmp.2.999" / "leaf_00000.npy").write_bytes(b"junk")
    assert checkpoint.latest_step(str(tmp_path)) == 1


def test_keep_last_k(tmp_path):
    t = _tree()
    for s in range(6):
        checkpoint.save(str(tmp_path), s, t, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and checkpoint.latest_step(str(tmp_path)) == 5


def test_async_save(tmp_path):
    t = _tree()
    th = checkpoint.save(str(tmp_path), 3, t, blocking=False)
    th.join()
    assert checkpoint.latest_step(str(tmp_path)) == 3


def test_search_resume_bit_deterministic(tmp_path):
    """10 epochs + checkpoint + 10 epochs == 20 epochs straight."""
    ecfg = env_lib.EnvConfig(platform="cloud")
    rcfg10 = reinforce.ReinforceConfig(epochs=10, episodes_per_epoch=2,
                                       seed=3)
    rcfg20 = reinforce.ReinforceConfig(epochs=20, episodes_per_epoch=2,
                                       seed=3)
    sA, _ = reinforce.run_search(_wl(), ecfg, rcfg20)

    s1, _ = reinforce.run_search(_wl(), ecfg, rcfg10)
    checkpoint.save(str(tmp_path), int(s1.epoch), s1._asdict())
    like = jax.tree.map(jnp.zeros_like, s1._asdict())
    got, _, _ = checkpoint.restore(str(tmp_path), like)
    s1r = reinforce.SearchState(**got)
    sB, _ = reinforce.run_search(_wl(), ecfg, rcfg10, state=s1r)

    np.testing.assert_allclose(float(sA.best_value), float(sB.best_value),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(sA.params), jax.tree.leaves(sB.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_elastic_restore_resharding(tmp_path):
    """Restore places leaves with the target tree's shardings (1-dev CPU)."""
    t = _tree()
    checkpoint.save(str(tmp_path), 1, t)
    like = jax.tree.map(
        lambda x: jax.device_put(jnp.zeros_like(x), jax.devices()[0]), t)
    got, _, _ = checkpoint.restore(str(tmp_path), like)
    for leaf in jax.tree.leaves(got):
        assert leaf.devices() == {jax.devices()[0]}
