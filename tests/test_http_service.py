"""HTTP front door: wire parity, admission control, fairness, streaming.

The load-bearing guarantee carries over from the in-process service:
a search submitted over HTTP returns bit-identical history/assignment to
the same ``api.run_search`` call (JSON float round-tripping is exact).
The rest is what makes the front door operable -- bounded admission
(429 + Retry-After), per-tenant weighted round-robin so a backlog can't
starve an interactive probe, cancel over the wire for queued AND running
jobs, chunked JSONL progress, and per-tenant accounting in /v1/stats.
"""
import time

import numpy as np
import pytest

from repro import api
from repro.core import env as env_lib
from repro.serving import (HttpConfig, QueueFull, SearchClient,
                           SearchHTTPService, ServiceConfig)

ECFG = env_lib.EnvConfig(platform="cloud")


def _hub(max_workers=2, max_queue=8, max_running=None, weights=(),
         progress_every=200):
    return SearchHTTPService(
        service_cfg=ServiceConfig(max_workers=max_workers,
                                  default_progress_every=progress_every),
        http_cfg=HttpConfig(port=0, max_queue=max_queue,
                            max_running=max_running,
                            tenant_weights=weights,
                            progress_poll_s=0.01)).start()


def _wait(pred, timeout=120, step=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return True
        time.sleep(step)
    return False


# ---------------------------------------------------------------------------
# Wire parity.
# ---------------------------------------------------------------------------
def test_http_end_to_end_bit_identical_to_in_process():
    """Same fixed-seed search over the wire == api.run_search, bit for bit
    (history bytes, pe/kt assignment, best value)."""
    want = api.run_search(api.SearchRequest(
        workload="ncf", env=ECFG, eps=200, seed=3, method="random"))
    hub = _hub()
    try:
        client = SearchClient(port=hub.port)
        uid = client.submit({"workload": "ncf", "method": "random",
                             "eps": 200, "seed": 3})["uid"]
        out = client.result(uid, timeout=300)
        assert out["best_value"] == want.best_value
        got_hist = np.asarray(out["history"], want.history.dtype)
        assert got_hist.tobytes() == want.history.tobytes()
        np.testing.assert_array_equal(
            np.asarray(out["pe"], np.asarray(want.pe).dtype), want.pe)
        np.testing.assert_array_equal(
            np.asarray(out["kt"], np.asarray(want.kt).dtype), want.kt)
        assert out["method"] == "random" and out["seed"] == 3
    finally:
        hub.close()


def test_http_full_env_spec_and_options_pass_through():
    """objective/constraint/dataflow and leftover option keys survive the
    spec -> SearchRequest translation (same convention as serve_search)."""
    env2 = env_lib.EnvConfig(platform="cloud", objective="energy",
                             constraint="power")
    want = api.run_search(api.SearchRequest(
        workload="ncf", env=env2, eps=150, seed=2, method="ga",
        options={"population": 30}))
    hub = _hub()
    try:
        client = SearchClient(port=hub.port)
        uid = client.submit({"workload": "ncf", "method": "ga", "eps": 150,
                             "seed": 2, "objective": "energy",
                             "constraint": "power",
                             "population": 30})["uid"]
        out = client.result(uid, timeout=300)
        assert out["best_value"] == want.best_value
        got_hist = np.asarray(out["history"], want.history.dtype)
        assert got_hist.tobytes() == want.history.tobytes()
    finally:
        hub.close()


# ---------------------------------------------------------------------------
# Admission control.
# ---------------------------------------------------------------------------
def test_queue_full_returns_429_with_retry_after():
    hub = _hub(max_workers=1, max_queue=1, max_running=1)
    try:
        client = SearchClient(port=hub.port)
        running = client.submit({"workload": "ncf", "method": "reinforce",
                                 "eps": 10_000_000})
        # Wait until the scheduler moved it out of the admission queue.
        assert _wait(lambda: hub.front.stats()["running"] == 1
                     and hub.front.stats()["queued"] == 0)
        queued = client.submit({"workload": "ncf", "method": "random",
                                "eps": 100})
        assert hub.front.stats()["queued"] == 1      # queue now full
        status, headers, _ = client._request(
            "POST", "/v1/search",
            {"workload": "ncf", "method": "random", "eps": 100})
        assert status == 429
        assert float(headers["Retry-After"]) > 0
        with pytest.raises(QueueFull):               # client-side surface
            client.submit({"workload": "ncf", "method": "random",
                           "eps": 100})
        st = hub.front.stats()
        assert st["rejected"] == 2
        assert st["tenants"]["anon"]["rejected"] == 2
        client.cancel(queued["uid"])
        client.cancel(running["uid"])
    finally:
        hub.close()


def test_bad_request_body_is_400_not_500():
    hub = _hub()
    try:
        client = SearchClient(port=hub.port)
        status, _, data = client._request("POST", "/v1/search",
                                          {"method": "random"})  # no workload
        assert status == 400 and b"workload" in data
        status, _, _ = client._request("GET", "/v1/search/nope")
        assert status == 404
        status, _, _ = client._request("DELETE", "/v1/search/nope")
        assert status == 404
        status, _, _ = client._request("GET", "/no/such/route")
        assert status == 404
    finally:
        hub.close()


# ---------------------------------------------------------------------------
# Cancellation over the wire.
# ---------------------------------------------------------------------------
def test_cancel_over_wire_running_and_queued():
    hub = _hub(max_workers=1, max_queue=8, max_running=1,
               progress_every=50)
    try:
        client = SearchClient(port=hub.port)
        running = client.submit({"workload": "ncf", "method": "reinforce",
                                 "eps": 10_000_000})["uid"]
        assert _wait(lambda: client.status(running)["status"] == "running")
        queued = client.submit({"workload": "ncf", "method": "random",
                                "eps": 100})["uid"]
        # Queued cancel resolves while the worker is still busy.
        client.cancel(queued)
        assert _wait(lambda: client.status(queued)["status"] == "cancelled",
                     timeout=5)
        assert client.status(running)["status"] == "running"
        client.cancel(running)
        assert _wait(lambda: client.status(running)["status"] == "cancelled")
        with pytest.raises(RuntimeError, match="cancelled"):
            client.result(queued, timeout=5)
        st = client.stats()["front_door"]["tenants"]["anon"]
        assert st["cancelled"] == 2 and st["completed"] == 0
    finally:
        hub.close()


# ---------------------------------------------------------------------------
# Progress streaming.
# ---------------------------------------------------------------------------
def test_progress_stream_is_incremental_jsonl():
    hub = _hub(max_workers=1, progress_every=25)
    try:
        client = SearchClient(port=hub.port)
        uid = client.submit({"workload": "ncf", "method": "reinforce",
                             "eps": 100})["uid"]
        recs = list(client.progress(uid))
        assert recs[-1]["done"] is True
        assert recs[-1]["status"] == "done"
        trials = recs[:-1]
        assert len(trials) >= 3                      # 25-step cadence
        steps = [r["step"] for r in trials]
        assert steps == sorted(steps) and steps[-1] == 100
        assert all(np.isfinite(r["best_value"]) or r["best_value"] == float(
            "inf") for r in trials)
    finally:
        hub.close()


# ---------------------------------------------------------------------------
# Tenant fairness + accounting.
# ---------------------------------------------------------------------------
def test_wrr_interactive_tenant_not_starved_by_backlog():
    """One running slot, tenant A floods 4 jobs, tenant B submits 1: WRR
    must schedule B's single job ahead of A's backlog tail."""
    hub = _hub(max_workers=1, max_queue=16, max_running=1)
    try:
        client = SearchClient(port=hub.port)
        a = [client.submit({"workload": "ncf", "method": "random",
                            "eps": 600, "seed": s, "tenant": "batch"})["uid"]
             for s in range(4)]
        b = client.submit({"workload": "ncf", "method": "random",
                           "eps": 300, "seed": 9,
                           "tenant": "interactive"})["uid"]
        for uid in a + [b]:
            client.result(uid, timeout=300)
        jobs = {uid: hub.front.get(uid) for uid in a + [b]}
        # B entered the rotation after at most one A job from the backlog:
        # it must have finished before A's last two.
        assert jobs[b].finished_at < jobs[a[2]].finished_at
        assert jobs[b].finished_at < jobs[a[3]].finished_at

        tenants = client.stats()["front_door"]["tenants"]
        assert tenants["batch"]["submitted"] == 4
        assert tenants["batch"]["completed"] == 4
        assert tenants["batch"]["eps_requested"] == 4 * 600
        assert tenants["batch"]["eps_finished"] == 4 * 600
        assert tenants["interactive"]["completed"] == 1
        assert tenants["interactive"]["eps_finished"] == 300
    finally:
        hub.close()


def test_stats_and_metrics_endpoints():
    hub = _hub()
    try:
        client = SearchClient(port=hub.port)
        uid = client.submit({"workload": "ncf", "method": "random",
                             "eps": 60, "tenant": "t0"})["uid"]
        client.result(uid, timeout=300)
        st = client.stats()
        assert st["service"]["completed"] == 1
        assert st["front_door"]["tenants"]["t0"]["completed"] == 1
        assert st["front_door"]["max_queue"] == 8
        text = client.metrics_text()
        # The registry's exposition is served whole -- the front-door
        # metrics are registered (samples only accrue while obs is on).
        assert "# TYPE repro_http_requests counter" in text
        assert "# TYPE repro_service_requests counter" in text
    finally:
        hub.close()


def test_http_metrics_accrue_when_telemetry_enabled():
    from repro import obs
    from repro.obs import instrument

    obs.enable()
    try:
        hub = _hub()
        try:
            client = SearchClient(port=hub.port)
            before = instrument.HTTP_REQUESTS.value(route="/v1/stats",
                                                    code="200")
            client.stats()
            client.stats()
            assert instrument.HTTP_REQUESTS.value(
                route="/v1/stats", code="200") == before + 2
            text = client.metrics_text()
            assert "repro_http_requests_total{" in text
            assert 'route="/v1/stats"' in text
        finally:
            hub.close()
    finally:
        obs.disable()
