"""Golden regression tests for core/env.py constraint semantics.

Pins exact float32 values (not allclose -- the aggregation layer must stay
byte-for-byte what it was when the multi-objective refactor landed) for one
conv / dwconv / gemm layer each:

  * per-layer (latency, energy, area, power) from the cost model;
  * LP aggregation = SUM over layers (one chip partition per layer);
  * LS aggregation = MAX over layers (one shared time-multiplexed design);
  * feasibility against the Table II cloud budgets (LS/power is the
    deliberately infeasible row);
  * the ``blend`` objective ``lat^w * en^(1-w)`` at w in {0, 1/2, 1}
    (w=0 == energy, w=1 == latency, exactly).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import env as env_lib
from repro.costmodel import maestro
from repro.costmodel.layers import LayerSpec

WL = [LayerSpec.conv(32, 64, 28, 28, 3, 3),
      LayerSpec.dwconv(192, 28, 28, 3, 3),
      LayerSpec.gemm(128, 256, 512)]
PE = jnp.asarray([16.0, 37.0, 128.0], jnp.float32)
KT = jnp.asarray([4.0, 7.0, 16.0], jnp.float32)
DF = 0  # DLA

# Per-layer (lat, en, area, pw) for (WL[i], PE[i], KT[i], DLA) -- exact f32.
GOLDEN_LAYERS = {
    "conv":   (778776.0, 69904.8203125, 115200.0, 24.6560001373291),
    "dwconv": (42614.08203125, 63259.84765625, 377400.0, 67.00699615478516),
    "gemm":   (131103.3125, 117588.171875, 716800.0, 178.8159942626953),
}

# (scenario, constraint) -> (budget, total_lat, total_en, total_area,
#                            total_pw, objective, constraint_value, feasible)
# LP totals are the SUMS of the per-layer rows above; LS area/power are the
# MAXES (the gemm row); objectives (summed) are identical across the four.
GOLDEN_AGG = {
    ("LP", "area"):  (2252800.0, 952493.375, 250752.84375, 1209400.0,
                      270.47900390625, 952493.375, 1209400.0, True),
    ("LP", "power"): (374.2080078125, 952493.375, 250752.84375, 1209400.0,
                      270.47900390625, 952493.375, 270.47900390625, True),
    ("LS", "area"):  (972800.0, 952493.375, 250752.84375, 716800.0,
                      178.8159942626953, 952493.375, 716800.0, True),
    ("LS", "power"): (144.70399475097656, 952493.375, 250752.84375,
                      716800.0, 178.8159942626953, 952493.375,
                      178.8159942626953, False),
}

# blend_weight -> exact f32 objective; w=0/1 must equal the energy/latency
# totals above bit-for-bit.
GOLDEN_BLEND = {0.0: 250752.84375, 0.5: 488713.03125, 1.0: 952493.375}


@pytest.mark.parametrize("kind", sorted(GOLDEN_LAYERS))
def test_per_layer_golden(kind):
    idx = {"conv": 0, "dwconv": 1, "gemm": 2}[kind]
    ecfg = env_lib.EnvConfig(platform="cloud")
    env = env_lib.make_env(WL, ecfg)
    out = maestro.evaluate(env.layers, PE, KT, DF)
    got = tuple(float(np.asarray(a, np.float32)[idx])
                for a in (out.latency, out.energy, out.area, out.power))
    assert got == tuple(float(np.float32(w)) for w in GOLDEN_LAYERS[kind])


@pytest.mark.parametrize("scen,cons", sorted(GOLDEN_AGG))
def test_aggregation_golden(scen, cons):
    budget, tl, te, ta, tp, obj, cval, feas = GOLDEN_AGG[(scen, cons)]
    ecfg = env_lib.EnvConfig(platform="cloud", scenario=scen,
                             constraint=cons)
    env = env_lib.make_env(WL, ecfg)
    assert float(np.float32(env.budget)) == float(np.float32(budget))
    g_tl, g_te, g_ta, g_tp, g_feas = env_lib.genome_costs_multi(
        env, ecfg, PE, KT, DF)
    got = tuple(float(np.asarray(v, np.float32))
                for v in (g_tl, g_te, g_ta, g_tp))
    assert got == tuple(float(np.float32(w)) for w in (tl, te, ta, tp))
    assert bool(g_feas) is feas
    g_obj, g_cval, g_feas2 = env_lib.genome_cost(env, ecfg, PE, KT, DF)
    assert float(np.asarray(g_obj, np.float32)) == float(np.float32(obj))
    assert float(np.asarray(g_cval, np.float32)) == float(np.float32(cval))
    assert bool(g_feas2) is feas
    # Scalar view == multi view on the shared fields, bit-for-bit.
    assert float(np.asarray(g_cval, np.float32)) == (
        got[2] if cons == "area" else got[3])
    assert bool(g_feas) is bool(g_feas2)
    # Feasibility mask agrees with the aggregate verdict.
    assert bool(env_lib.feasibility_mask(env, ecfg, PE, KT, DF)) is feas


def test_lp_is_sum_ls_is_max_of_golden_layers():
    """The aggregates above really are the sum/max of the per-layer rows."""
    rows = np.asarray([GOLDEN_LAYERS[k] for k in ("conv", "dwconv", "gemm")],
                      np.float32)
    lp = GOLDEN_AGG[("LP", "area")]
    ls = GOLDEN_AGG[("LS", "area")]
    assert float(rows[:, 2].sum()) == float(np.float32(lp[3]))   # area sum
    assert float(rows[:, 3].sum()) == float(np.float32(lp[4]))   # power sum
    assert float(rows[:, 2].max()) == float(np.float32(ls[3]))   # area max
    assert float(rows[:, 3].max()) == float(np.float32(ls[4]))   # power max


@pytest.mark.parametrize("w", sorted(GOLDEN_BLEND))
def test_blend_objective_golden(w):
    ecfg = env_lib.EnvConfig(platform="cloud", objective="blend",
                             blend_weight=w)
    env = env_lib.make_env(WL, ecfg)
    obj, _, _ = env_lib.genome_cost(env, ecfg, PE, KT, DF)
    assert float(np.asarray(obj, np.float32)) == float(
        np.float32(GOLDEN_BLEND[w]))


def test_blend_endpoints_equal_pure_objectives():
    assert GOLDEN_BLEND[0.0] == GOLDEN_AGG[("LP", "area")][2]   # == energy
    assert GOLDEN_BLEND[1.0] == GOLDEN_AGG[("LP", "area")][1]   # == latency


def test_blend_has_no_per_layer_decomposition():
    """The RL reward path cannot decompose lat^w * en^(1-w) per step."""
    ecfg = env_lib.EnvConfig(platform="cloud", objective="blend")
    env = env_lib.make_env(WL, ecfg)
    with pytest.raises(ValueError, match="blend"):
        env_lib.layer_cost(env, ecfg, 0, PE[0], KT[0], DF)
