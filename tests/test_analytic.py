"""Analytic FLOPs model vs XLA on a fully-unrolled reduced config.

XLA's cost_analysis counts while bodies once, so we unroll every stack
(lm.UNROLL_STACKS) and pick dims small enough that the flash/CE chunk scans
also don't trigger -- then XLA's count is complete and must agree with the
closed-form model (matmul-only, so the analytic number is a lower bound
within ~20%: XLA adds elementwise/softmax/norm flops).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.base import InputShape
from repro.distributed import analytic
from repro.models import lm
from repro.training import optim


def _compiled_flops(compiled) -> float:
    """jax's Compiled.cost_analysis() returns a dict in newer versions and a
    one-element list of dicts in older ones -- accept both."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


def _unrolled_flops(cfg, B, T, kind):
    lm.UNROLL_STACKS = True
    try:
        if kind == "train":
            opt = optim.Adam(lr=1e-4)

            def init():
                p = lm.init_params(jax.random.PRNGKey(0), cfg)
                return p, opt.init(p)

            ps = jax.eval_shape(init)
            sds = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), ps)
            batch = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B, T), jnp.int32)}
            step = functools.partial(lm.train_step, cfg=cfg, optimizer=opt)
            c = jax.jit(step).lower(sds[0], sds[1], batch).compile()
        else:
            def init():
                return lm.init_params(jax.random.PRNGKey(0), cfg)

            sds = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                jax.eval_shape(init))
            tok = jax.ShapeDtypeStruct((B, T), jnp.int32)
            c = jax.jit(lambda p, t: lm.prefill(p, cfg, t)).lower(
                sds, tok).compile()
        return _compiled_flops(c)
    finally:
        lm.UNROLL_STACKS = False


@pytest.mark.parametrize("arch,kind", [("qwen1p5_0p5b", "train"),
                                       ("qwen1p5_0p5b", "prefill"),
                                       ("starcoder2_3b", "train")])
def test_analytic_matches_unrolled_xla(arch, kind):
    cfg = dataclasses.replace(
        configs.get_smoke(arch), num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=4, d_ff=1024, vocab_size=2048,
        param_dtype="float32", compute_dtype="float32")
    B, T = 4, 512
    xla = _unrolled_flops(cfg, B, T, kind)
    shape = InputShape("probe", T, B, kind)
    ours = analytic.flops_cell(cfg, shape)["total"]
    ratio = xla / ours
    # analytic counts matmuls only; XLA adds elementwise overheads and for
    # train the remat factor differs slightly from 4.0 at this tiny depth.
    assert 0.6 < ratio < 1.45, (xla, ours, ratio)


def test_xla_undercounts_scans():
    """The reason this module exists: scan depth doesn't change XLA flops."""
    def flops_at(L):
        cfg = dataclasses.replace(
            configs.get_smoke("qwen1p5_0p5b"), num_layers=L, d_model=128,
            num_heads=8, num_kv_heads=8, d_ff=256, vocab_size=512)
        opt = optim.Adam(lr=1e-4)

        def init():
            p = lm.init_params(jax.random.PRNGKey(0), cfg)
            return p, opt.init(p)

        ps = jax.eval_shape(init)
        sds = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                           ps)
        batch = {"tokens": jax.ShapeDtypeStruct((4, 256), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 256), jnp.int32)}
        step = functools.partial(lm.train_step, cfg=cfg, optimizer=opt)
        return _compiled_flops(
            jax.jit(step).lower(sds[0], sds[1], batch).compile())

    assert flops_at(8) / flops_at(4) < 1.5  # NOT ~2x: body counted once


def test_analytic_scales_linearly_in_depth():
    a = analytic.flops_cell(configs.get("qwen1p5_0p5b"),
                            InputShape("x", 1024, 4, "prefill"))["total"]
    cfg2 = dataclasses.replace(configs.get("qwen1p5_0p5b"), num_layers=48)
    b = analytic.flops_cell(cfg2, InputShape("x", 1024, 4, "prefill"))["total"]
    blocks_a = a - analytic._unembed_flops(configs.get("qwen1p5_0p5b"), 4, 1)
    blocks_b = b - analytic._unembed_flops(cfg2, 4, 1)
    assert blocks_b / blocks_a == pytest.approx(2.0, rel=1e-6)
