"""Serving engine behaviour: bucketed prefill + lockstep decode."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serving import Engine, Request, ServeConfig
from repro.serving.engine import synthetic_requests


def _engine(arch: str, **scfg):
    cfg = dataclasses.replace(configs.get_smoke(arch),
                              param_dtype="float32",
                              compute_dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cross = None
    if cfg.family == "audio":
        cross = jax.numpy.zeros((1, cfg.encoder_seq, cfg.d_model))
    elif cfg.family == "vlm":
        cross = jax.numpy.zeros((1, cfg.vision_seq, cfg.d_model))
    return cfg, Engine(cfg, params,
                       ServeConfig(**{"max_len": 64, "max_batch": 4,
                                      **scfg}), cross_feats=cross)


@pytest.mark.parametrize("arch", ["qwen1p5_0p5b", "mamba2_130m",
                                  "zamba2_1p2b", "whisper_small",
                                  "llama3p2_vision_90b",
                                  "phi3p5_moe_42b"])
def test_generates_requested_tokens(arch):
    """Every model family serves through the same engine (KV caches, SSM
    state, hybrid, cross-attention to frontend features, MoE)."""
    cfg, eng = _engine(arch)
    reqs = synthetic_requests(5, cfg.vocab_size, prompt_lens=(4, 7),
                              max_new=6)
    stats = eng.serve(reqs)
    assert stats["requests"] == 5
    assert all(r.done and len(r.output) == 6 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.output)
    assert stats["buckets"] == 2  # two prompt lengths -> two buckets


def test_batched_matches_single_request():
    """Lockstep batching must not change any request's greedy output."""
    cfg, eng = _engine("qwen1p5_0p5b")
    reqs = synthetic_requests(4, cfg.vocab_size, prompt_lens=(5,), max_new=5)
    solo = [Request(uid=r.uid, prompt=list(r.prompt),
                    max_new_tokens=r.max_new_tokens) for r in reqs]
    eng.serve(reqs)
    cfg2, eng2 = _engine("qwen1p5_0p5b", max_batch=1)
    eng2.serve(solo)
    for a, b in zip(reqs, solo):
        assert a.output == b.output, (a.uid, a.output, b.output)


def test_stop_token_retires_request():
    cfg, eng = _engine("qwen1p5_0p5b")
    # Find what the model emits first, then use it as the stop token.
    probe = synthetic_requests(1, cfg.vocab_size, prompt_lens=(4,),
                               max_new=3, seed=7)
    eng.serve(probe)
    stop = probe[0].output[0]
    cfg2, eng2 = _engine("qwen1p5_0p5b", stop_token=stop)
    reqs = synthetic_requests(1, cfg.vocab_size, prompt_lens=(4,),
                              max_new=8, seed=7)
    eng2.serve(reqs)
    assert reqs[0].output[-1] == stop
    assert len(reqs[0].output) <= 8


def test_engine_respects_cache_capacity():
    cfg, eng = _engine("qwen1p5_0p5b", max_len=12)
    reqs = [Request(uid=0, prompt=[1] * 8, max_new_tokens=100)]
    eng.serve(reqs)
    # 8 prompt + generation must stay within max_len - 1.
    assert len(reqs[0].output) <= 12 - 8
