"""Pipeline parallelism: the GPipe shard_map schedule must match the plain
train step exactly (loss + params after one optimizer step)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n: int = 4, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pp_matches_reference():
    out = _run("""
import dataclasses, functools
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import lm
from repro.training import optim
from repro.distributed import pipeline, sharding

cfg = dataclasses.replace(configs.get_smoke("qwen3_32b"),
                          param_dtype="float32", compute_dtype="float32",
                          num_layers=4)
opt = optim.Adam(lr=1e-3)
mesh = jax.make_mesh((2, 2), ("data", "model"))
params, opt_state = pipeline.init_pp(jax.random.PRNGKey(0), cfg, opt)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                            cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}

ref = {"embed": params["embed"], "blocks": params["blocks"]}
p1, _, l1 = jax.jit(functools.partial(lm.train_step, cfg=cfg,
                                      optimizer=opt))(ref, opt.init(ref),
                                                      batch)
for M in (1, 2, 4):
    step = pipeline.make_pp_train_step(cfg, opt, mesh, n_micro=M)
    psh, osh = pipeline.pp_shardings(mesh, params, opt_state)
    bsh = sharding.batch_sharding(mesh, 8)
    with mesh:
        p2, _, l2 = jax.jit(step)(
            jax.device_put(params, psh), jax.device_put(opt_state, osh),
            {k: jax.device_put(v, bsh) for k, v in batch.items()})
    assert abs(float(l1) - float(l2)) < 2e-4, (M, float(l1), float(l2))
    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - np.asarray(b)).max()),
        {"blocks": p1["blocks"], "embed": p1["embed"]}, p2)))
    assert d < 5e-4, (M, d)
    print("OK", M, float(l2), d)
""")
    assert out.count("OK") == 3
