"""Model zoo: per-arch smoke tests + cross-path consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import common, lm, moe as moe_lib
from repro.training import optim


def _f32(cfg):
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


def _aux(cfg, key, B):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02}
    if cfg.family == "vlm":
        return {"patches": jax.random.normal(
            key, (B, cfg.vision_seq, cfg.d_model)) * 0.02}
    return {}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """Reduced same-family config: one forward + one train step on CPU."""
    cfg = _f32(configs.get_smoke(arch))
    key = jax.random.PRNGKey(0)
    B, T = 2, 16
    params = lm.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    aux = _aux(cfg, key, B)
    logits = lm.forward(params, cfg, tokens, aux or None)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    opt = optim.Adam(lr=1e-3)
    batch = {"tokens": tokens, "labels": tokens, **aux}
    p2, o2, loss = lm.train_step(params, opt.init(params), batch, cfg, opt)
    assert np.isfinite(float(loss))
    # params actually moved
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, p2)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_matches_forward(arch):
    """Step-by-step decode == full causal forward (per family).

    MoE uses a no-drop capacity factor: with finite capacity, prefill and
    decode drop different tokens by design (tested separately below)."""
    cfg = _f32(configs.get_smoke(arch))
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    B, T = 2, 10
    params = lm.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    aux = _aux(cfg, key, B)
    full = lm.forward(params, cfg, tokens, aux or None, remat=False)
    cache = lm.init_cache(cfg, B, T, dtype="float32")
    if cfg.family == "audio":
        feats = lm._encode_audio(params, cfg, aux["frames"], remat=False)
        xk, xv = lm.precompute_cross_kv(params, cfg, feats)
        cache = cache._replace(cross_k=xk, cross_v=xv)
    elif cfg.family == "vlm":
        xk, xv = lm.precompute_cross_kv(
            params, cfg, aux["patches"].astype(jnp.float32))
        cache = cache._replace(cross_k=xk, cross_v=xv)
    errs = []
    for t in range(T):
        logits, cache = lm.decode_step(params, cfg, cache, tokens[:, t])
        errs.append(float(jnp.abs(logits - full[:, t]).max()))
    assert max(errs) < 2e-4, errs


def test_blockwise_attention_matches_direct():
    key = jax.random.PRNGKey(0)
    B, T, H, Kv, hd = 2, 2048, 8, 2, 32
    q = jax.random.normal(key, (B, T, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Kv, hd))
    s = common._gqa_scores(q, k, 1.0 / jnp.sqrt(hd)).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((T, T), bool))
    w = jax.nn.softmax(jnp.where(mask, s, -1e30), -1).astype(q.dtype)
    ref = jnp.einsum("bkgts,bskd->btkgd", w, v).reshape(B, T, H * hd)
    out = common.blockwise_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_blockwise_ragged_kv():
    key = jax.random.PRNGKey(3)
    B, T, H, Kv, hd, S = 1, 1032, 4, 2, 16, 1601   # S prime
    q = jax.random.normal(key, (B, T, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Kv, hd))
    s = common._gqa_scores(q, k, 1.0 / jnp.sqrt(hd)).astype(jnp.float32)
    w = jax.nn.softmax(s, -1).astype(q.dtype)
    ref = jnp.einsum("bkgts,bskd->btkgd", w, v).reshape(B, T, H * hd)
    out = common.blockwise_attention(q, k, v, causal=False, kv_chunk=512)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_moe_routing_invariants():
    cfg = _f32(configs.get_smoke("qwen3_moe_235b"))
    key = jax.random.PRNGKey(0)
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.1
    y = moe_lib.moe_ffn(p, cfg, x, n_groups=1)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    aux = moe_lib.aux_load_balance_loss(p, cfg, x)
    assert np.isfinite(float(aux)) and float(aux) >= 0.9  # ~E*mean^2 lower bd


def test_moe_capacity_drops_bounded():
    """With cf=1.0 the capacity exactly bounds routed slots per expert."""
    cfg = dataclasses.replace(_f32(configs.get_smoke("phi3p5_moe_42b")),
                              moe_capacity_factor=1.0)
    g = 64
    C = moe_lib.capacity(g, cfg)
    assert C == g * cfg.experts_per_token // cfg.num_experts


def test_ssd_chunk_invariance():
    """Chunked SSD result is independent of chunk size (exact algorithm)."""
    from repro.models import ssm
    key = jax.random.PRNGKey(0)
    B, T, H, P, S = 2, 64, 4, 8, 16
    x = jax.random.normal(key, (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, T, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, T, S))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, T, S))
    y8 = ssm.ssd_chunked(x, dt, A, Bm, Cm, 8)
    y16 = ssm.ssd_chunked(x, dt, A, Bm, Cm, 16)
    y64 = ssm.ssd_chunked(x, dt, A, Bm, Cm, 64)
    # f32 accumulation order differs with chunk size; tolerance covers it.
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), atol=3e-4)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), atol=3e-4)


def test_unroll_matches_scan():
    cfg = _f32(configs.get_smoke("qwen1p5_0p5b"))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    a = lm.forward(params, cfg, tokens, remat=False)
    lm.UNROLL_STACKS = True
    try:
        b = lm.forward(params, cfg, tokens, remat=False)
    finally:
        lm.UNROLL_STACKS = False
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_param_counts_match_published():
    """Full configs land near the published parameter counts."""
    expect = {"qwen3_32b": 32e9, "qwen1p5_0p5b": 0.62e9,
              "starcoder2_3b": 3.0e9, "qwen2p5_3b": 3.1e9,
              "qwen3_moe_235b": 235e9, "phi3p5_moe_42b": 42e9,
              "mamba2_130m": 0.13e9, "llama3p2_vision_90b": 80e9}
    for arch, n in expect.items():
        got = configs.get(arch).param_count()
        assert 0.5 < got / n < 1.8, (arch, got, n)
