"""Property tests for the Pareto/dominance layer (core/nsga2.py).

Three invariants the frontier machinery must hold under any inputs:

  * a frontier is *mutually non-dominating* -- no member dominates another
    (both for ``non_dominated_mask`` on random clouds and for the archive
    a real NSGA-II run reports);
  * inserting a dominated (or duplicate) point never grows a frontier;
  * 2-D hypervolume is monotone under set union -- and therefore the
    frontier trace of a chunked NSGA-II run is monotone non-decreasing
    while the archive is below capacity.

CI runs this file under the real ``hypothesis`` package in its own tier-1
step (tests/hypothesis_stub degrades it to skips only for bare local
checkouts).
"""
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade property tests to skips, not collection errors
    from hypothesis_stub import given, settings, st

from repro.core import env as env_lib
from repro.core import nsga2
from repro.costmodel import workloads

NCF = workloads.get_workload("ncf")


def _cloud(rng, m, k=2):
    """Random objective cloud with deliberate duplicates/collinear points."""
    pts = rng.uniform(0.1, 10.0, size=(m, k))
    if m >= 4:
        pts[m // 2] = pts[0]            # exact duplicate
        pts[m // 4, 0] = pts[0, 0]      # tie in one objective
    return pts


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(1, 40))
def test_non_dominated_mask_is_mutually_non_dominating(seed, m):
    pts = _cloud(np.random.default_rng(seed), m)
    mask = nsga2.non_dominated_mask(pts)
    assert mask.any()                   # a finite set has a non-empty front
    front = pts[mask]
    for i in range(len(front)):
        for j in range(len(front)):
            if i != j:
                assert not nsga2.pareto_dominates(front[i], front[j])
    # Every excluded point is dominated by some front member.
    for q in pts[~mask]:
        assert any(nsga2.pareto_dominates(p, q) for p in front)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(1, 30))
def test_dominated_insertion_never_grows_the_front(seed, m):
    rng = np.random.default_rng(seed)
    pts = _cloud(rng, m)
    front = []
    for p in pts:
        front = nsga2.pareto_insert(front, p)
    size = len(front)
    arr = np.asarray(front)
    assert nsga2.non_dominated_mask(arr).all()
    # Dominated by a front member: strictly worse in every objective.
    for p in list(front):
        worse = np.asarray(p) * (1.0 + rng.uniform(0.01, 1.0, size=2))
        front2 = nsga2.pareto_insert(front, worse)
        assert len(front2) == size
    # Re-inserting existing members is a no-op too.
    for p in list(front):
        assert len(nsga2.pareto_insert(front, np.asarray(p))) == size


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(1, 25),
       extra=st.integers(1, 25))
def test_hypervolume_monotone_under_union(seed, m, extra):
    rng = np.random.default_rng(seed)
    a = _cloud(rng, m)
    b = _cloud(rng, extra)
    ref = np.maximum(a.max(axis=0), b.max(axis=0)) * 1.1
    hv_a = nsga2.hypervolume_2d(a, ref)
    hv_union = nsga2.hypervolume_2d(np.concatenate([a, b]), ref)
    assert hv_union >= hv_a - 1e-12
    assert hv_a >= 0.0
    # Points at/beyond the reference contribute nothing.
    assert nsga2.hypervolume_2d(np.asarray([ref, ref * 2]), ref) == 0.0


def test_chunked_run_frontier_trace_hv_is_monotone():
    """A real (small) NSGA-II run: each chunk's frontier snapshot dominates
    at least as much hypervolume as the last, and the final reported
    frontier is mutually non-dominating and feasible."""
    ecfg = env_lib.EnvConfig(platform="cloud")
    cfg = nsga2.NSGA2Config(population=16, generations=8, seed=3)
    snaps = []
    state, _hist = nsga2.run_nsga2_search(
        NCF, ecfg, cfg, chunk=1,
        on_chunk=lambda s, h, done: snaps.append(nsga2.frontier_points(s)))
    assert len(snaps) == 8
    final = nsga2.frontier_points(state)
    assert len(final) >= 1
    np.testing.assert_array_equal(final, snaps[-1])
    obj = final[:, :2]
    assert nsga2.non_dominated_mask(obj).all()
    # Archive capacity (128) far exceeds what 8 generations of 16 find, so
    # no truncation happened and HV must be monotone non-decreasing.
    assert all(len(s) <= cfg.archive for s in snaps)
    ref = np.concatenate([s[:, :2] for s in snaps if len(s)]).max(axis=0)
    ref = ref * 1.1
    hvs = [nsga2.hypervolume_2d(s[:, :2], ref) for s in snaps]
    assert all(b >= a - 1e-9 for a, b in zip(hvs, hvs[1:])), hvs
    assert hvs[-1] > 0.0
