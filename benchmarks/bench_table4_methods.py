"""Table IV: optimizer comparison across platform constraints.

MobileNet-V2, NVDLA-style, LP deployment.  Grid / Random / SA / GA /
Bayesian-opt / Con'X(global) under area & power budgets from unlimited to
IoTx.  The paper's headline: classic methods fail to find *feasible* points
under tight constraints ("NAN"); Con'X always succeeds and dominates.

The whole sweep is one loop over unified-registry names -- every method
takes the same SearchRequest and returns the same SearchOutcome.
"""
from __future__ import annotations

from benchmarks import common
from repro import api
from repro.costmodel import workloads

# (registry name, method-specific options, eps cap).  BO's surrogate update
# is O(observations) per batch, so its budget is capped as before.
METHODS = [
    ("grid", {}, None),
    ("random", {}, None),
    ("sa", {}, None),
    ("ga", {"population": 100}, None),
    ("bo", {}, 1500),
    ("reinforce", {}, None),
]

ROWS_FULL = [
    ("latency", "area", "unlimited"), ("latency", "area", "cloud"),
    ("latency", "area", "iot"), ("latency", "area", "iotx"),
    ("latency", "power", "cloud"), ("latency", "power", "iot"),
    ("latency", "power", "iotx"),
    ("energy", "area", "unlimited"), ("energy", "area", "cloud"),
    ("energy", "area", "iot"), ("energy", "area", "iotx"),
    ("energy", "power", "cloud"), ("energy", "power", "iot"),
    ("energy", "power", "iotx"),
]
ROWS_QUICK = [
    ("latency", "area", "cloud"), ("latency", "area", "iot"),
    ("latency", "area", "iotx"), ("latency", "power", "iot"),
    ("energy", "area", "iot"),
]


def run(budget_name: str = "quick") -> dict:
    b = common.budget(budget_name)
    eps = b["eps"]
    rows = ROWS_FULL if b["rows"] == "all" else ROWS_QUICK
    wl = workloads.mobilenet_v2()
    out_rows, payload = [], []
    for obj, cstr, plat in rows:
        ecfg = api.EnvConfig(objective=obj, constraint=cstr, platform=plat)
        rec = {"objective": obj, "constraint": cstr, "platform": plat}
        for name, opts, cap in METHODS:
            out = api.get_optimizer(name).run(api.SearchRequest(
                workload=wl, env=ecfg, eps=min(eps, cap) if cap else eps,
                method=name, options=opts))
            rec[name] = out.best_value
        payload.append(rec)
        out_rows.append([obj, f"{cstr}:{plat}"]
                        + [rec[name] for name, _, _ in METHODS])
    common.print_table(
        f"Table IV (MobileNet-V2, dla, LP, Eps={eps})",
        ["obj", "constraint", "Grid", "Random", "SA", "GA", "Bayes",
         "Con'X(g)"],
        out_rows)
    # Claim checks: Con'X is feasible everywhere; baselines fail somewhere
    # under tight budgets (full run) and never beat Con'X by >5%.
    feas = all(r["reinforce"] < float("inf") for r in payload)
    print(f"Con'X feasible on all {len(payload)} rows: {feas}")
    return {"rows": payload, "conx_always_feasible": feas, "eps": eps}


if __name__ == "__main__":
    common.save_json("table4_methods", run())
