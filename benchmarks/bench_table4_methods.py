"""Table IV: optimizer comparison across platform constraints.

MobileNet-V2, NVDLA-style, LP deployment.  Grid / Random / SA / GA /
Bayesian-opt / Con'X(global) under area & power budgets from unlimited to
IoTx.  The paper's headline: classic methods fail to find *feasible* points
under tight constraints ("NAN"); Con'X always succeeds and dominates.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import baselines, env as env_lib, ga as ga_lib, reinforce, \
    search
from repro.costmodel import workloads

ROWS_FULL = [
    ("latency", "area", "unlimited"), ("latency", "area", "cloud"),
    ("latency", "area", "iot"), ("latency", "area", "iotx"),
    ("latency", "power", "cloud"), ("latency", "power", "iot"),
    ("latency", "power", "iotx"),
    ("energy", "area", "unlimited"), ("energy", "area", "cloud"),
    ("energy", "area", "iot"), ("energy", "area", "iotx"),
    ("energy", "power", "cloud"), ("energy", "power", "iot"),
    ("energy", "power", "iotx"),
]
ROWS_QUICK = [
    ("latency", "area", "cloud"), ("latency", "area", "iot"),
    ("latency", "area", "iotx"), ("latency", "power", "iot"),
    ("energy", "area", "iot"),
]


def run(budget_name: str = "quick") -> dict:
    b = common.budget(budget_name)
    eps = b["eps"]
    rows = ROWS_FULL if b["rows"] == "all" else ROWS_QUICK
    wl = workloads.mobilenet_v2()
    out_rows, payload = [], []
    for obj, cstr, plat in rows:
        ecfg = env_lib.EnvConfig(objective=obj, constraint=cstr,
                                 platform=plat)
        rec = {"objective": obj, "constraint": cstr, "platform": plat}
        rec["grid"] = baselines.grid_search(wl, ecfg, eps=eps).best_value
        rec["random"] = baselines.random_search(wl, ecfg, eps=eps).best_value
        rec["sa"] = baselines.simulated_annealing(wl, ecfg,
                                                  eps=eps).best_value
        rec["ga"] = float(ga_lib.baseline_ga(
            wl, ecfg, ga_lib.GAConfig(population=100,
                                      generations=max(eps // 100, 1))
        ).best_value)
        rec["bayes"] = baselines.bayes_opt(wl, ecfg,
                                           eps=min(eps, 1500)).best_value
        res = search.confuciux_search(
            wl, ecfg,
            rcfg=reinforce.ReinforceConfig(epochs=eps, episodes_per_epoch=1),
            fine_tune=False)
        rec["conx_global"] = res.best_value
        payload.append(rec)
        out_rows.append([obj, f"{cstr}:{plat}", rec["grid"], rec["random"],
                         rec["sa"], rec["ga"], rec["bayes"],
                         rec["conx_global"]])
    common.print_table(
        f"Table IV (MobileNet-V2, dla, LP, Eps={eps})",
        ["obj", "constraint", "Grid", "Random", "SA", "GA", "Bayes",
         "Con'X(g)"],
        out_rows)
    # Claim checks: Con'X is feasible everywhere; baselines fail somewhere
    # under tight budgets (full run) and never beat Con'X by >5%.
    feas = all(r["conx_global"] < float("inf") for r in payload)
    print(f"Con'X feasible on all {len(payload)} rows: {feas}")
    return {"rows": payload, "conx_always_feasible": feas, "eps": eps}


if __name__ == "__main__":
    common.save_json("table4_methods", run())
