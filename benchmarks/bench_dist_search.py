"""Distributed-search scaling: episode-parallel REINFORCE over a mesh.

Measures (a) epoch throughput and samples/sec as simulated devices grow
1 -> 4 -> 8 (subprocesses own their XLA_FLAGS), (b) the solution-quality
effect of the scale knobs: straggler masking (2 dead shards of 8) and the
int8-compressed cross-pod gradient reduction.  This is the paper's own
workload at pod scale -- on a real 256-chip pod the same shard_map program
runs 256x the episode batch per epoch.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks import common

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = """
import json, time
import jax, numpy as np
from repro.core import env as env_lib, reinforce
from repro.distributed import dist_search
from repro.costmodel import workloads

wl = workloads.mobilenet_v2()[:20]
n = {n}
mesh = jax.make_mesh({mesh_shape}, {mesh_axes})
mask = np.ones(n, bool)
{mask_line}
epochs = {epochs}
t0 = time.time()
state, hist = dist_search.run_distributed_search(
    wl, env_lib.EnvConfig(platform="iot"), mesh,
    reinforce.ReinforceConfig(epochs=epochs, lr=3e-3),
    dist_search.DistConfig(episodes_per_device=2,
                           compress_pod_axis={compress}),
    straggler_mask=mask)
dt = time.time() - t0
print(json.dumps({{
    "devices": n, "epochs": epochs, "seconds": dt,
    "episodes_per_sec": epochs * 2 * int(mask.sum()) / dt,
    "best_value": float(state.best_value)}}))
"""


def _run(n, mesh_shape, mesh_axes, epochs, *, dead=0, compress=False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    mask_line = (f"mask[:{dead}] = False" if dead else "pass")
    code = _CODE.format(n=n, mesh_shape=mesh_shape, mesh_axes=mesh_axes,
                        epochs=epochs, mask_line=mask_line,
                        compress=compress)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(budget_name: str = "quick") -> dict:
    epochs = 150 if budget_name == "quick" else 600
    rows, payload = [], {}
    base = _run(1, "(1,)", '("data",)', epochs)
    payload["d1"] = base
    rows.append([1, "-", base["episodes_per_sec"], base["best_value"]])
    for n, shape, axes, tag in [
            (4, "(2, 2)", '("data","model")', "d4"),
            (8, "(2, 2, 2)", '("pod","data","model")', "d8")]:
        r = _run(n, shape, axes, epochs)
        payload[tag] = r
        rows.append([n, "-", r["episodes_per_sec"], r["best_value"]])
    st = _run(8, "(2, 2, 2)", '("pod","data","model")', epochs, dead=2)
    payload["d8_straggler"] = st
    rows.append([8, "2 dead shards", st["episodes_per_sec"],
                 st["best_value"]])
    cq = _run(8, "(2, 2, 2)", '("pod","data","model")', epochs,
              compress=True)
    payload["d8_int8pod"] = cq
    rows.append([8, "int8 pod-axis AR", cq["episodes_per_sec"],
                 cq["best_value"]])
    common.print_table(
        f"Distributed search scaling (epochs={epochs}, 2 episodes/device)",
        ["devices", "knob", "episodes/s", "best value"], rows)
    ok = (st["best_value"] < float("inf")
          and cq["best_value"] < float("inf"))
    print(f"straggler-masked and int8-compressed runs both converge: {ok}")
    payload["fault_knobs_converge"] = ok
    return payload


if __name__ == "__main__":
    common.save_json("dist_search", run())
