"""Table III: converged LP solutions across DNNs x dataflows x platforms.

GA vs PPO2 vs Con'X(global), objective latency, area constraint.  The
paper's pattern: GA NANs out under tight constraints (IoT/IoTx); PPO2 and
Con'X always find feasible points; Con'X is as good or better.  One registry
loop per row -- every method shares the same request/outcome schema.
"""
from __future__ import annotations

from benchmarks import common
from repro import api
from repro.costmodel import dataflows as dfl

METHODS = [("ga", {"population": 100}), ("ppo2", {}), ("reinforce", {})]

ROWS_FULL = [
    ("mobilenet_v2", "dla", "iot"), ("mobilenet_v2", "eye", "iotx"),
    ("mobilenet_v2", "shi", "iotx"),
    ("mnasnet", "dla", "cloud"), ("mnasnet", "eye", "iotx"),
    ("mnasnet", "shi", "iotx"),
    ("resnet50", "dla", "cloud"), ("resnet50", "eye", "cloud"),
    ("resnet50", "shi", "cloud"),
    ("gnmt", "dla", "iotx"), ("gnmt", "eye", "iot"), ("gnmt", "shi", "iot"),
    ("transformer", "dla", "iotx"), ("transformer", "eye", "iot"),
    ("transformer", "shi", "iot"),
    ("ncf", "dla", "iotx"), ("ncf", "eye", "cloud"), ("ncf", "shi", "iot"),
]
ROWS_QUICK = [
    ("mobilenet_v2", "dla", "iot"), ("mobilenet_v2", "eye", "iotx"),
    ("mnasnet", "dla", "cloud"), ("gnmt", "dla", "iotx"),
    ("transformer", "eye", "iot"), ("ncf", "dla", "iotx"),
]


def run(budget_name: str = "quick") -> dict:
    b = common.budget(budget_name)
    eps = b["eps"]
    rows = ROWS_FULL if b["rows"] == "all" else ROWS_QUICK
    out_rows, payload = [], []
    n_ga_nan = n_conx_best = 0
    for model, df, plat in rows:
        ecfg = api.EnvConfig(platform=plat,
                             dataflow=dfl.DATAFLOW_NAMES.index(df))
        rec = {"model": model, "dataflow": df, "platform": plat}
        for name, opts in METHODS:
            out = api.run_search(api.SearchRequest(
                workload=model, env=ecfg, eps=eps, method=name,
                options=opts))
            rec[name] = out.best_value
        n_ga_nan += rec["ga"] == float("inf")
        n_conx_best += (rec["reinforce"]
                        <= min(rec["ga"], rec["ppo2"]) * 1.001)
        payload.append(rec)
        out_rows.append([f"{model}-{df}", plat, rec["ga"], rec["ppo2"],
                         rec["reinforce"]])
    common.print_table(
        f"Table III (LP converged latency, Eps={eps})",
        ["model", "cstr", "GA", "PPO2", "Con'X(g)"], out_rows)
    print(f"GA infeasible (NAN) rows: {n_ga_nan}/{len(rows)}; "
          f"Con'X best-or-tied: {n_conx_best}/{len(rows)}")
    return {"rows": payload, "ga_nan": n_ga_nan,
            "conx_best_or_tied": n_conx_best, "eps": eps}


if __name__ == "__main__":
    common.save_json("table3_lp", run())
