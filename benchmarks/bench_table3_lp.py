"""Table III: converged LP solutions across DNNs x dataflows x platforms.

GA vs PPO2 vs Con'X(global), objective latency, area constraint.  The
paper's pattern: GA NANs out under tight constraints (IoT/IoTx); PPO2 and
Con'X always find feasible points; Con'X is as good or better.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import env as env_lib, ga as ga_lib, reinforce, \
    rl_baselines, search
from repro.costmodel import dataflows as dfl
from repro.costmodel import workloads

ROWS_FULL = [
    ("mobilenet_v2", "dla", "iot"), ("mobilenet_v2", "eye", "iotx"),
    ("mobilenet_v2", "shi", "iotx"),
    ("mnasnet", "dla", "cloud"), ("mnasnet", "eye", "iotx"),
    ("mnasnet", "shi", "iotx"),
    ("resnet50", "dla", "cloud"), ("resnet50", "eye", "cloud"),
    ("resnet50", "shi", "cloud"),
    ("gnmt", "dla", "iotx"), ("gnmt", "eye", "iot"), ("gnmt", "shi", "iot"),
    ("transformer", "dla", "iotx"), ("transformer", "eye", "iot"),
    ("transformer", "shi", "iot"),
    ("ncf", "dla", "iotx"), ("ncf", "eye", "cloud"), ("ncf", "shi", "iot"),
]
ROWS_QUICK = [
    ("mobilenet_v2", "dla", "iot"), ("mobilenet_v2", "eye", "iotx"),
    ("mnasnet", "dla", "cloud"), ("gnmt", "dla", "iotx"),
    ("transformer", "eye", "iot"), ("ncf", "dla", "iotx"),
]


def run(budget_name: str = "quick") -> dict:
    b = common.budget(budget_name)
    eps = b["eps"]
    rows = ROWS_FULL if b["rows"] == "all" else ROWS_QUICK
    out_rows, payload = [], []
    n_ga_nan = n_conx_best = 0
    for model, df, plat in rows:
        wl = workloads.get_workload(model)
        ecfg = env_lib.EnvConfig(platform=plat,
                                 dataflow=dfl.DATAFLOW_NAMES.index(df))
        ga_v = float(ga_lib.baseline_ga(
            wl, ecfg, ga_lib.GAConfig(population=100,
                                      generations=max(eps // 100, 1))
        ).best_value)
        ppo_state, _ = rl_baselines.run_ac_search(
            wl, ecfg, rl_baselines.ACConfig(algo="ppo2", epochs=eps,
                                            episodes_per_epoch=1))
        ppo_v = float(ppo_state.best_value)
        conx_v = search.confuciux_search(
            wl, ecfg, rcfg=reinforce.ReinforceConfig(
                epochs=eps, episodes_per_epoch=1),
            fine_tune=False).best_value
        n_ga_nan += ga_v == float("inf")
        n_conx_best += conx_v <= min(ga_v, ppo_v) * 1.001
        payload.append({"model": model, "dataflow": df, "platform": plat,
                        "ga": ga_v, "ppo2": ppo_v, "conx_global": conx_v})
        out_rows.append([f"{model}-{df}", plat, ga_v, ppo_v, conx_v])
    common.print_table(
        f"Table III (LP converged latency, Eps={eps})",
        ["model", "cstr", "GA", "PPO2", "Con'X(g)"], out_rows)
    print(f"GA infeasible (NAN) rows: {n_ga_nan}/{len(rows)}; "
          f"Con'X best-or-tied: {n_conx_best}/{len(rows)}")
    return {"rows": payload, "ga_nan": n_ga_nan,
            "conx_best_or_tied": n_conx_best, "eps": eps}


if __name__ == "__main__":
    common.save_json("table3_lp", run())
