"""Fig. 7: convergence / sample-efficiency traces.

Best-so-far objective vs epoch for Con'X(global), PPO2, GA, random -- the
traces behind the paper's fast-convergence claim.  Exported to JSON for
plotting; the table reports value at checkpoints (10%/30%/100% of budget).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines, env as env_lib, ga as ga_lib, reinforce, \
    rl_baselines, search
from repro.costmodel import workloads


def _at(trace, frac):
    trace = np.asarray(trace, dtype=float)
    i = min(len(trace) - 1, max(0, int(frac * len(trace)) - 1))
    v = np.minimum.accumulate(np.where(np.isfinite(trace), trace, np.inf))
    return float(v[i])


def run(budget_name: str = "quick") -> dict:
    eps = common.budget(budget_name)["eps"]
    wl = workloads.mobilenet_v2()
    ecfg = env_lib.EnvConfig(platform="iot")

    traces = {}
    res = search.confuciux_search(
        wl, ecfg, rcfg=reinforce.ReinforceConfig(epochs=eps,
                                                 episodes_per_epoch=1),
        fine_tune=False)
    traces["conx"] = res.history["best_value"]
    _, hist = rl_baselines.run_ac_search(
        wl, ecfg, rl_baselines.ACConfig(algo="ppo2", epochs=eps,
                                        episodes_per_epoch=1))
    traces["ppo2"] = hist["best_value"]
    ga_res = ga_lib.baseline_ga(
        wl, ecfg, ga_lib.GAConfig(population=100,
                                  generations=max(eps // 100, 1)))
    traces["ga"] = np.repeat(np.asarray(ga_res.history), 100)[:eps]
    traces["random"] = baselines.random_search(wl, ecfg, eps=eps).history

    rows = []
    for name, tr in traces.items():
        rows.append([name, _at(tr, 0.1), _at(tr, 0.3), _at(tr, 1.0)])
    common.print_table(
        f"Fig. 7 (best-so-far latency vs epoch, MobileNet-V2 IoT, Eps={eps})",
        ["method", "@10%", "@30%", "@100%"], rows)
    return {"eps": eps,
            "traces": {k: np.asarray(v, dtype=float).tolist()[:eps]
                       for k, v in traces.items()},
            "checkpoints": {r[0]: r[1:] for r in rows}}


if __name__ == "__main__":
    common.save_json("fig7_convergence", run())
