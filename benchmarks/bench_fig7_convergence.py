"""Fig. 7: convergence / sample-efficiency traces.

Best-so-far objective vs sample for Con'X(global), PPO2, GA, random -- the
traces behind the paper's fast-convergence claim.  Every trace is the
unified SearchOutcome.history (length == Eps, monotone best-so-far), so the
methods are directly comparable sample-for-sample.  Exported to JSON for
plotting; the table reports value at checkpoints (10%/30%/100% of budget).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import api
from repro.costmodel import workloads

METHODS = [
    ("reinforce", {}),
    ("ppo2", {}),
    ("ga", {"population": 100}),
    ("random", {}),
    # 4 parallel workers, merged wall-clock view: at trace index i the
    # ensemble has consumed 4*i samples.  backend=auto picks the parallel
    # path the host supports (device when >= 4 local devices, else threads).
    ("fanout", {"inner": "reinforce", "n_shards": 4, "backend": "auto"}),
]


def _at(trace, frac):
    trace = np.asarray(trace, dtype=float)
    i = min(len(trace) - 1, max(0, int(frac * len(trace)) - 1))
    return float(trace[i])


def run(budget_name: str = "quick") -> dict:
    eps = common.budget(budget_name)["eps"]
    wl = workloads.mobilenet_v2()
    ecfg = api.EnvConfig(platform="iot")

    traces = {}
    for name, opts in METHODS:
        out = api.run_search(api.SearchRequest(
            workload=wl, env=ecfg, eps=eps, method=name, options=opts))
        traces[name] = out.history

    rows = [[name, _at(tr, 0.1), _at(tr, 0.3), _at(tr, 1.0)]
            for name, tr in traces.items()]
    common.print_table(
        f"Fig. 7 (best-so-far latency vs sample, MobileNet-V2 IoT, "
        f"Eps={eps})",
        ["method", "@10%", "@30%", "@100%"], rows)
    return {"eps": eps,
            "traces": {k: np.asarray(v, dtype=float).tolist()
                       for k, v in traces.items()},
            "checkpoints": {r[0]: r[1:] for r in rows}}


if __name__ == "__main__":
    common.save_json("fig7_convergence", run())
