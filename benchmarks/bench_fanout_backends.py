"""Fanout backend scaling: serial vs threads vs device at 4 shards.

The ``fanout`` optimizer runs n independent seeds of an inner search and
merges the best -- the paper's sample-efficiency claim evaluated as a
wall-clock ensemble.  This benchmark measures how the three execution
backends spend that wall-clock for the two JAX-native inners (reinforce,
ga):

  * serial  -- n compiles + n sequential executions (the PR-1 baseline)
  * threads -- n compiles + n executions, overlapped by host threads
  * device  -- ONE compile of a shard_map'd program + all shards executing
               concurrently on the forced-host CPU devices

All backends produce bit-identical merged outcomes (asserted), so the only
difference is time.  Subprocesses own the XLA device-count flag, exactly
like bench_dist_search.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks import common

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = """
import json, time
from repro import api
from repro.costmodel import workloads

wl = workloads.mobilenet_v2()[:12]
req = dict(workload=wl, env=api.EnvConfig(platform="iot"),
           eps={eps}, seed=0, method="fanout")
res = {{}}
for backend in ("serial", "threads", "device"):
    t0 = time.time()
    out = api.run_search(api.SearchRequest(
        **req, options={{"inner": "{inner}", "n_shards": {shards},
                         "backend": backend,
                         "inner_options": {inner_opts}}}))
    res[backend] = {{"seconds": time.time() - t0,
                     "best_value": out.best_value,
                     "history_tail": float(out.history[-1])}}
    assert out.extras["backend"] == backend
# All three must merge to the same ensemble result.
assert len({{r["best_value"] for r in res.values()}}) == 1, res
print(json.dumps(res))
"""


def _run(inner, eps, shards, inner_opts):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={shards}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = _CODE.format(inner=inner, eps=eps, shards=shards,
                        inner_opts=json.dumps(inner_opts))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1800, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(budget_name: str = "quick") -> dict:
    eps = 300 if budget_name == "quick" else 2000
    shards = 4
    payload = {"n_shards": shards, "eps": eps}
    rows = []
    for inner, iopts in [("reinforce", {}), ("ga", {"population": 50})]:
        r = _run(inner, eps, shards, iopts)
        payload[inner] = r
        base = r["serial"]["seconds"]
        for backend in ("serial", "threads", "device"):
            rows.append([inner, backend, r[backend]["seconds"],
                         base / r[backend]["seconds"],
                         r[backend]["best_value"]])
    common.print_table(
        f"Fanout backends ({shards} shards, eps={eps}/shard, identical "
        f"merged outcomes)",
        ["inner", "backend", "seconds", "speedup vs serial", "best value"],
        rows)
    payload["speedup_device"] = {
        inner: payload[inner]["serial"]["seconds"]
        / payload[inner]["device"]["seconds"]
        for inner in ("reinforce", "ga")}
    return payload


if __name__ == "__main__":
    common.save_json("fanout_backends", run())
