"""Table IX: policy-network ablation -- MLP vs RNN(LSTM) x action levels L.

The paper: the RNN beats the MLP (it can remember consumed budget) and
L=12 is the sweet spot.  The policy variant is a ``policy`` option on the
unified request -- same registered optimizer, same outcome schema.
"""
from __future__ import annotations

from benchmarks import common
from repro import api

PLATFORMS_FULL = ["cloud", "iot", "iotx"]
PLATFORMS_QUICK = ["iot"]
LEVELS = [10, 12, 14]


def run(budget_name: str = "quick") -> dict:
    b = common.budget(budget_name)
    # The LSTM needs more samples than the MLP before its budget-memory
    # advantage shows (it starts behind at tiny budgets); floor at 2000.
    eps = max(b["eps"], 2000)
    platforms = (PLATFORMS_FULL if b["rows"] == "all" else PLATFORMS_QUICK)
    out_rows, payload = [], []
    for kind in ("mlp", "rnn"):
        for plat in platforms:
            vals = {}
            for L in LEVELS:
                out = api.run_search(api.SearchRequest(
                    workload="mobilenet_v2",
                    env=api.EnvConfig(platform=plat, levels=L), eps=eps,
                    method="reinforce",
                    options={"policy": {"kind": kind}}))
                vals[L] = out.best_value
            payload.append({"net": kind, "platform": plat,
                            **{f"L{L}": vals[L] for L in LEVELS}})
            out_rows.append([kind.upper(), plat] + [vals[L] for L in LEVELS])
    common.print_table(
        f"Table IX (policy network ablation, Eps={eps})",
        ["net", "cstr", "L=10", "L=12", "L=14"], out_rows)
    # Claim: RNN <= MLP at the paper's L=12 on each platform.
    rnn_wins = 0
    for plat in platforms:
        m = next(r for r in payload if r["net"] == "mlp"
                 and r["platform"] == plat)
        r = next(r for r in payload if r["net"] == "rnn"
                 and r["platform"] == plat)
        rnn_wins += r["L12"] <= m["L12"] * 1.02
    print(f"RNN best-or-tied at L=12 on {rnn_wins}/{len(platforms)} "
          "platforms")
    return {"rows": payload, "eps": eps, "rnn_wins_at_L12": rnn_wins}


if __name__ == "__main__":
    common.save_json("table9_policy", run())
