"""Framework throughput: the batched cost-model evaluation hot-spot.

Design-point evaluations / second for (a) the pure-jnp oracle and (b) the
Pallas kernel in interpret mode (correctness path; the TPU path uses the
same kernel compiled).  Also measures the end-to-end REINFORCE epoch rate
-- the number the paper reports as "search time" (Table V) collapses from
minutes to milliseconds with the env inside the XLA program (DESIGN.md S3).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import env as env_lib, reinforce
from repro.costmodel import workloads
from repro.costmodel.layers import layers_to_array
from repro.kernels import ops as kops


def _bench(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(budget_name: str = "quick") -> dict:
    full = common.budget(budget_name)["rows"] == "all"
    wl = workloads.mobilenet_v2()
    layers = jnp.asarray(layers_to_array(wl), jnp.float32)
    N = layers.shape[0]
    rows, payload = [], {}
    for B in ((256, 2048, 16384) if full else (256, 2048)):
        key = jax.random.PRNGKey(0)
        pe = jax.random.uniform(key, (B, N), minval=1.0, maxval=128.0)
        kt = jax.random.uniform(key, (B, N), minval=1.0, maxval=12.0)

        ref_fn = jax.jit(lambda l, p, k: kops.batched_cost(
            l, p, k, 0.0, use_kernel=False))
        t_ref = _bench(ref_fn, layers, pe, kt)
        evals = B * N
        rows.append([f"oracle (jnp)", B, f"{evals/t_ref:,.0f}"])
        payload[f"oracle_B{B}_evals_per_s"] = evals / t_ref

        if B <= 2048:  # interpret mode is python-speed; keep it bounded
            kern_fn = jax.jit(lambda l, p, k: kops.batched_cost(
                l, p, k, 0.0, use_kernel=True))
            t_k = _bench(kern_fn, layers, pe, kt, iters=2)
            rows.append([f"pallas (interpret)", B, f"{evals/t_k:,.0f}"])
            payload[f"pallas_interp_B{B}_evals_per_s"] = evals / t_k

    # End-to-end epoch rate (env-in-the-graph REINFORCE).
    ecfg = env_lib.EnvConfig(platform="iot")
    env = env_lib.make_env(wl, ecfg)
    import repro.core.policy as policy_lib
    from repro.training import optim
    pcfg = policy_lib.PolicyConfig(obs_dim=ecfg.obs_dim)
    rcfg = reinforce.ReinforceConfig(episodes_per_epoch=1)
    opt = optim.Adam(lr=3e-3)
    state = reinforce.init_search(env, ecfg, pcfg, rcfg, opt)
    epoch_fn = reinforce.make_epoch_fn(ecfg, pcfg, rcfg, env, opt)
    chunk = jax.jit(lambda s: jax.lax.scan(epoch_fn, s, None, length=100))
    state2, _ = chunk(state)
    jax.block_until_ready(state2.params)
    t0 = time.time()
    state2, _ = chunk(state2)
    jax.block_until_ready(state2.params)
    dt = time.time() - t0
    rows.append(["REINFORCE epochs/s (52-layer)", 100, f"{100/dt:,.0f}"])
    payload["reinforce_epochs_per_s"] = 100 / dt
    payload["paper_faithful_search_5000ep_seconds"] = 5000 * dt / 100

    common.print_table("Cost-model / search throughput (CPU host)",
                       ["path", "batch", "rate"], rows)
    print(f"=> full 5000-epoch paper search: "
          f"{payload['paper_faithful_search_5000ep_seconds']:.1f}s wall "
          "(the paper's PyTorch+binary setup: 25 min - 27 hrs, Table V)")
    return payload


if __name__ == "__main__":
    common.save_json("costmodel_throughput", run())
