"""Table V: RL-algorithm comparison -- solution quality, search time, and
sample efficiency (epochs to convergence).

REINFORCE (Con'X global) vs the actor-critic baselines A2C and PPO2 on the
same env/observation/reward.  The paper's claims: (1) REINFORCE reaches
equal-or-better objective values; (2) it converges 4.7-24x faster.
Convergence (first sample within 5% of the method's own final best) and
wall time come straight off the unified SearchOutcome -- the sweep is one
loop over registry names with zero per-method branching.
"""
from __future__ import annotations

from benchmarks import common
from repro import api

METHODS = ("reinforce", "a2c", "ppo2")

ROWS_FULL = [
    ("mobilenet_v2", "latency", "area", "iot"),
    ("mobilenet_v2", "latency", "area", "iotx"),
    ("mobilenet_v2", "latency", "power", "iot"),
    ("mobilenet_v2", "energy", "area", "iot"),
    ("mnasnet", "latency", "area", "iot"),
    ("resnet50", "latency", "area", "cloud"),
]
ROWS_QUICK = ROWS_FULL[:3]


def run(budget_name: str = "quick") -> dict:
    b = common.budget(budget_name)
    eps = b["eps"]
    rows = ROWS_FULL if b["rows"] == "all" else ROWS_QUICK
    out_rows, payload = [], []
    for model, obj, cstr, plat in rows:
        ecfg = api.EnvConfig(objective=obj, constraint=cstr, platform=plat)
        rec = {"model": model, "objective": obj,
               "constraint": f"{cstr}:{plat}"}
        for method in METHODS:
            out = api.run_search(api.SearchRequest(
                workload=model, env=ecfg, eps=eps, method=method))
            rec[method] = {"value": out.best_value,
                           "seconds": out.wall_seconds,
                           "epochs_conv": out.samples_to_convergence}
        payload.append(rec)
        # When a baseline never finds a feasible point its epochs_conv is
        # the full budget -- the true speedup is a LOWER bound.
        speedups, bounded = [], False
        for a in ("a2c", "ppo2"):
            speedups.append(rec[a]["epochs_conv"]
                            / max(rec["reinforce"]["epochs_conv"], 1))
            bounded |= rec[a]["value"] == float("inf")
        pre = ">=" if bounded else ""
        out_rows.append([
            model, obj, f"{cstr}:{plat}",
            rec["reinforce"]["value"], rec["reinforce"]["seconds"],
            rec["reinforce"]["epochs_conv"],
            rec["a2c"]["value"], rec["a2c"]["epochs_conv"],
            rec["ppo2"]["value"], rec["ppo2"]["epochs_conv"],
            f"{pre}{min(speedups):.1f}-{max(speedups):.1f}x"])
    common.print_table(
        f"Table V (RL algorithms, Eps={eps})",
        ["model", "obj", "cstr", "Con'X", "s", "ep_conv",
         "A2C", "ep_conv", "PPO2", "ep_conv", "conv speedup"],
        out_rows)
    return {"rows": payload, "eps": eps}


if __name__ == "__main__":
    common.save_json("table5_rl", run())
