"""Table V: RL-algorithm comparison -- solution quality, search time, and
sample efficiency (epochs to convergence).

REINFORCE (Con'X global) vs the actor-critic baselines A2C and PPO2 on the
same env/observation/reward.  The paper's claims: (1) REINFORCE reaches
equal-or-better objective values; (2) it converges 4.7-24x faster.  We
measure convergence as the first epoch reaching within 5% of the method's
own final best ("epochs to converge"), plus wall seconds.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import env as env_lib, reinforce, rl_baselines, search
from repro.costmodel import workloads

ROWS_FULL = [
    ("mobilenet_v2", "latency", "area", "iot"),
    ("mobilenet_v2", "latency", "area", "iotx"),
    ("mobilenet_v2", "latency", "power", "iot"),
    ("mobilenet_v2", "energy", "area", "iot"),
    ("mnasnet", "latency", "area", "iot"),
    ("resnet50", "latency", "area", "cloud"),
]
ROWS_QUICK = ROWS_FULL[:3]


def epochs_to_converge(best_trace: np.ndarray, tol: float = 0.05) -> int:
    finite = np.isfinite(best_trace)
    if not finite.any():
        return len(best_trace)
    final = best_trace[finite][-1]
    ok = finite & (best_trace <= final * (1 + tol))
    return int(np.argmax(ok)) + 1 if ok.any() else len(best_trace)


def run(budget_name: str = "quick") -> dict:
    b = common.budget(budget_name)
    eps = b["eps"]
    rows = ROWS_FULL if b["rows"] == "all" else ROWS_QUICK
    out_rows, payload = [], []
    for model, obj, cstr, plat in rows:
        wl = workloads.get_workload(model)
        ecfg = env_lib.EnvConfig(objective=obj, constraint=cstr,
                                 platform=plat)
        rec = {"model": model, "objective": obj,
               "constraint": f"{cstr}:{plat}"}

        with common.Timer() as t:
            res = search.confuciux_search(
                wl, ecfg, rcfg=reinforce.ReinforceConfig(
                    epochs=eps, episodes_per_epoch=1), fine_tune=False)
        rec["conx"] = {"value": res.best_value, "seconds": t.seconds,
                       "epochs_conv": epochs_to_converge(
                           res.history["best_value"])}

        for algo in ("a2c", "ppo2"):
            with common.Timer() as t:
                state, hist = rl_baselines.run_ac_search(
                    wl, ecfg, rl_baselines.ACConfig(
                        algo=algo, epochs=eps, episodes_per_epoch=1))
            rec[algo] = {"value": float(state.best_value),
                         "seconds": t.seconds,
                         "epochs_conv": epochs_to_converge(
                             hist["best_value"])}
        payload.append(rec)
        # When a baseline never finds a feasible point its epochs_conv is
        # the full budget -- the true speedup is a LOWER bound.
        speedups, bounded = [], False
        for a in ("a2c", "ppo2"):
            speedups.append(rec[a]["epochs_conv"]
                            / max(rec["conx"]["epochs_conv"], 1))
            bounded |= rec[a]["value"] == float("inf")
        pre = ">=" if bounded else ""
        out_rows.append([
            model, obj, f"{cstr}:{plat}",
            rec["conx"]["value"], rec["conx"]["seconds"],
            rec["conx"]["epochs_conv"],
            rec["a2c"]["value"], rec["a2c"]["epochs_conv"],
            rec["ppo2"]["value"], rec["ppo2"]["epochs_conv"],
            f"{pre}{min(speedups):.1f}-{max(speedups):.1f}x"])
    common.print_table(
        f"Table V (RL algorithms, Eps={eps})",
        ["model", "obj", "cstr", "Con'X", "s", "ep_conv",
         "A2C", "ep_conv", "PPO2", "ep_conv", "conv speedup"],
        out_rows)
    return {"rows": payload, "eps": eps}


if __name__ == "__main__":
    common.save_json("table5_rl", run())
