"""Fig. 5: the per-layer LS study -- exhaustive (PE, Buf) grids per layer,
Con'X per-layer optima vs heuristics A and B.

The paper's claims reproduced here:
  * each layer has a *different* optimal action pair;
  * Heuristic A (tune on the hottest layer) and B (best uniform pair for
    end-to-end) are dominated by per-layer assignment;
  * over-provisioning plateaus exist (flat latency regions at high levels);
  * DWCONV layers are indifferent to the buffer level under dla.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import env as env_lib, search
from repro.costmodel import workloads
from repro.costmodel.layers import DWCONV


def run(budget_name: str = "quick") -> dict:
    wl = workloads.mobilenet_v2()
    if common.budget(budget_name)["rows"] != "all":
        wl = wl[:20]
    ecfg = env_lib.EnvConfig(scenario="LS", platform="iot")
    grids = search.per_layer_optima(wl, ecfg)
    ha = search.heuristic_a(wl, ecfg)
    hb = search.heuristic_b(wl, ecfg)

    opt = grids["optima_latency"]
    n_unique = len({tuple(o) for o in opt})
    per_layer_best = float(sum(
        grids["latency"][i][tuple(opt[i])] for i in range(len(wl))))

    # Plateau + DWCONV structure checks straight off the grids.
    lat = grids["latency"]                       # (N, L, L)
    plateau_frac = float(np.mean(
        np.isclose(lat[:, -1, :], lat[:, -2, :], rtol=1e-3)))
    dw_idx = [i for i, l in enumerate(wl) if l.type == DWCONV]
    dw_kt_spread = float(np.mean(
        [lat[i].max(axis=0).max() / max(lat[i].max(axis=0).min(), 1)
         for i in dw_idx])) if dw_idx else 1.0
    dw_kt_flat = float(np.mean(
        [(lat[i][:, 1:].std(axis=1) / np.maximum(
            lat[i][:, 1:].mean(axis=1), 1)).mean() for i in dw_idx])
    ) if dw_idx else 0.0

    rows = [
        ["distinct per-layer optima", f"{n_unique}/{len(wl)}"],
        ["sum of per-layer optimum latency", per_layer_best],
        ["Heuristic A (hot-layer uniform)", ha["value"]],
        ["Heuristic B (best uniform)", hb["value"]],
        ["A vs per-layer", f"{ha['value']/per_layer_best:.2f}x"],
        ["B vs per-layer", f"{hb['value']/per_layer_best:.2f}x"],
        ["PE-plateau fraction (top levels)", f"{plateau_frac:.2f}"],
        ["DWCONV kt-flatness (cv, kt>=2)", f"{dw_kt_flat:.3f}"],
    ]
    common.print_table("Fig. 5 (LS per-layer study, MobileNet-V2)",
                       ["metric", "value"], rows)
    return {"n_layers": len(wl), "n_unique_optima": n_unique,
            "per_layer_best": per_layer_best,
            "heuristic_a": ha["value"], "heuristic_b": hb["value"],
            "plateau_frac": plateau_frac, "dwconv_kt_cv": dw_kt_flat}


if __name__ == "__main__":
    common.save_json("fig5_perlayer", run())
