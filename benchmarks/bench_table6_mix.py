"""Table VI: dataflow-HW co-automation.  Con'X-dla/-eye/-shi vs Con'X-MIX.

The MIX agent makes three decisions per layer (PE, Buffer, dataflow style);
the paper reports 4-69% further improvement over the best fixed style.  All
four variants run through the one registered "reinforce" optimizer -- only
the EnvConfig differs.
"""
from __future__ import annotations

from benchmarks import common
from repro import api
from repro.costmodel import dataflows as dfl

ROWS_FULL = [
    ("mobilenet_v2", "iot"), ("mobilenet_v2", "iotx"),
    ("mnasnet", "cloud"), ("mnasnet", "iot"),
    ("resnet50", "cloud"), ("resnet50", "iot"), ("resnet50", "iotx"),
    ("gnmt", "cloud"), ("ncf", "cloud"), ("ncf", "iot"),
]
ROWS_QUICK = [("mobilenet_v2", "iot"), ("mnasnet", "cloud"),
              ("ncf", "cloud")]

EPISODES = 4


def run(budget_name: str = "quick") -> dict:
    b = common.budget(budget_name)
    # One epoch = EPISODES vmapped episodes; keep the epoch count at the
    # budget's eps as before.
    eps = b["eps"] * EPISODES
    rows = ROWS_FULL if b["rows"] == "all" else ROWS_QUICK
    opts = {"episodes_per_epoch": EPISODES}
    out_rows, payload = [], []
    for model, plat in rows:
        vals = {}
        for name in dfl.DATAFLOW_NAMES:
            ecfg = api.EnvConfig(
                platform=plat, dataflow=dfl.DATAFLOW_NAMES.index(name))
            vals[name] = api.run_search(api.SearchRequest(
                workload=model, env=ecfg, eps=eps, method="reinforce",
                options=opts)).best_value
        mix_out = api.run_search(api.SearchRequest(
            workload=model, env=api.EnvConfig(platform=plat, mix=True),
            eps=eps, method="reinforce", options=opts))
        vals["mix"] = mix_out.best_value
        best_fixed = min(vals[n] for n in dfl.DATAFLOW_NAMES)
        impr = 100.0 * (1 - vals["mix"] / best_fixed)
        payload.append({"model": model, "platform": plat, **vals,
                        "mix_improvement_pct": impr,
                        "mix_styles": [dfl.DATAFLOW_NAMES[int(d)]
                                       for d in mix_out.df]})
        out_rows.append([model, plat, vals["dla"], vals["eye"], vals["shi"],
                         vals["mix"], f"{impr:+.1f}%"])
    common.print_table(
        f"Table VI (dataflow-HW co-automation, Eps={eps})",
        ["model", "cstr", "Con'X-dla", "Con'X-eye", "Con'X-shi", "Con'X-MIX",
         "vs best fixed"], out_rows)
    return {"rows": payload, "eps": eps}


if __name__ == "__main__":
    common.save_json("table6_mix", run())
