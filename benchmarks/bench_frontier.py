"""NSGA-II frontier search vs scalarized weight sweeps at equal budget.

The paper optimizes latency *or* energy per run; deployments want the
trade-off curve.  This benchmark measures how much curve one eval budget
buys, two ways:

  * ``nsga2``: one native multi-objective run, the whole budget on one
    constrained Pareto search (frontier = the run's archive);
  * ``sweep``: the classic alternative -- the same budget split across 5
    scalarized single-objective runs (``lat^w * en^(1-w)`` for w in
    {0, .25, .5, .75, 1}, GA as the inner engine), frontier = the feasible
    winners (:func:`repro.core.search.scalarized_frontier_sweep`).

Score: dominated hypervolume (minimization, reference point = 1.1x the
nadir of the union of both frontiers, per config).  Acceptance: nsga2 HV
>= sweep HV on >= 3 of the 4 standard configs, and nsga2 outcomes
byte-identical between serial and service-batched execution.  A fifth
multi-DNN co-design row (3-architecture mix, per-layer dataflow genes,
``EnvConfig(mix=True)``) exercises the ragged multi-workload path but does
not count toward the 3-of-4 criterion.

Writes ``results/frontier.json`` + human-readable ``results/frontier.md``.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks import common
from repro import api
from repro.core import env as env_lib
from repro.core import nsga2 as nsga2_lib
from repro.core import search as search_lib
from repro.costmodel import workloads

WEIGHTS = (0.0, 0.25, 0.5, 0.75, 1.0)

# (name, workload, env kwargs, counts toward the 3-of-4 acceptance check).
CONFIGS = [
    ("ncf/cloud/lat",     "ncf",          dict(platform="cloud"), True),
    ("ncf/iot/energy",    "ncf",          dict(platform="iot",
                                               objective="energy",
                                               constraint="power"), True),
    ("mnasnet/cloud/lat", "mnasnet",      dict(platform="cloud"), True),
    ("mobilenet/iot/lat", "mobilenet_v2", dict(platform="iot"), True),
    ("mix3/cloud/lat",    "multi_dnn",    dict(platform="cloud", mix=True),
     False),
]

MIX_ARCHS = ["qwen1p5_0p5b", "whisper_small", "mamba2_130m"]


def _reference_point(*point_sets) -> np.ndarray:
    """1.1x the nadir (per-dim max) of the union of (k, 2) point sets."""
    pts = np.concatenate([np.asarray(p, float).reshape(-1, 2)
                          for p in point_sets if len(p)], axis=0)
    pts = pts[np.all(np.isfinite(pts), axis=1)]
    if len(pts) == 0:
        return np.array([1.0, 1.0])
    return pts.max(axis=0) * 1.1


def _service_parity(request: api.SearchRequest,
                    serial: api.SearchOutcome) -> bool:
    """Serial vs service-batched nsga2: byte-identical outcome?"""
    from repro.serving import SearchService
    from repro.serving.search_service import ServiceConfig

    with SearchService(ServiceConfig(max_workers=2)) as svc:
        batched = svc.submit(request).result()
    return (serial.history.tobytes() == batched.history.tobytes()
            and serial.pe.tobytes() == batched.pe.tobytes()
            and serial.kt.tobytes() == batched.kt.tobytes()
            and np.array_equal(serial.frontier["lat"],
                               batched.frontier["lat"])
            and np.array_equal(serial.frontier["en"],
                               batched.frontier["en"]))


def run(budget_name: str = "quick") -> dict:
    eps = common.budget(budget_name)["eps"]
    results = {}
    rows = []
    for cname, wname, env_kw, counts in CONFIGS:
        if wname == "multi_dnn":
            wl = workloads.multi_dnn(MIX_ARCHS, tokens=32)
            c_eps = max(eps // 3, 96)
        else:
            wl = workloads.get_workload(wname)
            c_eps = eps
        ecfg = env_lib.EnvConfig(**env_kw)
        pop = max(min(30, c_eps // 10), 8)

        # Native multi-objective run (whole budget on one frontier).
        t0 = time.time()
        request = api.SearchRequest(
            workload=wl, env=ecfg, eps=c_eps, seed=0, method="nsga2",
            options={"population": pop, "archive": 128})
        out = api.run_search(request)
        t_nsga2 = time.time() - t0
        front = np.stack([out.frontier["lat"], out.frontier["en"]], axis=-1)
        parity = _service_parity(request, out)

        # Scalarized 5-weight sweep at the same total hard-eval budget.
        t0 = time.time()
        sweep = search_lib.scalarized_frontier_sweep(
            wl, ecfg, eps=c_eps, weights=WEIGHTS, method="ga", seed=0,
            options={"population": max(min(30, c_eps // len(WEIGHTS) // 4,),
                                       8)})
        t_sweep = time.time() - t0
        sweep_pts = sweep["points"][:, :2]

        ref = _reference_point(front, sweep_pts)
        hv_nsga2 = nsga2_lib.hypervolume_2d(front, ref)
        hv_sweep = nsga2_lib.hypervolume_2d(sweep_pts, ref)
        results[cname] = {
            "eps": c_eps, "population": pop,
            "hv_nsga2": hv_nsga2, "hv_sweep": hv_sweep,
            "hv_ratio": (hv_nsga2 / hv_sweep if hv_sweep > 0
                         else float("inf") if hv_nsga2 > 0 else 1.0),
            "nsga2_ge_sweep": bool(hv_nsga2 >= hv_sweep),
            "frontier_size": int(len(front)),
            "sweep_points": int(len(sweep_pts)),
            "reference_point": ref.tolist(),
            "frontier": {k: np.asarray(v).tolist()
                         for k, v in out.frontier.items()
                         if k in ("lat", "en", "area", "pw")},
            "sweep_frontier": sweep_pts.tolist(),
            "best_value_nsga2": out.best_value,
            "serial_batched_identical": parity,
            "counts_toward_acceptance": counts,
            "seconds_nsga2": round(t_nsga2, 1),
            "seconds_sweep": round(t_sweep, 1),
        }
        rows.append([cname, c_eps, len(front), len(sweep_pts),
                     hv_nsga2, hv_sweep,
                     "yes" if hv_nsga2 >= hv_sweep else "no",
                     "yes" if parity else "NO"])

    common.print_table(
        "Pareto frontier: nsga2 vs 5-weight scalarized sweep "
        f"(equal budget, eps={eps})",
        ["config", "eps", "|front|", "|sweep|", "HV nsga2", "HV sweep",
         "nsga2>=sweep", "serial==batched"],
        rows)

    standard = [c for c, _, _, counts in CONFIGS if counts]
    n_pass = sum(results[c]["nsga2_ge_sweep"] for c in standard)
    all_parity = all(results[c]["serial_batched_identical"]
                     for c, _, _, _ in CONFIGS)
    verdict = (f"nsga2 hypervolume >= scalarized sweep on "
               f"{n_pass}/{len(standard)} standard configs at equal "
               f"hard-eval budget; serial == service-batched outcomes: "
               f"{'yes' if all_parity else 'NO'}")
    print(f"\nverdict: {verdict}")
    _write_md(rows, eps, verdict)
    return {"configs": results, "n_pass": n_pass,
            "all_parity": all_parity, "verdict": verdict}


def _write_md(rows, eps, verdict) -> None:
    lines = [
        "# Pareto frontier: NSGA-II vs scalarized weight sweeps",
        "",
        "One constrained multi-objective `nsga2` run vs the same hard-eval",
        f"budget (eps={eps}) split across 5 scalarized GA runs",
        "(`lat^w * en^(1-w)`, w in {0, .25, .5, .75, 1}).  Score =",
        "dominated hypervolume w.r.t. 1.1x the nadir of the union of both",
        "frontiers (minimization; bigger is better).  The `mix3` row",
        "co-designs one HW assignment for a 3-architecture serving mix",
        "(per-layer dataflow genes, `EnvConfig(mix=True)`) and is reported",
        "but not counted in the acceptance check.",
        "",
        "| config | eps | frontier pts | sweep pts | HV nsga2 | HV sweep |"
        " nsga2 >= sweep | serial == batched |",
        "| ------ | --- | ------------ | --------- | -------- | -------- |"
        " -------------- | ----------------- |",
    ]
    for r in rows:
        lines.append("| " + " | ".join(common.fmt(c) for c in r) + " |")
    lines += ["", f"**Verdict:** {verdict}", ""]
    os.makedirs(common.RESULTS_DIR, exist_ok=True)
    path = os.path.join(common.RESULTS_DIR, "frontier.md")
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {path}")


if __name__ == "__main__":
    import sys

    payload = run(sys.argv[1] if len(sys.argv) > 1 else "quick")
    common.save_json("frontier", payload)
