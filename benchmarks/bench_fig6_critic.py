"""Fig. 6: can a critic network learn the HW-performance value function?

The paper's standalone experiment: train the critic (same trunk as the
actor-critic baselines) to regress per-layer latency of MobileNet-V2 from
the observation, over increasing dataset sizes.  The RMSE plateaus at a
large value (5.3e4 cycles in the paper) -- the landscape is too discrete /
irregular -- which is the paper's explanation for why REINFORCE (no critic)
beats actor-critic methods here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import env as env_lib
from repro.costmodel import maestro, workloads
from repro.training import optim

SIZES_FULL = [2_000, 10_000, 50_000, 260_000]
SIZES_QUICK = [2_000, 20_000]


def _dataset(n: int, seed: int = 0):
    """(obs, latency) pairs: random layer x random action, like RL visits."""
    wl = workloads.mobilenet_v2()
    env = env_lib.make_env(wl, env_lib.EnvConfig())
    rng = np.random.default_rng(seed)
    li = rng.integers(0, env.num_layers, size=n)
    pe_lvl = rng.integers(0, 12, size=n)
    kt_lvl = rng.integers(0, 12, size=n)
    pe = np.asarray(env.pe_table)[pe_lvl]
    kt = np.asarray(env.kt_table)[kt_lvl]
    lat = maestro.evaluate(env.layers[li], jnp.asarray(pe, jnp.float32),
                           jnp.asarray(kt, jnp.float32), 0).latency
    sobs = np.asarray(env.static_obs)[li]
    L = 11.0
    obs = np.concatenate(
        [sobs, (2 * pe_lvl[:, None] / L - 1), (2 * kt_lvl[:, None] / L - 1),
         (2 * li[:, None] / max(env.num_layers - 1, 1) - 1)], axis=1)
    return (jnp.asarray(obs, jnp.float32),
            jnp.asarray(np.asarray(lat), jnp.float32))


def _fit(obs, y, *, hidden=128, steps=3000, lr=1e-3, seed=0):
    """The critic: MLP(128) regression head, MSE + Adam (as Fig. 6)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    I = obs.shape[1]
    params = {
        "w1": jax.random.normal(k1, (I, hidden)) * (2.0 / (I + hidden)) ** .5,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, hidden)) * (1.0 / hidden) ** .5,
        "b2": jnp.zeros((hidden,)),
        "w3": jax.random.normal(k3, (hidden, 1)) * (1.0 / hidden) ** .5,
        "b3": jnp.zeros((1,)),
    }
    # Normalize the target (the critic sees standardized rewards too).
    mu, sd = jnp.mean(y), jnp.std(y) + 1e-6
    yn = (y - mu) / sd
    n = obs.shape[0]
    ntr = int(0.9 * n)
    opt = optim.Adam(lr=lr)
    ost = opt.init(params)

    def pred(p, x):
        h = jnp.tanh(x @ p["w1"] + p["b1"])
        h = jnp.tanh(h @ p["w2"] + p["b2"])
        return (h @ p["w3"] + p["b3"])[..., 0]

    def loss_fn(p, x, t):
        return jnp.mean(jnp.square(pred(p, x) - t))

    @jax.jit
    def step(p, ost, key):
        idx = jax.random.randint(key, (min(1024, ntr),), 0, ntr)
        l, g = jax.value_and_grad(loss_fn)(p, obs[idx], yn[idx])
        p, ost = opt.update(g, ost, p)
        return p, ost, l

    key = jax.random.PRNGKey(seed + 1)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        params, ost, _ = step(params, ost, sub)
    rmse_tr = float(jnp.sqrt(loss_fn(params, obs[:ntr], yn[:ntr]))) * float(sd)
    rmse_te = float(jnp.sqrt(loss_fn(params, obs[ntr:], yn[ntr:]))) * float(sd)
    pred_te = pred(params, obs[ntr:]) * sd + mu
    med_rel = float(jnp.median(jnp.abs(pred_te - y[ntr:])
                               / jnp.maximum(y[ntr:], 1.0)))
    return rmse_tr, rmse_te, med_rel


def _median_rel_error(params_pred, obs, y, ntr):
    import jax.numpy as jnp
    err = jnp.abs(params_pred - y[ntr:])
    return float(jnp.median(err / jnp.maximum(y[ntr:], 1.0)))


def run(budget_name: str = "quick") -> dict:
    sizes = (SIZES_FULL if common.budget(budget_name)["rows"] == "all"
             else SIZES_QUICK)
    rows, payload = [], []
    y_range = None
    for n in sizes:
        obs, y = _dataset(n)
        if y_range is None:
            y_range = (float(y.min()), float(y.max()), float(y.std()),
                       float(np.median(np.asarray(y))))
        tr, te, med_rel = _fit(obs, y)
        payload.append({"n": n, "rmse_train": tr, "rmse_test": te,
                        "rmse_test_over_std": te / y_range[2],
                        "rmse_over_median_latency": te / y_range[3],
                        "median_rel_error": med_rel})
        rows.append([n, tr, te, f"{te/y_range[3]:.1f}x",
                     f"{100*med_rel:.0f}%"])
    common.print_table(
        "Fig. 6 (critic value-function fit, MobileNet-V2 latency)",
        ["#data", "train RMSE (cy)", "test RMSE (cy)", "RMSE/median(y)",
         "median rel err"], rows)
    print(f"latency range: [{y_range[0]:.2e}, {y_range[1]:.2e}], "
          f"std {y_range[2]:.2e}, median {y_range[3]:.2e}")
    # The paper's reading (its best RMSE 5.3e4 cycles is called a failure):
    # the critic's error dwarfs the per-layer latencies the policy must
    # discriminate, even though the large cross-layer variance lets the
    # *absolute* RMSE look respectable.
    fails = payload[-1]["rmse_over_median_latency"] > 1.0
    print(f"critic error exceeds the median layer latency at max data: "
          f"{fails} -- unusable as a per-action value signal")
    return {"rows": payload, "y_range": y_range,
            "critic_fails_to_fit": bool(fails)}


if __name__ == "__main__":
    common.save_json("fig6_critic", run())
