"""One-shot relaxed search vs the sampling engines: quality per hard eval.

The honest version of the "most radical speed play": the relaxed engine
descends the *differentiable* soft cost model and only spends its ``eps``
budget on hard-model probes of rounded candidates, so it should reach
REINFORCE-class solutions with an order of magnitude fewer hard
evaluations.  This benchmark measures exactly that, fig7-style, on several
workload configs:

  * every method reports its unified ``SearchOutcome.history`` (best-so-far
    per hard eval), so samples are comparable one-for-one;
  * ``relaxed`` runs at 1/10th the baselines' hard-eval budget;
  * "matched quality" = first sample within 5% of REINFORCE's final best;
    we report each method's evals-to-match and wall-clock, plus the EDP
    (latency x energy of the returned design under the hard model) so the
    comparison is not gameable by the objective choice alone.

Writes ``results/relaxed_oneshot.json`` and a human-readable
``results/relaxed_oneshot.md`` recording the acceptance check (relaxed
within 5% of reinforce on >= 3 configs at <= 1/10th the hard evals).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks import common
from repro import api
from repro.core import env as env_lib
from repro.costmodel import maestro, layers_to_array

CONFIGS = [
    ("ncf/cloud/lat",     "ncf",          dict(platform="cloud")),
    ("ncf/iot/energy",    "ncf",          dict(platform="iot",
                                               objective="energy",
                                               constraint="power")),
    ("mnasnet/cloud/lat", "mnasnet",      dict(platform="cloud")),
    ("mobilenet/iot/lat", "mobilenet_v2", dict(platform="iot")),
]


def _edp(workload, ecfg, out):
    """latency x energy of the returned design under the hard model."""
    if not out.feasible:
        return float("inf")
    arr = layers_to_array(workload) if isinstance(workload, (list, tuple)) \
        else np.asarray(workload)
    mc = maestro.model_cost(arr, np.asarray(out.pe, np.float32),
                            np.asarray(out.kt, np.float32),
                            np.asarray(out.df, np.float32), ecfg.scenario)
    return float(mc.latency) * float(mc.energy)


def _evals_to(trace, target):
    """First sample index (1-based) reaching within 5% of target."""
    tr = np.asarray(trace, dtype=float)
    ok = np.isfinite(tr) & (tr <= target * 1.05)
    return int(np.argmax(ok)) + 1 if ok.any() else None


def run(budget_name: str = "quick") -> dict:
    eps = common.budget(budget_name)["eps"]
    eps_relaxed = max(eps // 10, 20)
    results = {}
    rows = []
    for cname, wname, env_kw in CONFIGS:
        from repro.costmodel import workloads
        wl = workloads.get_workload(wname)
        ecfg = env_lib.EnvConfig(**env_kw)
        per_method = {}
        for method, budget_eps, opts in [
                ("reinforce", eps, {}),
                ("ga", eps, {"population": min(100, eps // 5)}),
                ("relaxed", eps_relaxed, {})]:
            t0 = time.time()
            out = api.run_search(api.SearchRequest(
                workload=wl, env=ecfg, eps=budget_eps, seed=0,
                method=method, options=opts))
            per_method[method] = {
                "eps": budget_eps,
                "best": out.best_value,
                "wall_s": round(time.time() - t0, 2),
                "edp": _edp(wl, ecfg, out),
                "history": np.asarray(out.history, dtype=float),
            }
        ref_best = per_method["reinforce"]["best"]
        for method, rec in per_method.items():
            rec["evals_to_match"] = (_evals_to(rec["history"], ref_best)
                                     if np.isfinite(ref_best) else None)
            rec["within_5pct"] = bool(
                np.isfinite(rec["best"]) and np.isfinite(ref_best)
                and rec["best"] <= ref_best * 1.05)
            rows.append([cname, method, rec["eps"], rec["best"],
                         rec["evals_to_match"], rec["wall_s"], rec["edp"]])
        results[cname] = per_method

    common.print_table(
        f"One-shot relaxed vs sampling engines (Eps={eps}, "
        f"relaxed at Eps/10={eps_relaxed})",
        ["config", "method", "evals", "best", "evals_to_match",
         "wall_s", "edp"], rows)

    n_pass = sum(results[c]["relaxed"]["within_5pct"] for c, _, _ in CONFIGS)
    ratio = eps_relaxed / eps
    verdict = (f"relaxed matched reinforce (<=5% worse) on "
               f"{n_pass}/{len(CONFIGS)} configs using {ratio:.2f}x "
               f"the hard-model evals")
    print(f"\n{verdict}")
    _write_md(rows, eps, eps_relaxed, verdict)
    return {"eps": eps, "eps_relaxed": eps_relaxed,
            "configs": {c: {m: {k: v for k, v in rec.items()
                                if k != "history"}
                            for m, rec in per.items()}
                        for c, per in results.items()},
            "traces": {c: {m: rec["history"].tolist()
                           for m, rec in per.items()}
                       for c, per in results.items()},
            "pass_count": n_pass, "verdict": verdict}


def _write_md(rows, eps, eps_relaxed, verdict) -> None:
    lines = [
        "# One-shot relaxed search vs sampling engines",
        "",
        "The `relaxed` engine descends the differentiable soft cost model "
        "and spends hard-model evaluations only on rounded candidates; the "
        "sampling engines (`reinforce`, `ga`) pay one hard eval per sample.",
        "",
        f"Budgets: baselines Eps={eps} hard evals, relaxed "
        f"Eps={eps_relaxed} (1/10th).  `evals_to_match` = first hard eval "
        "within 5% of reinforce's final best (the matched-quality point); "
        "`edp` = latency x energy of the returned design under the hard "
        "model.",
        "",
        "| config | method | hard evals | best objective | evals to match "
        "| wall (s) | EDP |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append("| " + " | ".join(common.fmt(c) for c in r) + " |")
    lines += ["", f"**Result:** {verdict}.", ""]
    path = os.path.join(common.RESULTS_DIR, "relaxed_oneshot.md")
    os.makedirs(common.RESULTS_DIR, exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {path}")


if __name__ == "__main__":
    common.save_json("relaxed_oneshot", run())
