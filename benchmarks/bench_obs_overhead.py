"""Telemetry overhead: the disabled path must cost (almost) nothing.

The ``repro.obs`` contract is one bool check per call site while disabled.
Two measurements back that up:

  * **macro** -- the 8-way service mix from ``bench_search_service``
    (random/grid/bo/ga/sa over two workloads) run with telemetry off and
    on, interleaved off/on/off/on... so machine drift hits both arms
    equally; the median off-vs-off-baseline overhead of the *off* arm vs a
    never-imported baseline is what the <2% acceptance bound refers to
    (the *on* arm is reported for context -- tracing real spans is allowed
    to cost more);
  * **micro** -- ns/op of the disabled primitives themselves
    (``span()``, ``Counter.inc``, ``Histogram.observe``, ``record()``),
    which is where the "one bool check" claim is directly visible.

Outcomes of off and on runs are asserted byte-identical (the conformance
suite asserts the same registry-wide; here it is checked on the service
mix end to end).
"""
from __future__ import annotations

import statistics
import time

import numpy as np

from benchmarks import common
from repro import api, obs
from repro.serving import SearchService, ServiceConfig


def _mix(eps: int, n_users: int):
    workloads = ("ncf", "mobilenet_v2")
    methods = ("random", "grid", "bo", "ga", "sa", "random", "ga", "sa")
    reqs = []
    for u in range(n_users):
        method = methods[u % len(methods)]
        reqs.append(api.SearchRequest(
            workload=workloads[u % 2],
            env=api.EnvConfig(platform="cloud"),
            eps=eps, seed=u // 2, method=method,
            options={"population": 50} if method == "ga" else {}))
    return reqs


def _run_mix(eps: int, n_users: int) -> tuple:
    with SearchService(ServiceConfig(max_workers=n_users)) as svc:
        with common.Timer() as t:
            outs = svc.run_all(_mix(eps, n_users))
    return t.seconds, outs


def _micro(n: int = 200_000) -> dict:
    """ns/op of the disabled-telemetry primitives."""
    assert not obs.enabled()
    c = obs.counter("repro_bench_disabled_counter")
    h = obs.histogram("repro_bench_disabled_hist")
    from repro.obs import recorder as rec_mod
    from repro.obs import trace as trace_mod

    def bench(fn):
        t0 = time.perf_counter_ns()
        for _ in range(n):
            fn()
        return (time.perf_counter_ns() - t0) / n

    return {
        "span_ns": bench(lambda: trace_mod.span("x")),
        "counter_inc_ns": bench(lambda: c.inc()),
        "histogram_observe_ns": bench(lambda: h.observe(1.0)),
        "record_ns": bench(lambda: rec_mod.record("k")),
    }


def run(budget_name: str = "quick") -> dict:
    eps = 200 if budget_name == "quick" else 1000
    n_users = 8
    rounds = 3 if budget_name == "quick" else 5

    obs.disable()
    # Warm-up: JIT compiles and the env memo must not land in either arm.
    _, ref = _run_mix(eps, n_users)

    off_s, on_s = [], []
    on_outs = None
    for _ in range(rounds):
        obs.disable()
        s, outs_off = _run_mix(eps, n_users)
        off_s.append(s)
        obs.reset()
        obs.enable(trace=True)
        s, on_outs = _run_mix(eps, n_users)
        on_s.append(s)
        obs.disable()

    # Telemetry is observational: identical outcomes off vs on.
    for a, b in zip(ref, on_outs):
        assert a.best_value == b.best_value, (a.method,)
        assert np.array_equal(a.history, b.history), a.method

    med_off = statistics.median(off_s)
    med_on = statistics.median(on_s)
    micro = _micro()

    overhead_pct = 100.0 * (med_on - med_off) / med_off
    rows = [["off (disabled)", med_off, 0.0],
            ["on (tracing)", med_on, overhead_pct]]
    common.print_table(
        f"Telemetry overhead on the {n_users}-way service mix "
        f"(eps={eps}, median of {rounds})",
        ["telemetry", "seconds", "overhead %"], rows)
    common.print_table(
        "Disabled primitives (ns/op)",
        ["primitive", "ns"],
        [[k.replace("_ns", ""), v] for k, v in micro.items()])

    payload = {
        "eps": eps, "n_users": n_users, "rounds": rounds,
        "off_seconds": off_s, "on_seconds": on_s,
        "median_off_seconds": med_off, "median_on_seconds": med_on,
        "enabled_overhead_pct": overhead_pct,
        "micro_disabled": micro,
        "outcomes_identical": True,
    }
    _write_md(payload)
    return payload


def _write_md(p: dict) -> None:
    import os

    os.makedirs(common.RESULTS_DIR, exist_ok=True)
    path = os.path.join(common.RESULTS_DIR, "obs_overhead.md")
    with open(path, "w") as f:
        f.write("# Telemetry overhead\n\n")
        f.write(f"8-way service mix, eps={p['eps']}, median of "
                f"{p['rounds']} interleaved rounds.\n\n")
        f.write("| telemetry | median seconds |\n|---|---|\n")
        f.write(f"| off | {p['median_off_seconds']:.2f} |\n")
        f.write(f"| on (tracing) | {p['median_on_seconds']:.2f} |\n\n")
        f.write(f"Enabled overhead: {p['enabled_overhead_pct']:.1f}% "
                "(the <2% acceptance bound applies to the *disabled* "
                "path, whose per-call cost is below).\n\n")
        f.write("| disabled primitive | ns/op |\n|---|---|\n")
        for k, v in p["micro_disabled"].items():
            f.write(f"| {k.replace('_ns', '')} | {v:.0f} |\n")
        f.write("\nOutcomes off vs on: byte-identical (asserted).\n")


if __name__ == "__main__":
    common.save_json("obs_overhead", run())
