"""Shared benchmark harness utilities.

Every benchmark module exposes ``run(budget) -> dict`` where budget scales
the sample counts ("quick" for CI-sized runs, "full" for the paper's
Eps=5000).  Results are printed as aligned tables and written to
``results/<bench>.json`` so EXPERIMENTS.md can cite them.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Sequence

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")

# Sample budgets (paper: Eps = 5000).
BUDGETS = {
    "quick": {"eps": 600, "ga_gens": 300, "rows": "subset"},
    "full": {"eps": 5000, "ga_gens": 2000, "rows": "all"},
}


def budget(name: str) -> Dict:
    return BUDGETS[name]


def fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == float("inf"):
            return "NAN"          # the paper's notation for infeasible
        if v != 0 and (abs(v) >= 1e4 or abs(v) < 1e-2):
            return f"{v:.2e}"
        return f"{v:.3g}"
    return str(v)


def print_table(title: str, headers: Sequence[str],
                rows: Sequence[Sequence]) -> None:
    cells = [[fmt(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in cells)) if cells else len(h)
              for i, h in enumerate(headers)]
    print(f"\n== {title} ==")
    print("  ".join(h.ljust(w) for h, w in zip(headers, widths)))
    print("  ".join("-" * w for w in widths))
    for r in cells:
        print("  ".join(c.ljust(w) for c, w in zip(r, widths)))


def save_json(name: str, payload: Dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_jsonable)
    return path


def _jsonable(o):
    import numpy as np

    if isinstance(o, (np.floating, np.integer)):
        return o.item()
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0


def stamp_metrics(payload: Dict, key: str = "metrics") -> Dict:
    """Attach the current ``repro.obs`` metrics snapshot to a results
    payload (no-op when telemetry is disabled) -- benchmarks call this just
    before ``save_json`` so ``results/*.json`` carry the registry state
    that produced them."""
    from repro import obs

    if obs.enabled():
        payload[key] = obs.REGISTRY.snapshot()
    return payload


def write_metrics_prom(name: str) -> str:
    """Write the current registry as ``results/<name>.prom`` (Prometheus
    text exposition) and return the path."""
    from repro import obs

    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.prom")
    obs.write_prometheus(path)
    return path
