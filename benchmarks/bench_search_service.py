"""Search-as-a-service throughput: concurrent multiplexing vs serial dispatch.

A fleet of "users" submits the SAME kind of traffic a deployed ConfuciuX
endpoint would see: a mix of methods over a couple of popular workloads,
with some users submitting identical queries (resubmissions / defaults).
We measure:

  * serial   -- ``api.run_search`` over the requests one after another,
                every search driving its own jit-dispatch loop (the PR-1
                deployment story);
  * service  -- the same requests through :class:`SearchService`: one
                worker-pool, one fused cost-eval dispatch stream, one
                shared per-point memo cache.

Every outcome is asserted bit-identical between the two paths (the service
is an execution strategy, not an approximation).  Reported: wall-clock
speedup, searches/sec, cache hit rate, and batcher fusion stats.  A second
warm wave (the same traffic again) shows the steady-state regime where the
cache has saturated the popular workloads' point space.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro import api
from repro.serving import SearchService, ServiceConfig


def _mix(eps: int, n_users: int):
    """n_users requests: methods x workloads round-robin, 2 users/seed."""
    workloads = ("ncf", "mobilenet_v2")
    methods = ("random", "grid", "bo", "random")
    reqs = []
    for u in range(n_users):
        reqs.append(api.SearchRequest(
            workload=workloads[u % 2],
            env=api.EnvConfig(platform="cloud"),
            eps=eps, seed=u // 2,             # 2 users share each seed
            method=methods[u % 4]))
    return reqs


def run(budget_name: str = "quick") -> dict:
    eps = 400 if budget_name == "quick" else 2000
    n_users = 8 if budget_name == "quick" else 16
    reqs = _mix(eps, n_users)

    with common.Timer() as t_serial:
        serial = [api.run_search(r) for r in reqs]

    svc = SearchService(ServiceConfig(max_workers=n_users))
    with common.Timer() as t_cold:
        cold = svc.run_all(_mix(eps, n_users))
    stats_cold = svc.stats()
    with common.Timer() as t_warm:
        warm = svc.run_all(_mix(eps, n_users))
    stats_warm = svc.stats()
    svc.close()

    # CPU/GPU route the batcher through the jnp oracle -> bit-exact parity.
    # On TPU the auto-selected Pallas kernel agrees with the oracle only to
    # float32 allclose (same status as every kernel/oracle pair), so the
    # parity assertion relaxes accordingly.
    import jax

    exact = jax.default_backend() != "tpu"
    for a, b, c in zip(serial, cold, warm):
        for other in (b, c):
            if exact:
                assert a.best_value == other.best_value, \
                    (a.method, a.best_value, other.best_value)
                assert np.array_equal(a.history, other.history)
            else:
                np.testing.assert_allclose(a.best_value, other.best_value,
                                           rtol=1e-5)

    warm_hits = stats_warm["cache_hits"] - stats_cold["cache_hits"]
    warm_misses = stats_warm["cache_misses"] - stats_cold["cache_misses"]
    warm_rate = warm_hits / max(warm_hits + warm_misses, 1)
    rows = [
        ["serial", t_serial.seconds, 1.0, n_users / t_serial.seconds, None],
        ["service (cold cache)", t_cold.seconds,
         t_serial.seconds / t_cold.seconds, n_users / t_cold.seconds,
         stats_cold["cache_hit_rate"]],
        ["service (warm cache)", t_warm.seconds,
         t_serial.seconds / t_warm.seconds, n_users / t_warm.seconds,
         warm_rate],
    ]
    common.print_table(
        f"Search service: {n_users} concurrent searches, eps={eps}, "
        f"identical outcomes vs serial (asserted)",
        ["dispatch", "seconds", "speedup", "searches/sec", "cache hit rate"],
        rows)
    common.print_table(
        "Batcher fusion (cumulative)",
        ["wave", "dispatches", "fused", "max fused reqs", "points",
         "fresh evals"],
        [["cold", stats_cold["dispatches"], stats_cold["fused_dispatches"],
          stats_cold["max_items_per_dispatch"], stats_cold["points"],
          stats_cold["fresh_points"]],
         ["cold+warm", stats_warm["dispatches"],
          stats_warm["fused_dispatches"],
          stats_warm["max_items_per_dispatch"], stats_warm["points"],
          stats_warm["fresh_points"]]])

    return {
        "n_users": n_users, "eps": eps,
        "serial_seconds": t_serial.seconds,
        "service_cold_seconds": t_cold.seconds,
        "service_warm_seconds": t_warm.seconds,
        "speedup_cold": t_serial.seconds / t_cold.seconds,
        "speedup_warm": t_serial.seconds / t_warm.seconds,
        "searches_per_sec_warm": n_users / t_warm.seconds,
        "cache_hit_rate_cold": stats_cold["cache_hit_rate"],
        "cache_hit_rate_warm_wave": warm_rate,
        "outcomes_identical": True,
        "stats": stats_warm,
    }


if __name__ == "__main__":
    common.save_json("search_service", run())
