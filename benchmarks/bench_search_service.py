"""Search-as-a-service throughput: concurrent multiplexing vs serial dispatch.

A fleet of "users" submits the SAME kind of traffic a deployed ConfuciuX
endpoint would see: a mix of methods over a couple of popular workloads,
with some users submitting identical queries (resubmissions / defaults).
Since the chunked-GA/SA work, the mix includes ``ga`` and ``sa`` -- GA
populations are the largest eval batches in the system and now route
through the cross-request batcher like everyone else.  We measure:

  * serial    -- ``api.run_search`` over the requests one after another,
                 every search driving its own jit-dispatch loop (the PR-1
                 deployment story);
  * service   -- the same requests through :class:`SearchService` with the
                 single-thread fused dispatcher (the PR-3 configuration);
  * service (pool) -- the same service with ``dispatch_workers > 1``: up to
                 N fused dispatches execute concurrently;
  * persistent restart -- a service with ``cache_dir`` set runs the mix
                 cold (writing cache shards), closes, and a FRESH service
                 on the same directory reruns it: the warm-restart wave
                 must evaluate zero fresh points (100% hit rate straight
                 from disk) while staying bit-identical.

Every outcome is asserted bit-identical across all paths (the service is an
execution strategy, not an approximation).  Reported: wall-clock speedup,
searches/sec, cache hit rate, and batcher fusion stats.  A warm wave (the
same traffic again) shows the steady-state regime where the cache has
saturated the popular workloads' point space.

A final *telemetry probe* wave re-runs reinforce/ga/nsga2/relaxed through
the service with ``repro.obs`` enabled: each outcome's flight-recorder
summary lands in the results JSON, the span trace is written to
``results/search_service_trace.jsonl`` and the metrics registry to
``results/search_service_metrics.prom`` (the artifacts
``tools/check_telemetry.py`` validates in CI).  The timed phases above run
with telemetry off, so the headline numbers measure the un-instrumented
fast path.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks import common
from repro import api, obs
from repro.serving import SearchService, ServiceConfig

POOL_WORKERS = 2  # sized for the 2-core dev container; raise on real hosts


def _mix(eps: int, n_users: int):
    """n_users requests: methods x workloads round-robin, 2 users/seed."""
    workloads = ("ncf", "mobilenet_v2")
    methods = ("random", "grid", "bo", "ga", "sa", "random", "ga", "sa")
    reqs = []
    for u in range(n_users):
        method = methods[u % len(methods)]
        reqs.append(api.SearchRequest(
            workload=workloads[u % 2],
            env=api.EnvConfig(platform="cloud"),
            eps=eps, seed=u // 2,             # 2 users share each seed
            method=method,
            options={"population": 50} if method == "ga" else {}))
    return reqs


def _assert_identical(serial, outs, exact):
    for a, b in zip(serial, outs):
        if exact:
            assert a.best_value == b.best_value, \
                (a.method, a.best_value, b.best_value)
            assert np.array_equal(a.history, b.history), a.method
        else:
            np.testing.assert_allclose(a.best_value, b.best_value, rtol=1e-5)


def _telemetry_probe(eps: int):
    """Instrumented wave: the chunked-engine quartet through the service.

    Returns (per-method telemetry summaries, trace path, metrics path,
    metrics snapshot) and leaves the artifacts in ``results/`` for
    ``tools/check_telemetry.py``.
    """
    os.makedirs(common.RESULTS_DIR, exist_ok=True)
    trace_path = os.path.join(common.RESULTS_DIR,
                              "search_service_trace.jsonl")
    obs.reset()
    obs.enable(trace=True, jsonl_path=trace_path)
    reqs = [api.SearchRequest(workload="ncf",
                              env=api.EnvConfig(platform="cloud"),
                              eps=eps, seed=0, method=m)
            for m in ("reinforce", "ga", "nsga2", "relaxed")]
    with SearchService(ServiceConfig(max_workers=4)) as svc:
        outs = svc.run_all(reqs)
    telemetry = {o.method: o.telemetry for o in outs}
    for m, t in telemetry.items():
        assert t is not None and t.get("hard_evals", 0) > 0, (m, t)
    prom_path = common.write_metrics_prom("search_service_metrics")
    snapshot = obs.REGISTRY.snapshot()
    obs.tracer().close()   # the JSONL sink already streamed every span
    obs.disable()
    common.print_table(
        "Telemetry probe (instrumented service wave)",
        ["method", "hard evals", "chunks", "cache hit rate", "jit compiles"],
        [[m, t.get("hard_evals"), t.get("chunks"),
          t.get("cache_hit_rate"), t.get("jit_compiles")]
         for m, t in telemetry.items()])
    return telemetry, trace_path, prom_path, snapshot


def run(budget_name: str = "quick") -> dict:
    eps = 400 if budget_name == "quick" else 2000
    n_users = 8 if budget_name == "quick" else 16
    reqs = _mix(eps, n_users)

    with common.Timer() as t_serial:
        serial = [api.run_search(r) for r in reqs]

    # CPU/GPU route the batcher through the jnp oracle -> bit-exact parity.
    # On TPU the auto-selected Pallas kernel agrees with the oracle only to
    # float32 allclose (same status as every kernel/oracle pair), so the
    # parity assertion relaxes accordingly.
    import jax

    exact = jax.default_backend() != "tpu"

    svc = SearchService(ServiceConfig(max_workers=n_users))
    with common.Timer() as t_cold:
        cold = svc.run_all(_mix(eps, n_users))
    stats_cold = svc.stats()
    with common.Timer() as t_warm:
        warm = svc.run_all(_mix(eps, n_users))
    stats_warm = svc.stats()
    svc.close()
    _assert_identical(serial, cold, exact)
    _assert_identical(serial, warm, exact)

    pool = SearchService(ServiceConfig(max_workers=n_users,
                                       dispatch_workers=POOL_WORKERS))
    with common.Timer() as t_pool_cold:
        pool_cold = pool.run_all(_mix(eps, n_users))
    stats_pool_cold = pool.stats()
    with common.Timer() as t_pool_warm:
        pool_warm = pool.run_all(_mix(eps, n_users))
    stats_pool = pool.stats()
    pool.close()
    _assert_identical(serial, pool_cold, exact)
    _assert_identical(serial, pool_warm, exact)

    # Persistent-cache restart: same mix, cold service writes shards on
    # close; a brand-new service on the same cache_dir serves the whole
    # rerun from disk (zero fresh evaluations, still bit-identical).
    import tempfile

    cache_dir = tempfile.mkdtemp(prefix="repro-bench-cache-")
    pers1 = SearchService(ServiceConfig(max_workers=n_users,
                                        cache_dir=cache_dir))
    with common.Timer() as t_pers_cold:
        pers_cold = pers1.run_all(_mix(eps, n_users))
    stats_pers_cold = pers1.stats()
    pers1.close()
    pers2 = SearchService(ServiceConfig(max_workers=n_users,
                                        cache_dir=cache_dir))
    with common.Timer() as t_pers_warm:
        pers_warm = pers2.run_all(_mix(eps, n_users))
    stats_pers_warm = pers2.stats()
    pers2.close()
    _assert_identical(serial, pers_cold, exact)
    _assert_identical(serial, pers_warm, exact)
    assert stats_pers_warm["cache_misses"] == 0, \
        f"warm restart missed {stats_pers_warm['cache_misses']} points"

    def warm_rate(warm_stats, cold_stats):
        hits = warm_stats["cache_hits"] - cold_stats["cache_hits"]
        misses = warm_stats["cache_misses"] - cold_stats["cache_misses"]
        return hits / max(hits + misses, 1)

    rows = [
        ["serial", t_serial.seconds, 1.0, n_users / t_serial.seconds, None],
        ["service (cold cache)", t_cold.seconds,
         t_serial.seconds / t_cold.seconds, n_users / t_cold.seconds,
         stats_cold["cache_hit_rate"]],
        ["service (warm cache)", t_warm.seconds,
         t_serial.seconds / t_warm.seconds, n_users / t_warm.seconds,
         warm_rate(stats_warm, stats_cold)],
        [f"pool x{POOL_WORKERS} (cold cache)", t_pool_cold.seconds,
         t_serial.seconds / t_pool_cold.seconds,
         n_users / t_pool_cold.seconds, stats_pool_cold["cache_hit_rate"]],
        [f"pool x{POOL_WORKERS} (warm cache)", t_pool_warm.seconds,
         t_serial.seconds / t_pool_warm.seconds,
         n_users / t_pool_warm.seconds,
         warm_rate(stats_pool, stats_pool_cold)],
        ["persistent (cold, writes shards)", t_pers_cold.seconds,
         t_serial.seconds / t_pers_cold.seconds,
         n_users / t_pers_cold.seconds, stats_pers_cold["cache_hit_rate"]],
        ["persistent (warm RESTART)", t_pers_warm.seconds,
         t_serial.seconds / t_pers_warm.seconds,
         n_users / t_pers_warm.seconds, stats_pers_warm["cache_hit_rate"]],
    ]
    common.print_table(
        f"Search service: {n_users} concurrent searches (incl. ga/sa), "
        f"eps={eps}, identical outcomes vs serial (asserted)",
        ["dispatch", "seconds", "speedup", "searches/sec", "cache hit rate"],
        rows)
    common.print_table(
        "Batcher fusion (cumulative)",
        ["config", "dispatches", "fused", "max fused reqs", "points",
         "fresh evals", "max concurrent"],
        [["single, cold", stats_cold["dispatches"],
          stats_cold["fused_dispatches"],
          stats_cold["max_items_per_dispatch"], stats_cold["points"],
          stats_cold["fresh_points"],
          stats_cold["max_concurrent_dispatches"]],
         ["single, cold+warm", stats_warm["dispatches"],
          stats_warm["fused_dispatches"],
          stats_warm["max_items_per_dispatch"], stats_warm["points"],
          stats_warm["fresh_points"],
          stats_warm["max_concurrent_dispatches"]],
         [f"pool x{POOL_WORKERS}, cold+warm", stats_pool["dispatches"],
          stats_pool["fused_dispatches"],
          stats_pool["max_items_per_dispatch"], stats_pool["points"],
          stats_pool["fresh_points"],
          stats_pool["max_concurrent_dispatches"]]])

    telemetry, trace_path, prom_path, metrics_snapshot = _telemetry_probe(
        eps)

    return {
        "n_users": n_users, "eps": eps,
        "telemetry_probe": telemetry,
        "trace_path": trace_path,
        "metrics_path": prom_path,
        "metrics": metrics_snapshot,
        "pool_workers": POOL_WORKERS,
        "serial_seconds": t_serial.seconds,
        "service_cold_seconds": t_cold.seconds,
        "service_warm_seconds": t_warm.seconds,
        "pool_cold_seconds": t_pool_cold.seconds,
        "pool_warm_seconds": t_pool_warm.seconds,
        "speedup_cold": t_serial.seconds / t_cold.seconds,
        "speedup_warm": t_serial.seconds / t_warm.seconds,
        "speedup_pool_cold": t_serial.seconds / t_pool_cold.seconds,
        "speedup_pool_warm": t_serial.seconds / t_pool_warm.seconds,
        "searches_per_sec_warm": n_users / t_warm.seconds,
        "searches_per_sec_pool_warm": n_users / t_pool_warm.seconds,
        "cache_hit_rate_cold": stats_cold["cache_hit_rate"],
        "cache_hit_rate_warm_wave": warm_rate(stats_warm, stats_cold),
        "persistent_cold_seconds": t_pers_cold.seconds,
        "persistent_warm_restart_seconds": t_pers_warm.seconds,
        "speedup_persistent_warm_restart":
            t_serial.seconds / t_pers_warm.seconds,
        "persistent_warm_restart_hit_rate":
            stats_pers_warm["cache_hit_rate"],
        "persistent_warm_restart_fresh_points":
            stats_pers_warm["fresh_points"],
        "persistent_entries_loaded": stats_pers_warm["cache_entries"],
        "persistent_shards_loaded": stats_pers_warm["cache_shards_loaded"],
        "max_concurrent_dispatches_pool":
            stats_pool["max_concurrent_dispatches"],
        "outcomes_identical": True,
        "stats_single": stats_warm,
        "stats_pool": stats_pool,
    }


if __name__ == "__main__":
    common.save_json("search_service", run())
