"""Table VII: the two-stage optimization breakdown.

initial-valid value -> stage-1 (RL global) -> stage-2 (local GA), with the
paper's improvement percentages (stage-1: 37.9-99.8%, stage-2: 7-93%).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import env as env_lib, ga as ga_lib, reinforce, search
from repro.costmodel import workloads

ROWS_FULL = [
    ("mobilenet_v2", "iot"), ("mnasnet", "iot"), ("resnet50", "cloud"),
    ("resnet50", "iot"), ("gnmt", "iot"), ("ncf", "iot"),
]
ROWS_QUICK = [("mobilenet_v2", "iot"), ("ncf", "iot")]


def run(budget_name: str = "quick") -> dict:
    b = common.budget(budget_name)
    eps, gens = b["eps"], b["ga_gens"]
    rows = ROWS_FULL if b["rows"] == "all" else ROWS_QUICK
    out_rows, payload = [], []
    for model, plat in rows:
        wl = workloads.get_workload(model)
        ecfg = env_lib.EnvConfig(platform=plat)
        res = search.confuciux_search(
            wl, ecfg,
            rcfg=reinforce.ReinforceConfig(epochs=eps, episodes_per_epoch=1),
            gcfg=ga_lib.LocalGAConfig(population=20, generations=gens,
                                      crossover_rate=0.2, mutation_rate=0.05,
                                      mutation_step=4))
        s1 = (100 * (1 - res.stage1_value / res.initial_valid_value)
              if np.isfinite(res.initial_valid_value) else None)
        s2 = (100 * (1 - res.best_value / res.stage1_value)
              if np.isfinite(res.stage1_value) else None)
        payload.append({"model": model, "platform": plat,
                        "initial_valid": res.initial_valid_value,
                        "stage1": res.stage1_value, "stage2": res.best_value,
                        "stage1_impr_pct": s1, "stage2_impr_pct": s2})
        out_rows.append([f"{model}-dla", plat, res.initial_valid_value,
                         res.stage1_value,
                         f"{s1:.1f}%" if s1 is not None else "-",
                         res.best_value,
                         f"{s2:.1f}%" if s2 is not None else "-"])
    common.print_table(
        f"Table VII (two-stage optimization, Eps={eps}, GA gens={gens})",
        ["model", "cstr", "init valid", "stage1", "impr", "stage2 (final)",
         "impr"], out_rows)
    return {"rows": payload, "eps": eps}


if __name__ == "__main__":
    common.save_json("table7_twostage", run())
