"""Table VII: the two-stage optimization breakdown.

initial-valid value -> stage-1 (RL global) -> stage-2 (local GA), with the
paper's improvement percentages (stage-1: 37.9-99.8%, stage-2: 7-93%).
Driven through the registered "two_stage" optimizer; the stage breakdown
rides in SearchOutcome.extras.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import api

ROWS_FULL = [
    ("mobilenet_v2", "iot"), ("mnasnet", "iot"), ("resnet50", "cloud"),
    ("resnet50", "iot"), ("gnmt", "iot"), ("ncf", "iot"),
]
ROWS_QUICK = [("mobilenet_v2", "iot"), ("ncf", "iot")]


def run(budget_name: str = "quick") -> dict:
    b = common.budget(budget_name)
    eps, gens = b["eps"], b["ga_gens"]
    rows = ROWS_FULL if b["rows"] == "all" else ROWS_QUICK
    opts = {"ga": {"population": 20, "generations": gens,
                   "crossover_rate": 0.2, "mutation_rate": 0.05,
                   "mutation_step": 4}}
    out_rows, payload = [], []
    for model, plat in rows:
        out = api.run_search(api.SearchRequest(
            workload=model, env=api.EnvConfig(platform=plat), eps=eps,
            method="two_stage", options=opts))
        initial = out.extras["initial_valid_value"]
        stage1 = out.extras["stage1_value"]
        s1 = (100 * (1 - stage1 / initial)
              if np.isfinite(initial) else None)
        s2 = (100 * (1 - out.best_value / stage1)
              if np.isfinite(stage1) else None)
        payload.append({"model": model, "platform": plat,
                        "initial_valid": initial,
                        "stage1": stage1, "stage2": out.best_value,
                        "stage1_impr_pct": s1, "stage2_impr_pct": s2})
        out_rows.append([f"{model}-dla", plat, initial, stage1,
                         f"{s1:.1f}%" if s1 is not None else "-",
                         out.best_value,
                         f"{s2:.1f}%" if s2 is not None else "-"])
    common.print_table(
        f"Table VII (two-stage optimization, Eps={eps}, GA gens={gens})",
        ["model", "cstr", "init valid", "stage1", "impr", "stage2 (final)",
         "impr"], out_rows)
    return {"rows": payload, "eps": eps}


if __name__ == "__main__":
    common.save_json("table7_twostage", run())
