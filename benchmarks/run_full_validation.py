"""Full-budget (paper Eps=5000) validation runs for the headline tables.

    PYTHONPATH=src python -m benchmarks.run_full_validation

Runs Table IV (optimizer comparison, all 14 rows) and Table VII
(two-stage, all 6 rows) at the paper's sample budget and writes
results/<name>_full.json -- the quick-budget files from benchmarks.run
are left untouched.  Takes ~1 h on one CPU core.
"""
from __future__ import annotations

import sys
import time

from benchmarks import bench_table4_methods, bench_table7_twostage, common


def main(argv=None):
    t0 = time.time()
    for name, mod in [("table4_methods", bench_table4_methods),
                      ("table7_twostage", bench_table7_twostage)]:
        print(f"\n########## {name} (budget=full) ##########", flush=True)
        payload = mod.run("full")
        payload["_budget"] = "full"
        path = common.save_json(f"{name}_full", payload)
        print(f"[{name}] -> {path}", flush=True)
    print(f"full-budget validation finished in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
