"""Roofline report: the 40-cell (arch x shape) table from the dry-run.

Reads results/dryrun_*.jsonl (produced by repro.launch.dryrun, which must
run in its own process with 512 host devices) and prints the three roofline
terms per cell, the dominant bottleneck, and the useful-FLOPs ratio.  When
an optimized run (results/dryrun_opt.jsonl) is present, prints the
before/after deltas for the hillclimbed cells.

Hardware constants: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (distributed/hlo_analysis.py).
"""
from __future__ import annotations

import json
import os

from benchmarks import common

BASE = os.path.join(common.RESULTS_DIR, "dryrun_baseline.jsonl")
OPT = os.path.join(common.RESULTS_DIR, "dryrun_opt.jsonl")
AUTO = os.path.join(common.RESULTS_DIR, "dryrun_auto.jsonl")


def load(path):
    if not os.path.exists(path):
        return []
    recs = [json.loads(l) for l in open(path)]
    # Deduplicate on (arch, shape, mesh): last record wins.
    out = {}
    for r in recs:
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return list(out.values())


def run(budget_name: str = "quick") -> dict:
    base = load(BASE)
    if not base:
        print("no dry-run results found; run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        return {"error": "missing dryrun_baseline.jsonl"}
    single = [r for r in base if r["mesh"] == "16x16"]
    rows = []
    for r in sorted(single, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            rows.append([r["arch"], r["shape"], "SKIP (full attention)",
                         None, None, None, None, None])
            continue
        if r["status"] != "ok":
            rows.append([r["arch"], r["shape"], "ERROR"] + [None] * 5)
            continue
        rows.append([
            r["arch"], r["shape"], r["bottleneck"].replace("t_", ""),
            r["t_compute"], r["t_memory"], r["t_collective"],
            f"{100 * r['compute_fraction']:.1f}%",
            f"{100 * r['useful_flops_ratio']:.0f}%"])
    common.print_table(
        "Roofline (single-pod 16x16 = 256 chips; seconds per step)",
        ["arch", "shape", "bound", "t_comp", "t_mem", "t_coll",
         "comp frac", "useful/HLO"], rows)

    ok = [r for r in single if r["status"] == "ok"]
    summary = {
        "cells_total": len(single),
        "cells_ok": len(ok),
        "cells_skipped": sum(r["status"] == "skipped" for r in single),
        "collective_bound": sum(
            r.get("bottleneck") == "t_collective" for r in ok),
        "compute_bound": sum(
            r.get("bottleneck") == "t_compute" for r in ok),
        "memory_bound": sum(r.get("bottleneck") == "t_memory" for r in ok),
        "multi_pod_ok": sum(r["status"] == "ok" for r in base
                            if r["mesh"] == "2x16x16"),
    }

    opt = load(OPT)
    deltas = []
    if opt:
        by_key = {(r["arch"], r["shape"], r["mesh"]): r for r in base}
        drows = []
        for r in sorted(opt, key=lambda r: (r["arch"], r["shape"])):
            if r["status"] != "ok":
                continue
            b = by_key.get((r["arch"], r["shape"], r["mesh"]))
            if not b or b["status"] != "ok":
                continue
            speedup = b["bound_seconds"] / r["bound_seconds"]
            deltas.append({
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "before_s": b["bound_seconds"], "after_s": r["bound_seconds"],
                "speedup": speedup,
                "before_frac": b["compute_fraction"],
                "after_frac": r["compute_fraction"]})
            drows.append([r["arch"], r["shape"], r["mesh"],
                          b["bound_seconds"], r["bound_seconds"],
                          f"{speedup:.2f}x",
                          f"{100*b['compute_fraction']:.1f}%"
                          f"->{100*r['compute_fraction']:.1f}%"])
        if drows:
            common.print_table("Hillclimbed cells (before -> after)",
                               ["arch", "shape", "mesh", "bound before",
                                "bound after", "speedup", "comp frac"],
                               drows)
    # Full-grid optimized ("auto" mode) vs baseline comparison.
    auto = [r for r in load(AUTO) if r["mesh"] == "16x16"]
    auto_rows, auto_payload = [], []
    if auto:
        by_key = {(r["arch"], r["shape"]): r for r in single}
        for r in sorted(auto, key=lambda r: (r["arch"], r["shape"])):
            b = by_key.get((r["arch"], r["shape"]))
            if not b or r["status"] != "ok" or b["status"] != "ok":
                continue
            sp = b["bound_seconds"] / r["bound_seconds"]
            auto_payload.append({
                "arch": r["arch"], "shape": r["shape"],
                "mode": r.get("mode"), "speedup": sp,
                "before_s": b["bound_seconds"],
                "after_s": r["bound_seconds"],
                "after_bottleneck": r["bottleneck"],
                "after_frac": r["compute_fraction"]})
            auto_rows.append([
                r["arch"], r["shape"], r.get("mode"),
                b["bound_seconds"], r["bound_seconds"], f"{sp:.1f}x",
                r["bottleneck"].replace("t_", ""),
                f"{100*r['compute_fraction']:.0f}%"])
        if auto_rows:
            common.print_table(
                "Optimized defaults (--mode auto) vs baseline, all cells",
                ["arch", "shape", "mode", "before (s)", "after (s)",
                 "speedup", "bound", "comp frac"], auto_rows)
            import numpy as _np
            gm = float(_np.exp(_np.mean(
                [_np.log(p["speedup"]) for p in auto_payload])))
            n_cb = sum(p["after_bottleneck"] != "t_collective"
                       for p in auto_payload)
            print(f"geometric-mean speedup {gm:.2f}x over "
                  f"{len(auto_payload)} cells; "
                  f"{n_cb}/{len(auto_payload)} now compute- or "
                  "memory-bound")
            summary["auto_geomean_speedup"] = gm

    print(f"\n{summary['cells_ok']}/{summary['cells_total']} cells compiled "
          f"(+{summary['cells_skipped']} principled skips); bottleneck mix: "
          f"{summary['collective_bound']} collective / "
          f"{summary['compute_bound']} compute / "
          f"{summary['memory_bound']} memory; multi-pod (512-chip) ok: "
          f"{summary['multi_pod_ok']}")
    return {"summary": summary, "hillclimb": deltas,
            "auto_sweep": auto_payload}


if __name__ == "__main__":
    common.save_json("roofline", run())
