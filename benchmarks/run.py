"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--budget quick|full] \
        [--only table4,fig7]

quick (default): CI-sized budgets (Eps=600) -- every claim is exercised,
absolute values are noisier.  full: the paper's Eps=5000 (hours on CPU).
Each module writes results/<name>.json for EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import common

BENCHES = [
    ("fig5_perlayer", "benchmarks.bench_fig5_perlayer"),
    ("table3_lp", "benchmarks.bench_table3_lp"),
    ("table4_methods", "benchmarks.bench_table4_methods"),
    ("table5_rl", "benchmarks.bench_table5_rl"),
    ("table6_mix", "benchmarks.bench_table6_mix"),
    ("table7_twostage", "benchmarks.bench_table7_twostage"),
    ("table9_policy", "benchmarks.bench_table9_policy"),
    ("fig6_critic", "benchmarks.bench_fig6_critic"),
    ("fig7_convergence", "benchmarks.bench_fig7_convergence"),
    ("relaxed_oneshot", "benchmarks.bench_relaxed_oneshot"),
    ("frontier", "benchmarks.bench_frontier"),
    ("costmodel_throughput", "benchmarks.bench_costmodel_throughput"),
    ("dist_search", "benchmarks.bench_dist_search"),
    ("fanout_backends", "benchmarks.bench_fanout_backends"),
    ("search_service", "benchmarks.bench_search_service"),
    ("obs_overhead", "benchmarks.bench_obs_overhead"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--budget", default="quick", choices=["quick", "full"])
    ap.add_argument("--only", default="",
                    help="comma-separated bench name substrings")
    args = ap.parse_args(argv)

    sel = [s for s in args.only.split(",") if s]
    failures = []
    t_all = time.time()
    for name, module in BENCHES:
        if sel and not any(s in name for s in sel):
            continue
        print(f"\n########## {name} (budget={args.budget}) ##########",
              flush=True)
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            payload = mod.run(args.budget)
            payload["_budget"] = args.budget
            payload["_seconds"] = round(time.time() - t0, 1)
            path = common.save_json(name, payload)
            print(f"[{name}] done in {payload['_seconds']}s -> {path}",
                  flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
    print(f"\n===== benchmarks finished in {time.time()-t_all:.0f}s; "
          f"{len(failures)} failures =====")
    for name, err in failures:
        print(f"  FAILED {name}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
